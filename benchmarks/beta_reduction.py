"""Paper Fig. 4: β (boundary-edge ratio) with and without message reduction,
two- and three-way partitioning, scale-free vs uniform graphs."""
from __future__ import annotations

from repro.core import partition as PT
from benchmarks.common import emit, workload


def run(scale: int = 16):
    for kind in ("rmat", "uniform"):
        g = workload(scale, kind)
        for parts in (2, 3):
            pg = PT.partition(g, parts, PT.RAND, seed=0)
            emit(f"fig4_beta_{kind}{scale}_{parts}way", 0.0,
                 f"no_reduction={pg.beta_no_reduction:.3f}|"
                 f"with_reduction={pg.beta_with_reduction:.3f}")
