"""Parse compiled HLO text for collective traffic (spec §Roofline).

``cost_analysis()`` has no collective numbers, so we sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in ``compiled.as_text()``.

Loop correction: XLA prints each ``while`` body once, but scan bodies run
``trip_count`` times.  Every op's ``metadata={op_name="..."}`` records its
``/while/body/`` nesting path, so we multiply each collective by the product
of the trip counts of its enclosing loops.  Trip counts are supplied by the
caller per nesting depth (they are static properties of the program we
built: microbatch count, layer count, chunk counts).
"""
from __future__ import annotations

import re
from typing import Dict, List, Sequence

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _shape_bytes(dtype: str, dims: str) -> float:
    bs = _DTYPE_BYTES.get(dtype)
    if bs is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return float(n * bs)


def _first_output_bytes(line: str) -> float:
    """Sum the (tuple) output shapes on the lhs of the op line."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0.0
    # shapes between '=' and the op name
    m = re.match(r"\s*\(?([^)]*)\)?\s*" + "(?:" + "|".join(COLLECTIVES) + ")",
                 lhs[1])
    head = m.group(1) if m else lhs[1].split("(")[0]
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))            # [groups, members] v2 format
    m = _GROUPS_V1_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _depth(line: str) -> int:
    m = _OPNAME_RE.search(line)
    if not m:
        return 0
    return m.group(1).count("/while/body")


def collective_bytes(hlo_text: str,
                     depth_multipliers: Sequence[int] = (1,),
                     ) -> Dict[str, float]:
    """Per-collective operand bytes, loop-corrected.

    ``depth_multipliers[i]`` is the execution-count multiplier for a
    collective nested inside ``i`` while loops (e.g. train step:
    ``[1, n_microbatches, n_microbatches * n_layers]``); depths beyond the
    list reuse the last entry.
    """
    out = {c: 0.0 for c in COLLECTIVES}
    out["by_depth"] = {}
    for line in hlo_text.splitlines():
        for c in COLLECTIVES:
            if f" {c}(" not in line and f"{c}-start(" not in line:
                continue
            if f"%{c}" in line and " = " not in line:
                continue
            ob = _first_output_bytes(line)
            if ob == 0.0:
                continue
            g = _group_size(line)
            # operand bytes from output bytes per op semantics
            if c == "all-gather":
                operand = ob / max(g, 1)
            elif c == "reduce-scatter":
                operand = ob * g
            else:                      # all-reduce / all-to-all / permute
                operand = ob
            d = _depth(line)
            mult = depth_multipliers[min(d, len(depth_multipliers) - 1)]
            out[c] += operand * mult
            key = f"depth{d}"
            out["by_depth"][key] = out["by_depth"].get(key, 0.0) \
                + operand * mult
            break
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def collective_summary(hlo_text: str, depth_multipliers=(1,)) -> str:
    cb = collective_bytes(hlo_text, depth_multipliers)
    parts = [f"{c}={cb[c]/1e9:.3f}GB" for c in COLLECTIVES if cb[c]]
    return f"total={cb['total']/1e9:.3f}GB " + " ".join(parts)
