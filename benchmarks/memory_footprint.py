"""Paper Table 5: memory footprint of the offloaded (GPU) partition —
graph representation / inboxes / outboxes / algorithm state breakdown."""
from __future__ import annotations

from repro.core import partition as PT
from benchmarks.common import emit, workload

# per-vertex algorithm state sizes (paper Table 5 semantics)
ALG_STATE_BYTES = {"bfs": 4, "pagerank": 8, "bc": 16, "sssp": 4, "cc": 4}


def run(scale: int = 16):
    g = workload(scale, "rmat")
    pg = PT.partition(g, 2, PT.LOW, cpu_edge_fraction=0.7, seed=0)
    for alg, sbytes in ALG_STATE_BYTES.items():
        fp = PT.memory_footprint_bytes(pg, state_bytes=sbytes)
        p = 1  # the offloaded partition
        # records carry the non-numeric "tier" label since the tiered-memory
        # split — scale only the byte fields
        mb = {k: v / 2**20 for k, v in fp[p].items()
              if isinstance(v, (int, float))}
        emit(f"table5_{alg}_rmat{scale}", 0.0,
             f"graph={mb['graph']:.1f}MB|inbox={mb['inbox']:.1f}MB|"
             f"outbox={mb['outbox']:.1f}MB|state={mb['state']:.1f}MB|"
             f"total={mb['total']:.1f}MB|tier={fp[p]['tier']}")
