"""Paper Fig. 7 / Table 3: model-predicted vs measured hybrid speedup.

The paper offloads α-fraction partitions to GPUs and compares measured
speedup against Eq. 4.  Here the "hybrid" is the degree-split two-engine
step (MXU dense block + sparse remainder — DESIGN.md §2); the baseline is
the pure-sparse path.  Measured on the CPU backend (interpret-mode kernels),
so the *absolute* rates are not TPU numbers, but the model-vs-measured
correlation is exactly the paper's Table 3 metric.
"""
from __future__ import annotations

import numpy as np

from repro.core import perf_model as pm
from repro.core.hybrid import degree_split, hybrid_pagerank
from repro.algorithms import pagerank_reference
from benchmarks.common import emit, timeit, workload


def run(scale: int = 13):
    g = workload(scale, "rmat")
    base = degree_split(g, 0)

    base_t = timeit(lambda: hybrid_pagerank(base, num_iterations=3))
    # measured processing rate of the sparse engine (edges/s)
    r_sparse = 3 * g.num_edges / base_t

    preds, meas = [], []
    for k in (256, 512, 1024, 2048):
        hg = degree_split(g, k)
        t = timeit(lambda hg=hg: hybrid_pagerank(hg, num_iterations=3))
        measured = base_t / t
        # Eq.4 with alpha = sparse-path share, beta≈0 (on-chip split)
        alpha = hg.sparse_edges / g.num_edges
        # dense path "rate" measured analogue: assume dense engine ~free
        predicted = pm.speedup(alpha, beta=0.0, r_cpu=r_sparse, c=1e18)
        preds.append(predicted)
        meas.append(measured)
        emit(f"fig7_k_dense={k}", t,
             f"alpha={alpha:.2f}|pred={predicted:.2f}|meas={measured:.2f}")

    stats = pm.predicted_vs_measured(np.array(preds), np.array(meas))
    emit("table3_pagerank_rmat", 0.0,
         f"correlation={stats['correlation']:.3f}|"
         f"avg_error={stats['avg_error']*100:.1f}%")
