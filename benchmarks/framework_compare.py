"""Paper Table 4: TOTEM vs other frameworks (Galois/Ligra/PowerGraph).

Those frameworks are not available offline; the stand-ins are the strongest
same-machine single-threaded baselines available: scipy.sparse-style numpy
CSR kernels (the pagerank_reference/bfs_reference oracles, vectorized with
np.add.at / np.minimum.at — the idiomatic "lightweight framework" path).
The comparison answers the paper's question "is a generic engine
competitive with dedicated implementations?" on this container.
"""
from __future__ import annotations

import numpy as np

from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.algorithms import (bfs, bfs_reference, pagerank,
                              pagerank_reference, sssp, sssp_reference,
                              connected_components, cc_reference)
from repro.algorithms.cc import symmetrize
from benchmarks.common import emit, timeit, workload


def run(scale: int = 13):
    g = workload(scale, "rmat")
    gw = workload(scale, "rmat", weighted=True)
    gs = symmetrize(g)
    src = int(np.argmax(g.out_degrees()))

    eng = BSPEngine(PT.partition(g, 2, PT.HIGH, seed=0))
    engw = BSPEngine(PT.partition(gw, 2, PT.HIGH, seed=0))
    engs = BSPEngine(PT.partition(gs, 2, PT.HIGH, seed=0))

    cases = {
        "bfs": (lambda: bfs(eng, src)[0], lambda: bfs_reference(g, src)),
        "pagerank5": (lambda: pagerank(eng, 5),
                      lambda: pagerank_reference(g, 5)),
        "sssp": (lambda: sssp(engw, src)[0],
                 lambda: sssp_reference(gw, src)),
        "cc": (lambda: connected_components(engs)[0],
               lambda: cc_reference(gs)),
    }
    for name, (ours, ref) in cases.items():
        t_ours = timeit(ours, warmup=1, iters=3)
        t_ref = timeit(ref, warmup=0, iters=1)
        emit(f"table4_{name}_rmat{scale}", t_ours,
             f"totem_jax={t_ours*1e3:.0f}ms|numpy_ref={t_ref*1e3:.0f}ms|"
             f"ratio={t_ref/t_ours:.2f}x")
