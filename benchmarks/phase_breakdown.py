"""Paper Fig. 8/10/16: breakdown of execution time into computation vs
communication phases.

The engine's superstep is one fused XLA program, so phases are profiled by
lowering *phase-isolated* programs: (i) compute+reduce only (no exchange),
(ii) the full superstep.  The difference estimates the communication phase
— mirroring how the paper attributes stream-timer segments.  The expected
finding (paper §5.2): with message reduction, communication ≪ computation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import partition as PT
from repro.core.bsp import BSPEngine, _superstep, _Dims
from repro.algorithms.pagerank import make_pagerank_program, initial_state
from benchmarks.common import emit, timeit, workload


def run(scale: int = 14, parts: int = 4):
    g = workload(scale, "rmat")
    pg = PT.partition(g, parts, PT.HIGH, seed=0)
    eng = BSPEngine(pg)
    program = make_pagerank_program(pg.num_vertices)
    state0 = initial_state(pg)
    edges = eng.edges_for(program)
    dims = eng.dims_for(edges)

    full_step = jax.jit(functools.partial(
        _superstep, dims, program, edges, eng._exchange, jnp.all, None))

    def compute_only(state, step):
        # identical program with the exchange replaced by a zero-copy no-op
        return _superstep(dims, program, edges, lambda ob: ob * 0.0,
                          jnp.all, None, state, step)

    compute_step = jax.jit(compute_only)

    t_full = timeit(lambda: full_step(state0, jnp.int32(0)))
    t_comp = timeit(lambda: compute_step(state0, jnp.int32(0)))
    t_comm = max(t_full - t_comp, 0.0)
    emit(f"fig8_breakdown_rmat{scale}_{parts}parts", t_full,
         f"compute={t_comp/t_full*100:.0f}%|"
         f"communication={t_comm/t_full*100:.0f}%|"
         f"beta={pg.beta_with_reduction:.3f}")
