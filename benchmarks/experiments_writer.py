"""Generate EXPERIMENTS.md §Dry-run and §Roofline from the dry-run JSONs.

  PYTHONPATH=src:. python -m benchmarks.experiments_writer

§Perf is maintained by hand in experiments/perf_log.md (the hillclimb
iteration log) and included verbatim.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"
PERF_LOG = ROOT / "experiments" / "perf_log.md"
OUT = ROOT / "EXPERIMENTS.md"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "pagerank_superstep"]


def _cells(mesh):
    out = []
    for f in sorted(glob.glob(str(DRYRUN / f"*__{mesh}.json"))):
        out.append(json.load(open(f)))
    out.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                            if r["shape"] in SHAPE_ORDER else 99))
    return out


def _fix_sentence(rec):
    r = rec.get("roofline")
    if not r:
        return ""
    dom = r["dominant"]
    kind = rec["shape"]
    if dom == "compute" and r.get("useful_ratio", 1) < 0.8:
        return ("remat recompute inflates FLOPs ~4/3×; a selective "
                "checkpoint policy (save matmul outputs) trades memory for "
                "the recompute pass")
    if dom == "compute":
        return ("near the useful-FLOPs bound; next lever is attention-"
                "window/kernel-fusion to cut non-matmul overhead")
    if dom == "memory" and "decode" in kind or kind == "long_500k":
        return ("KV/state-cache streaming bound; levers: cache dtype (int8 "
                "KV), two-tier local/global cache, wider batch to amortize "
                "weight reads")
    if dom == "memory":
        return ("HBM traffic bound; levers: fused bf16 weights on the wire, "
                "activation re-layout, larger microbatches")
    return ("boundary traffic bound; levers: message reduction (done), "
            "int8 payloads, hierarchical in-pod reduce before cross-pod")


def write() -> None:
    single = _cells("single")
    multi = _cells("multi")

    md = ["# EXPERIMENTS", ""]
    md += [
        "Container: CPU-only (1 core); TPU v5e is the *target* "
        "(197 TFLOP/s bf16, 819 GB/s HBM, 4×50 GB/s ICI per chip). "
        "All dry-runs use 512 placeholder host devices "
        "(`xla_force_host_platform_device_count`, set only inside "
        "`launch/dryrun.py`).",
        "",
        "Methodology notes:",
        "- `cost_analysis()` counts `while`-body FLOPs ONCE (verified: a "
        "95-layer scan reports single-body numbers), so raw HLO FLOPs are "
        "recorded as a lower bound and the roofline compute/memory terms "
        "come from the first-principles calculator "
        "(`benchmarks/calculator.py`).",
        "- Collective bytes ARE parsed from `compiled.as_text()` (all-"
        "gather/all-reduce/reduce-scatter/all-to-all/collective-permute) "
        "with loop-depth multipliers from each op's `op_name` while-nesting "
        "(`benchmarks/hlo_analysis.py`).",
        "- `long_500k` runs only for sub-quadratic archs per spec; skips "
        "are recorded rows, not silent omissions.",
        "",
    ]

    # ----------------------------------------------------------------- dryrun
    md += ["## §Dry-run", ""]
    md += ["Every cell lowers + compiles for BOTH production meshes — "
           "single-pod `(data=16, model=16)` = 256 chips and multi-pod "
           "`(pod=2, data=16, model=16)` = 512 chips.", ""]
    for mesh, cells in (("single", single), ("multi", multi)):
        ok = sum(1 for r in cells if r.get("ok") and "skipped" not in r)
        skip = sum(1 for r in cells if r.get("skipped"))
        fail = sum(1 for r in cells if not r.get("ok"))
        md += [f"### Mesh: {mesh} — {ok} compiled, {skip} spec-skips, "
               f"{fail} failures", ""]
        md += ["| arch | shape | compile s | args GiB/dev | temp GiB/dev | "
               "HLO GFLOP (raw) | collectives GB (loop-corrected) |",
               "|---|---|---|---|---|---|---|"]
        for r in cells:
            if r.get("skipped"):
                md += [f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"{r['skipped'].split(';')[0]} |"]
                continue
            if not r.get("ok"):
                md += [f"| {r['arch']} | {r['shape']} | FAILED | | | | |"]
                continue
            ma = r["memory_analysis"]
            cb = r.get("collective_bytes", {})
            md += ["| {a} | {s} | {c:.1f} | {arg:.2f} | {tmp:.2f} | "
                   "{fl:.1f} | {coll:.1f} |".format(
                       a=r["arch"], s=r["shape"], c=r.get("compile_s", 0),
                       arg=ma.get("argument_bytes", 0) / 2**30,
                       tmp=ma.get("temp_bytes", 0) / 2**30,
                       fl=r["cost_analysis_raw"]["flops"] / 1e9,
                       coll=cb.get("total", 0) / 1e9)]
        md += [""]

    # --------------------------------------------------------------- roofline
    md += ["## §Roofline (single-pod, 256 chips)", ""]
    md += ["Terms in seconds per step/device: compute = FLOPs/(chips·peak), "
           "memory = bytes/(chips·HBM), collective = bytes/(chips·ICI). "
           "`useful` = MODEL_FLOPS / total-compiled-FLOPs "
           "(6·N·D dense, 6·N_active·D MoE); `MFU bound` = model FLOPs over "
           "peak during max(term).", ""]
    md += ["| arch | shape | compute s | memory s | collective s | "
           "dominant | useful | MFU bound | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in single:
        if not r.get("ok") or "roofline" not in r:
            continue
        rf = r["roofline"]
        md += ["| {a} | {s} | {c:.2e} | {m:.2e} | {k:.2e} | {d} | {u:.2f} | "
               "{f:.3f} | {fix} |".format(
                   a=r["arch"], s=r["shape"], c=rf["compute_s"],
                   m=rf["memory_s"], k=rf["collective_s"], d=rf["dominant"],
                   u=rf.get("useful_ratio", 0),
                   f=rf.get("mfu_bound", 0), fix=_fix_sentence(r))]
    md += [""]

    skips = [r for r in single if r.get("skipped")]
    if skips:
        md += ["Skipped cells (spec: long_500k needs sub-quadratic "
               "attention): " + ", ".join(f"{r['arch']}" for r in skips),
               ""]

    # ------------------------------------------------- paper validation
    bench_out = ROOT / "bench_output.txt"
    md += ["## §Paper-validation (reduced-scale, CPU backend)", ""]
    if bench_out.exists():
        lines = bench_out.read_text().splitlines()
        keys = ("fig2_worstcase", "fig4_beta", "table3_", "fig8_breakdown",
                "fig9_bfs_high", "fig9_bfs_rand", "table4_")
        md += ["Key rows from `bench_output.txt` "
               "(full CSV there; one benchmark per paper artifact):", "",
               "```"]
        md += [ln for ln in lines if ln.startswith(keys)]
        md += ["```", "",
               "Reading:",
               "- **Fig. 4 reproduced**: message reduction drops β from "
               "~50% to 3–5% on scale-free graphs (the paper reports <5%).",
               "- **Fig. 8 reproduced**: with reduction, communication is "
               "~5% of a superstep vs ~95% computation — the paper's "
               "pivotal finding that partitioning should target compute.",
               "- **Table 3 methodology**: model-vs-measured correlation "
               "0.72 on the hybrid two-engine step (paper: 0.88–0.99 on "
               "real hardware; interpret-mode kernel timings on 1 CPU core "
               "add noise the TPU target would not have).",
               "- **Fig. 9 nuance**: HIGH shrinks the bottleneck "
               "partition's vertex share 0.50 → 0.02 (the Fig. 13 "
               "mechanism), but wall-clock TEPS is ≈flat on this backend — "
               "the paper's super-linear win comes from LLC residency, "
               "which XLA-on-CPU segment ops do not model; the TPU "
               "analogue (VMEM-resident frontier) lives in the dense-path "
               "Pallas kernel.",
               "- **Table 4 caveat**: the numpy reference beats the engine "
               "at toy scale on CPU (fixed JAX dispatch overhead); this "
               "measures framework overhead, not the TPU-target "
               "throughput, which §Roofline covers.", ""]
    else:
        md += ["(run `python -m benchmarks.run | tee bench_output.txt` "
               "then regenerate)", ""]

    # ------------------------------------------------------------------ perf
    md += ["## §Perf — hillclimb log", ""]
    if PERF_LOG.exists():
        md += [PERF_LOG.read_text()]
    else:
        md += ["(pending — see experiments/perf_log.md)"]

    OUT.write_text("\n".join(md) + "\n")
    print(f"wrote {OUT} ({len(single)} single cells, {len(multi)} multi)")


if __name__ == "__main__":
    write()
