"""Paper Fig. 9 + Fig. 13: partitioning strategies (RAND/HIGH/LOW) vs the
share of edges on the bottleneck partition; BFS traversal rate (TEPS) and
the |V_cpu| skew that explains it (paper §6.3.2)."""
from __future__ import annotations

import numpy as np

from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.algorithms import bfs
from repro.algorithms.bfs import teps
from benchmarks.common import emit, timeit, workload


def run(scale: int = 14):
    g = workload(scale, "rmat")
    src = int(np.argmax(g.out_degrees()))

    for strategy in (PT.RAND, PT.HIGH, PT.LOW):
        for alpha in (0.5, 0.8):
            pg = PT.partition(g, 2, strategy, cpu_edge_fraction=alpha,
                              seed=0)
            eng = BSPEngine(pg)
            levels, _ = bfs(eng, src)            # warm компile + correctness

            def run_once():
                return bfs(eng, src)[0]

            t = timeit(run_once, warmup=0, iters=3)
            rate = teps(g, levels, t)
            v_share = pg.assignment.part_sizes[0] / g.num_vertices
            emit(f"fig9_bfs_{strategy}_alpha={alpha}", t,
                 f"TEPS={rate/1e6:.2f}M|V_share_p0={v_share:.3f}|"
                 f"beta={pg.beta_with_reduction:.3f}")
