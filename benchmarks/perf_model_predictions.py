"""Paper Fig. 2 + Fig. 3: speedups predicted by the performance model.

Left (Fig. 2a): vary r_cpu at β=5%.  Right (Fig. 2b): vary β at r_cpu=1BE/s.
Fig. 3: vary bytes/edge at α=60%.  Values reproduce the paper's curves from
Eq. 4 with the paper's parameters (c = 3 BE/s from 12 GB/s PCIe ÷ 4 B/edge);
the derived column also reports the TPU re-parameterization.
"""
from __future__ import annotations

import numpy as np

from repro.core import perf_model as pm
from benchmarks.common import emit


def run():
    alphas = np.array([0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9])

    # Fig 2 (left): r_cpu sweep at beta=5%
    for r_cpu in (0.5e9, 1e9, 2e9):
        s = pm.speedup_curve(alphas, beta=0.05, r_cpu=r_cpu, c=pm.PAPER_C)
        emit(f"fig2_left_rcpu={r_cpu/1e9:.1f}BE/s", 0.0,
             "speedup@alpha=" + "|".join(f"{a:.1f}:{v:.2f}"
                                         for a, v in zip(alphas, s)))

    # Fig 2 (right): beta sweep at r_cpu=1BE/s — includes the paper's
    # worst case beta=100% (slowdown only when alpha > ~0.7... see paper)
    for beta in (0.02, 0.05, 0.2, 0.4, 1.0):
        s = pm.speedup_curve(alphas, beta=beta, r_cpu=1e9, c=pm.PAPER_C)
        emit(f"fig2_right_beta={beta:.2f}", 0.0,
             "speedup@alpha=" + "|".join(f"{a:.1f}:{v:.2f}"
                                         for a, v in zip(alphas, s)))
    # paper check: at beta=1.0 slowdown appears only for alpha < ~0.7
    s_worst = pm.speedup_curve(alphas, beta=1.0, r_cpu=1e9, c=pm.PAPER_C)
    crossover = alphas[np.argmax(s_worst < 1.0)] if (s_worst < 1.0).any() \
        else None
    emit("fig2_worstcase_crossover", 0.0, f"alpha<1 below alpha={crossover}")

    # Fig 3: bytes/edge sweep at alpha=0.6
    for bytes_per_edge in (4, 8, 12):
        c = pm.PAPER_PCIE_GBPS / bytes_per_edge
        s = pm.speedup_curve(alphas, beta=0.05, r_cpu=1e9, c=c)
        emit(f"fig3_bytes_per_edge={bytes_per_edge}", 0.0,
             "speedup@alpha=" + "|".join(f"{a:.1f}:{v:.2f}"
                                         for a, v in zip(alphas, s)))

    # TPU re-parameterization (DESIGN.md §2)
    tpu = pm.ModelParams.tpu_defaults()
    s = pm.speedup_curve(alphas, beta=0.05, r_cpu=tpu.r_bottleneck, c=tpu.c)
    emit("tpu_reparam_beta=0.05", 0.0,
         f"c={tpu.c/1e9:.1f}BE/s r_sparse={tpu.r_bottleneck/1e9:.1f}BE/s "
         + "speedup@alpha=" + "|".join(f"{a:.1f}:{v:.2f}"
                                       for a, v in zip(alphas, s)))
    emit("tpu_mxu_crossover_density", 0.0,
         f"{pm.mxu_crossover_density():.2e}")
