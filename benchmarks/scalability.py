"""Paper Fig. 23: scalability across graph sizes and engine configurations.

The paper sweeps RMAT27→30 on 1S/2S/1S1G/2S1G/2S2G.  CPU-container analogue:
graph sizes RMAT12→16, configurations = {sparse-only (xS), hybrid
dense+sparse (xSyG)} × {1, 2 partitions}.  Rates are CPU-backend numbers —
relative scaling is the signal, as absolute TPU rates come from §Roofline.
"""
from __future__ import annotations

from repro.core.hybrid import degree_split, hybrid_pagerank
from benchmarks.common import emit, timeit, workload


def run():
    for scale in (12, 13, 14):
        g = workload(scale, "rmat")
        configs = {
            "sparse_only(2S)": 0,
            "hybrid(2S1G)": max(256, g.num_vertices // 16),
            "hybrid_big(2S2G)": max(512, g.num_vertices // 8),
        }
        for name, k in configs.items():
            hg = degree_split(g, k)
            t = timeit(lambda hg=hg: hybrid_pagerank(hg, num_iterations=3),
                       iters=3)
            rate = 3 * g.num_edges / t
            emit(f"fig23_pagerank_rmat{scale}_{name}", t,
                 f"TEPS={rate/1e6:.2f}M|dense_frac={hg.dense_fraction:.2f}")
