"""Benchmark harness entry point — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).  The graph
benchmarks run reduced workloads on the CPU backend (absolute rates are not
TPU numbers — DESIGN.md §6); the roofline section reads the 512-device
dry-run artifacts.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    import benchmarks.perf_model_predictions as b_model
    import benchmarks.beta_reduction as b_beta
    import benchmarks.model_accuracy as b_acc
    import benchmarks.partitioning_sweep as b_part
    import benchmarks.phase_breakdown as b_phase
    import benchmarks.scalability as b_scale
    import benchmarks.framework_compare as b_frame
    import benchmarks.memory_footprint as b_mem
    import benchmarks.roofline as b_roof

    sections = [
        ("fig2_fig3_perf_model", b_model.run),
        ("fig4_beta_reduction", b_beta.run),
        ("fig7_table3_model_accuracy", b_acc.run),
        ("fig8_phase_breakdown", b_phase.run),
        ("fig9_partitioning", b_part.run),
        ("fig23_scalability", b_scale.run),
        ("table4_framework_compare", b_frame.run),
        ("table5_memory_footprint", b_mem.run),
        ("roofline_40cells", b_roof.run),
    ]
    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{name},ERROR,", flush=True)
            traceback.print_exc()
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
