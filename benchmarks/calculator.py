"""First-principles roofline calculator (napkin math, per arch × shape).

Why analytic: XLA's ``cost_analysis()`` counts ``while``-loop bodies ONCE
(verified empirically — a 95-layer scan reports single-body FLOPs), so raw
HLO numbers under-count by the trip counts of the layer/microbatch scans.
The roofline compute/memory terms are therefore derived from first
principles here (the formulas ARE the napkin math the perf loop needs), the
collective term is parsed from the compiled HLO with loop-depth multipliers
(hlo_analysis.py), and raw cost_analysis numbers are recorded alongside as
the lower-bound cross-check.

Hardware constants per task spec: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (4 links/chip on a 2D torus).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.models.common import ArchConfig
from repro.models import api
from repro.models.api import ShapeSpec

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9 * 4          # per-chip aggregate over 4 links
BF16 = 2
F32 = 4


def count_params(cfg: ArchConfig) -> Dict[str, float]:
    """Exact parameter count via eval_shape; MoE active split."""
    import jax
    import numpy as np
    model = api.build(cfg)
    shapes = model.params_shape()
    total = 0
    moe = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        n = int(np.prod(leaf.shape))
        total += n
        if any("moe_w" in str(p) for p in path):
            moe += n
    active = total - moe + (moe * cfg.moe_top_k // max(cfg.moe_experts, 1)
                            if cfg.is_moe else 0)
    return {"total": total, "moe": moe, "active": active}


def _attn_flops_per_token(cfg: ArchConfig, s_att: float) -> float:
    d, hd, h, g = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    proj = 2 * d * (h + 2 * g) * hd + 2 * h * hd * d      # qkv + wo
    attn = 4 * s_att * h * hd                             # QKᵀ + PV
    return proj + attn


def _ffn_flops_per_token(cfg: ArchConfig) -> float:
    if cfg.is_moe:
        router = 2 * cfg.d_model * cfg.moe_experts
        expert = cfg.moe_top_k * 6 * cfg.d_model * cfg.d_ff
        return router + expert
    return 6 * cfg.d_model * cfg.d_ff


def _mamba_flops_per_token(cfg: ArchConfig, chunk: int = 128) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = di // 64
    proj = 2 * d * (2 * di + 2 * n + h) + 2 * di * d      # in_proj + out
    conv = 2 * cfg.ssm_conv * (di + 2 * n)
    # chunked SSD per token: intra-chunk quadratic + state in/out
    ssd = 2 * chunk * (n + di) + 4 * n * di
    return proj + conv + ssd


def _xlstm_flops_per_token(cfg: ArchConfig, chunk: int = 128) -> float:
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    dh = di // h
    proj = 2 * d * (2 * di) + 2 * d * di * 3 + 2 * di * d  # up,q,k,ogate,down
    # mLSTM via SSD dual (matrix memory: n = p = dh) + normalizer (p=1)
    mlstm = 2 * chunk * h * (dh + dh * dh) + 4 * h * dh * dh * dh / chunk \
        + 2 * chunk * h * 2 * dh
    return proj + mlstm


def _layer_flops_per_token(cfg: ArchConfig, s_att: float) -> float:
    if cfg.family == "ssm":
        return _xlstm_flops_per_token(cfg)
    if cfg.family == "hybrid":
        f = _mamba_flops_per_token(cfg)
        # shared attention block every attn_every layers (amortized)
        attn = (_attn_flops_per_token(cfg, s_att)
                + _ffn_flops_per_token(cfg)) / cfg.attn_every
        return f + attn
    return _attn_flops_per_token(cfg, s_att) + _ffn_flops_per_token(cfg)


@dataclasses.dataclass
class Roofline:
    flops: float               # per device per step
    hbm_bytes: float           # per device per step
    ici_bytes: float           # per device per step (analytic estimate)
    model_flops: float         # 6·N(active)·D global (reference)
    compute_s: float
    memory_s: float
    collective_s: float
    num_chips: int = 1

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total HLO-style FLOPs (global vs global) — the
        spec's remat/redundancy-waste metric."""
        total = self.flops * self.num_chips
        return self.model_flops / total if total else 0.0

    @property
    def attainment_bound(self) -> float:
        """Fraction of a perfectly-overlapped roofline step the dominant
        term would occupy if nothing overlapped (serial pessimistic)."""
        total = self.compute_s + self.memory_s + self.collective_s
        return max(self.compute_s, self.memory_s,
                   self.collective_s) / max(total, 1e-30)

    @property
    def mfu_bound(self) -> float:
        """Best-case MFU: model flops over peak during max(terms)."""
        step = max(self.compute_s, self.memory_s, self.collective_s)
        return self.model_flops / (self.num_chips * PEAK_FLOPS
                                   * max(step, 1e-30))

    def as_dict(self) -> dict:
        return {**dataclasses.asdict(self),
                "dominant": self.dominant,
                "useful_ratio": self.useful_ratio,
                "attainment_bound": self.attainment_bound,
                "mfu_bound": self.mfu_bound}


def analyze(cfg: ArchConfig, shape: ShapeSpec, num_chips: int,
            ici_bytes_measured: float | None = None) -> Roofline:
    """Roofline terms for one (arch × shape) cell on ``num_chips``."""
    b, s = shape.batch, shape.seq
    n_mb = cfg.microbatches if shape.kind == "train" else 1
    params = count_params(cfg)
    n_total, n_active = params["total"], params["active"]
    L = cfg.n_layers
    d, v = cfg.d_model, cfg.vocab

    if shape.kind == "train":
        tokens = b * s
        # causal attention averages S/2 keys; local layers see the window
        if cfg.local_global_ratio:
            r = cfg.local_global_ratio
            s_att = (r * min(cfg.local_window, s) + s / 2) / (r + 1)
        else:
            s_att = s / 2
        fwd_layer = _layer_flops_per_token(cfg, s_att) * tokens * L
        if cfg.enc_dec:
            fwd_layer *= 2  # encoder stack + decoder stack
        head = 2 * d * v * tokens
        # fwd + bwd(2×) + remat recompute(≈1× of layers) ; head has no remat
        flops = 4 * fwd_layer + 3 * head
        model_flops = 6 * n_active * tokens

        b_mb = b // n_mb
        act = b_mb * s * d * BF16
        hbm = (
            3 * 2 * n_total * n_mb            # weights: fwd+bwd+remat reads
            + 28 * n_total                    # optimizer: p,m,v r/w + grads
            + 12 * act * L * n_mb             # activation write/read traffic
            + 3 * b_mb * s * v * F32 * n_mb   # logits + softmax traffic
        )
        # FSDP all-gather (bf16 weights per mb) + grad reduce-scatter (f32)
        tp = 16
        ici = (2 * n_total / tp * n_mb        # param all-gather per mb
               + 4 * n_total / tp * n_mb      # grad reduce-scatter per mb
               + 4 * act * L * n_mb)          # TP activation all-reduces
    elif shape.kind == "prefill":
        tokens = b * s
        s_att = s / 2
        fwd_layer = _layer_flops_per_token(cfg, s_att) * tokens * L
        if cfg.enc_dec:
            enc_tokens = b * 4096
            fwd_layer += _layer_flops_per_token(cfg, 4096 / 2) \
                * enc_tokens * L
        head = 2 * d * v * b                  # last-token logits only
        flops = fwd_layer + head
        model_flops = 2 * n_active * tokens
        act = b * s * d * BF16
        cache = 2 * L * b * s * cfg.n_kv_heads * cfg.hd * BF16
        hbm = 2 * n_total + 6 * act * L + cache
        ici = 2 * n_total / 16 + 2 * act * L
    else:  # decode: one token against an s-long cache
        tokens = b
        if cfg.family == "ssm":
            s_att = 0.0
        elif cfg.family == "hybrid":
            s_att = s          # shared attn reads full cache
        elif cfg.local_global_ratio:
            r = cfg.local_global_ratio
            s_att = (r * min(cfg.local_window, s) + s) / (r + 1)
        else:
            s_att = s
        flops = _layer_flops_per_token(cfg, s_att) * tokens * L \
            + 2 * d * v * tokens
        model_flops = 2 * n_active * tokens
        # KV cache read dominates HBM traffic
        if cfg.family == "ssm":
            di = 2 * d
            dh = di // cfg.n_heads
            cache = L * b * cfg.n_heads * (dh * dh + 3 * dh) * F32 * 2
        elif cfg.family == "hybrid":
            di = cfg.ssm_expand * d
            ssm = L * b * (di * cfg.ssm_state / 64 * 64) * BF16 * 2
            ng = L // cfg.attn_every
            cache = ssm + 2 * ng * b * s * cfg.n_kv_heads * cfg.hd * BF16
        else:
            eff = s_att if cfg.local_global_ratio else s
            cache = 2 * L * b * eff * cfg.n_kv_heads * cfg.hd * BF16
        hbm = 2 * n_total + cache
        ici = 2 * n_total / 16 / 8  # per-step weight traffic amortizes; TP ar
        ici += 2 * b * d * L * BF16 * 2

    if ici_bytes_measured is not None:
        ici = ici_bytes_measured

    flops_dev = flops / num_chips
    hbm_dev = hbm / num_chips
    ici_dev = ici / num_chips
    return Roofline(
        flops=flops_dev, hbm_bytes=hbm_dev, ici_bytes=ici_dev,
        model_flops=model_flops,
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=hbm_dev / HBM_BW,
        collective_s=ici_dev / ICI_BW,
        num_chips=num_chips,
    )
