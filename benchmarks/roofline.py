"""§Roofline: aggregate the dry-run JSONs into the per-cell roofline table.

For each (arch × shape × mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and per-device memory — the
deliverable (g) table.  Also regenerates EXPERIMENTS.md §Dry-run/§Roofline
when invoked with --write-experiments (see experiments_writer.py).
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh: str = "single"):
    cells = []
    for f in sorted(glob.glob(str(DRYRUN_DIR / f"*__{mesh}.json"))):
        rec = json.load(open(f))
        if rec.get("ok") and "roofline" in rec:
            cells.append(rec)
    return cells


def run():
    cells = load_cells("single")
    if not cells:
        emit("roofline_missing", 0.0,
             "run: python -m repro.launch.dryrun --all --mesh both")
        return
    for rec in cells:
        r = rec["roofline"]
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        frac = max(r["compute_s"], r["memory_s"], r["collective_s"]) / \
            max(total, 1e-30)
        emit(f"roofline_{rec['arch']}_{rec['shape']}", total,
             f"dominant={r['dominant']}|compute={r['compute_s']:.2e}s|"
             f"memory={r['memory_s']:.2e}s|"
             f"collective={r['collective_s']:.2e}s|"
             f"useful_ratio={r['useful_ratio']:.2f}|"
             f"peak_mem={rec['memory_analysis']['peak_estimate_gib']}GiB")
    # summary: dominant-term histogram
    from collections import Counter
    hist = Counter(rec["roofline"]["dominant"] for rec in cells)
    emit("roofline_summary", 0.0,
         "|".join(f"{k}={v}" for k, v in sorted(hist.items())))
