"""Reference vs fused BSP superstep timings → BENCH_superstep.json.

Times one jitted superstep of the reference path (gather → [Pl, e_max]
messages → scatter-reduce) against the fused Pallas path for a sum-combine
program (PageRank) and a min-combine program (BFS), across RMAT scales and
all three partitioning strategies (RAND/HIGH/LOW).

Also verifies the fused path's core claim **structurally**: the compiled HLO
of the fused superstep must contain no non-parameter op producing an
``f32[Pl, e_max]`` (or ``f32[Pl, e_pad]``) value — i.e. the edge-message
array is never materialized in HBM.  The reference superstep must contain at
least one (that's the array being eliminated).  BFS and PageRank take no
``f32[Pl, e_max]``-shaped *inputs* either, so the check is exact for them.

Runs in interpret mode on CPU (the container default); on a real TPU the
same script times the compiled kernels.

Usage (from the repo root):
  python benchmarks/superstep_bench.py [--scales 10 11] [--parts 4]
      [--quick] [--hybrid] [--batched] [--dopt] [--distributed]
      [--devices 8] [--seed 1] [--out BENCH_superstep.json]

``--quick`` keeps only the smallest scale (the CI bench job's ~5-minute
budget); ``--hybrid`` also times the degree-split two-engine backend per
cell; ``--seed`` pins the RMAT topology so cells are comparable across runs.
``--batched`` adds the query-throughput column: full batched BFS runs at
Q ∈ {1, 8, 32} against Q sequential single-source runs on the same engine,
recording queries/sec, the amortized per-query time, the amortization
ratio, and the compile-cache growth across same-Q batches.  The
deterministic claim is asserted everywhere: a batch of Q queries runs
through **one** compiled while_loop (``retraces == 0`` across batches with
different sources — the compile-cache-hit contract).  The throughput claim
— amortized per-query time strictly below the sequential per-query time
for Q ≥ 8 — is asserted on a real TPU backend, where one while_loop
dispatch and one kernel-launch sequence genuinely replace Q of each; in
CPU interpret mode the Pallas grids execute Q× Python cells and XLA-CPU
compute scales ~linearly with Q, so (exactly like the fused/reference
economics, see ROADMAP) the ratio inverts and is *recorded* and
regression-gated by ``scripts/bench_check.py`` instead.  Point
``--scales 18`` at it for the rmat18 serving measurement.
``--dopt`` adds the direction-optimized traversal column (docs/traversal.md):
batched BFS over the *symmetrized* bench graph under forced top-down
(``direction="push"``) vs the fitted per-shard auto switch, recording wall
times (noisy, baseline-gated) and the deterministic superstep-indexed
counters that are absolutely asserted — auto examines strictly fewer edges
than top-down through at least one real switch, stays bitwise-identical to
the numpy oracle, respects the once-per-edge push bound, and never
retraces across a switch.
``--distributed`` adds a multi-device column: the bench re-executes itself
in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
when the runtime has fewer than ``--devices`` devices, then times one
superstep of the sharded fused engine against the sharded *hybrid* engine
(per-shard degree split + aggregated-outbox exchange) and records the
per-superstep exchanged bytes: the full ``[pl, P, o_max]`` tensor the
fused/reference exchange ships vs the compact used-slot blocks of the
hybrid exchange, next to the β·|E|·4 aggregation bound (paper §3.4).
``scripts/bench_check.py`` diffs the JSON against a baseline and fails on
>20% fused-superstep regression — and deterministically on any >20% growth
in exchanged bytes or fused temp bytes.
"""
from __future__ import annotations

import argparse
import functools
import json
import re
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))

from benchmarks.common import timeit  # noqa: E402
from repro.core import graph as G  # noqa: E402
from repro.core import partition as PT  # noqa: E402
from repro.core.bsp import BSPEngine  # noqa: E402
from repro.kernels.ops import fused_span_limit  # noqa: E402
from repro.algorithms.bfs import BFS_PROGRAM  # noqa: E402
from repro.algorithms.pagerank import (initial_state,  # noqa: E402
                                       make_pagerank_program)

_SKIP_OPS = ("parameter(", " copy(", "bitcast(", "constant(")


def message_array_lines(hlo: str, pl_count: int, e_sizes) -> list:
    """HLO lines where a non-parameter op produces an f32[Pl, e_*] value
    (with or without the engine's leading query-batch dim: f32[Q, Pl, e_*]
    counts too — a batched message array is still a message array)."""
    pats = [re.compile(rf"f32\[(?:\d+,)?{pl_count},{e}\]")
            for e in set(e_sizes)]
    hits = []
    for line in hlo.splitlines():
        lhs = line.split(" = ", 1)
        if len(lhs) != 2 or any(tok in lhs[1] for tok in _SKIP_OPS):
            continue
        head = lhs[1].split("(", 1)[0]   # output shape + op name
        if any(p.search(head) for p in pats):
            hits.append(line.strip())
    return hits


def _superstep_fn(eng: BSPEngine, program):
    from repro.core.bsp import batch_state

    edges = eng._edges_or_none(program)
    step_fn = eng._step_fn(program, edges, eng._exchange, eng._all_finished)
    # The internal step runs on [Q, Pl, ...] state; time it as a Q=1 batch
    # (exactly what run() executes per superstep).
    return jax.jit(lambda s, i: step_fn(batch_state(s), i))


def _program_and_state(pg, parts: int, alg: str):
    """The benchmarked program + initial state, shared by the single-device
    and distributed cells so their timings stay comparable."""
    if alg == "pagerank":
        return make_pagerank_program(pg.num_vertices), initial_state(pg)
    level0 = np.full((parts, pg.v_max), np.inf, dtype=np.float32)
    level0[0, 0] = 0.0
    return BFS_PROGRAM, {"level": jnp.asarray(level0)}


def bench_cell(pg, scale: int, parts: int, strategy: str, alg: str,
               block_e: int, hybrid: bool = False) -> dict:
    ref_eng = BSPEngine(pg)
    fus_eng = BSPEngine(pg, fused=True, block_e=block_e)
    program, state = _program_and_state(pg, parts, alg)

    blk = fus_eng._fwd_blk
    e_sizes = (pg.fwd.e_max, blk.e_pad)
    rec = dict(scale=scale, parts=parts, strategy=strategy, algorithm=alg,
               combine=program.combine, e_max=pg.fwd.e_max, e_pad=blk.e_pad,
               span=blk.span, span_req=blk.span_req, block_e=block_e,
               num_blocks=blk.num_blocks, v_max=pg.v_max,
               beta=pg.beta_with_reduction,
               # False → span exceeded max_span/VMEM budget and this cell's
               # "fused" engine statically fell back to the reference chain.
               fused_active=blk.span <= fused_span_limit(
                   block_e, program.combine))

    engines = [("ref", ref_eng), ("fused", fus_eng)]
    if hybrid:
        hyb_eng = BSPEngine(pg, backend="hybrid")
        engines.append(("hybrid", hyb_eng))
        plan = hyb_eng.hybrid_plan()
        rec["hybrid_k_dense"] = plan["k_dense"]
        rec["hybrid_mode"] = plan["mode"]

    step0 = jnp.int32(0)
    for name, eng in engines:
        fn = _superstep_fn(eng, program)
        lowered = fn.lower(state, step0)
        compiled = lowered.compile()
        hlo = compiled.as_text()
        rec[f"{name}_hlo_msg_arrays"] = len(
            message_array_lines(hlo, parts, e_sizes))
        try:
            rec[f"{name}_temp_bytes"] = int(
                compiled.memory_analysis().temp_size_in_bytes)
        except Exception:
            rec[f"{name}_temp_bytes"] = None
        rec[f"{name}_ms"] = timeit(fn, state, step0, warmup=1, iters=5) * 1e3

    rec["speedup"] = rec["ref_ms"] / max(rec["fused_ms"], 1e-12)
    return rec


def bench_batched_cell(pg, scale: int, parts: int, strategy: str,
                       q: int, block_e: int, seed: int,
                       backend: str = "reference") -> dict:
    """One query-throughput cell: a batch of Q BFS queries through one
    ``run_batched`` while_loop vs Q sequential single-source runs on the
    same engine.  Wall-clock timings are full-run (including host-side
    state construction and gather — the serving-realistic cost)."""
    import time

    from repro.algorithms.bfs import bfs, bfs_batched

    if backend == "fused":
        eng = BSPEngine(pg, fused=True, block_e=block_e)
    elif backend == "hybrid":
        eng = BSPEngine(pg, backend="hybrid")
    else:
        eng = BSPEngine(pg)
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, pg.num_vertices, size=q)

    def wall(fn, iters=3):
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return sorted(times)[len(times) // 2]

    bfs_batched(eng, sources)                  # compile the Q-batch loop
    cache_fn = BSPEngine._run_batched
    entries0 = cache_fn._cache_size()
    # Different sources, same Q: must reuse the compiled loop (no retrace).
    bfs_batched(eng, rng.integers(0, pg.num_vertices, size=q))
    retraces = cache_fn._cache_size() - entries0
    batched_s = wall(lambda: bfs_batched(eng, sources))

    bfs(eng, int(sources[0]))                  # compile the Q=1 loop
    seq_s = wall(lambda: [bfs(eng, int(s)) for s in sources], iters=1)

    return dict(
        scale=scale, parts=parts, strategy=strategy, algorithm="bfs",
        combine="min", mode=f"batched_q{q}", q=q, block_e=block_e,
        backend=backend, v_max=pg.v_max,
        batched_ms=batched_s * 1e3,
        batched_ms_per_query=batched_s * 1e3 / q,
        seq_ms=seq_s * 1e3, seq_ms_per_query=seq_s * 1e3 / q,
        amortization=seq_s / max(batched_s, 1e-12),
        queries_per_sec=q / max(batched_s, 1e-12),
        retraces=retraces,
        compile_cache_entries=cache_fn._cache_size())


def bench_dopt_cell(g, pg, scale: int, parts: int, strategy: str,
                    seed: int, backend: str = "reference",
                    block_e: int = 256, q: int = 4) -> dict:
    """One direction-optimized traversal cell: a Q-batch of BFS queries
    under forced ``direction="push"`` (classic top-down) vs ``"auto"``
    (per-query, per-shard fitted switching — docs/traversal.md), on the
    same engine backend.  Timings are noisy on CPU and only recorded; the
    asserted halves are the *deterministic* edge counters: auto must
    examine fewer edges than top-down while staying bitwise-identical to
    the numpy oracle, top-down must respect the once-per-edge BFS bound
    (every vertex joins the frontier exactly once, so a query scans at
    most |E| edges), and a direction switch must not retrace.

    The column traverses the *symmetrized* bench graph — undirected BFS
    is the canonical direction-optimized setting (arXiv 1503.04359):
    every visited vertex is a reachable parent through its in-edges, so
    the bottom-up scans early-exit instead of paying full rows for a
    permanently-unreachable tail."""
    import time

    from repro.algorithms.bfs import bfs_batched, bfs_reference
    from repro.algorithms.cc import symmetrize

    g = symmetrize(g)
    pg = PT.partition(g, parts, strategy)
    kw = {}
    if backend == "fused":
        kw = dict(fused=True, block_e=block_e)
    elif backend == "hybrid":
        kw = dict(backend="hybrid")
    top = BSPEngine(pg, direction="push", **kw)
    dopt = BSPEngine(pg, direction="auto", **kw)

    rng = np.random.default_rng(seed)
    sources = rng.integers(0, pg.num_vertices, size=q)

    def wall(fn, iters=3):
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return sorted(times)[len(times) // 2]

    lv_top, _ = bfs_batched(top, sources)          # compile the push loop
    st_top = top.last_direction_stats
    lv_dopt, _ = bfs_batched(dopt, sources)        # compile the auto loop
    st = dopt.last_direction_stats
    cache_fn = BSPEngine._run_batched
    entries0 = cache_fn._cache_size()
    # Different sources, same Q: switch points move between supersteps and
    # queries, but direction is traced-carry data — no retrace allowed.
    bfs_batched(dopt, rng.integers(0, pg.num_vertices, size=q))
    retraces = cache_fn._cache_size() - entries0

    oracle = np.stack([bfs_reference(g, int(s)) for s in sources])
    bitwise = int(np.array_equal(np.asarray(lv_top), oracle)
                  and np.array_equal(np.asarray(lv_dopt), oracle))

    topdown_ms = wall(lambda: bfs_batched(top, sources)) * 1e3
    dopt_ms = wall(lambda: bfs_batched(dopt, sources)) * 1e3

    topdown_edges = int(np.asarray(st_top["edges_examined"]).sum())
    dopt_edges = int(np.asarray(st["edges_examined"]).sum())
    return dict(
        scale=scale, parts=parts, strategy=strategy, algorithm="bfs",
        combine="min", mode="dopt", q=q, block_e=block_e, backend=backend,
        num_edges=g.num_edges,
        topdown_ms=topdown_ms, dopt_ms=dopt_ms,
        topdown_edges=topdown_edges, dopt_edges=dopt_edges,
        # once-per-edge push bound: Q queries scan at most Q·|E| edges
        edges_bound=q * g.num_edges,
        edges_saved_ratio=1.0 - dopt_edges / max(topdown_edges, 1),
        dopt_switches=int(np.asarray(st["switches"]).sum()),
        topdown_switches=int(np.asarray(st_top["switches"]).sum()),
        retraces=retraces,
        bitwise=bitwise)


def bench_mutations_cell(g, scale: int, parts: int, strategy: str,
                         seed: int, backend: str = "reference",
                         block_e: int = 256, rounds: int = 4,
                         mutation_batch: int = 256) -> dict:
    """One dynamic-graph cell: in-place mutation throughput + incremental
    warm-start economics on a resident DynamicGraph.

    Applies ``rounds`` insert-only mutation batches (insert-only keeps the
    window monotone so the warm-vs-cold comparison is apples-to-apples),
    recording edges/s applied through the compiled scatter, the warm-start
    vs cold superstep counts for a standing BFS query set, and the dynamic
    runner's compile-cache growth across batches (``retraces`` — 0 is the
    contract, gated deterministically by scripts/bench_check.py alongside
    ``incremental_steps``/``cold_steps``).
    """
    from repro.core import bsp
    from repro.core.dynamic import DynamicGraph
    from repro.data.graphs import edge_stream
    from repro.algorithms.bfs import bfs_batched, bfs_incremental

    dg = DynamicGraph(g, parts, strategy,
                      mutation_capacity=mutation_batch)
    if backend == "fused":
        eng = BSPEngine(dg, fused=True, block_e=block_e)
    elif backend == "hybrid":
        eng = BSPEngine(dg, backend="hybrid")
    else:
        eng = BSPEngine(dg)
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, g.num_vertices, size=8)
    prev, _ = bfs_batched(eng, sources)            # compile + first fixpoint
    stream = edge_stream(g, rounds + 1, mutation_batch, churn=1.0,
                         seed=seed)

    # warm-up round: compiles the incremental (relaxation) program too, so
    # the retrace counter below sees only genuine re-traces
    mark = dg.mark()
    dg.apply_mutations(stream[0])
    dirty, _ = dg.dirty_since(mark)
    prev, _ = bfs_incremental(eng, prev, dirty)
    prev, _ = bfs_batched(eng, sources)

    entries0 = bsp._run_dyn_jit._cache_size() + \
        bsp._run_dyn_hybrid_jit._cache_size()
    edges = apply_s = 0.0
    warm_steps = cold_steps = 0
    bitwise = True
    mark = dg.mark()
    for mb in stream[1:]:
        rep = dg.apply_mutations(mb)
        edges += rep["num_edges"]
        apply_s += rep["apply_ms"] / 1e3
        dirty, monotone = dg.dirty_since(mark)
        assert monotone                            # churn=1.0 stream
        warm, wsteps = bfs_incremental(eng, prev, dirty)
        cold, csteps = bfs_batched(eng, sources)
        bitwise = bitwise and bool(np.array_equal(warm, cold))
        warm_steps += int(wsteps.max())
        cold_steps += int(csteps.max())
        prev = cold
        mark = dg.mark()
    retraces = (bsp._run_dyn_jit._cache_size()
                + bsp._run_dyn_hybrid_jit._cache_size() - entries0)
    return dict(
        scale=scale, parts=parts, strategy=strategy, algorithm="bfs",
        combine="min", mode="mutations", block_e=block_e, backend=backend,
        v_max=dg.pg.v_max, delta_slots=dg.delta_slots,
        mutation_rounds=rounds, mutation_batch=mutation_batch,
        mutation_edges=int(edges),
        mutation_edges_per_sec=edges / max(apply_s, 1e-12),
        apply_ms_per_batch=apply_s * 1e3 / max(rounds, 1),
        incremental_steps=warm_steps, cold_steps=cold_steps,
        warm_bitwise_equal=bitwise,
        compactions=dg.compactions,
        hybrid_rebuilds=eng.hybrid_dyn_rebuilds, retraces=retraces)


def bench_checkpoint_cell(pg, scale: int, parts: int, strategy: str,
                          seed: int, chunk: int = 2, q: int = 8) -> dict:
    """One fault-tolerance cell: snapshot overhead + recovery time of the
    checkpointable chunked run mode (docs/robustness.md).

    Runs a Q-query BFS batch three ways on the same engine: the resident
    while_loop (the reference result), the chunked mode bare, and the
    chunked mode with a blocking ``save_tree`` snapshot at every chunk
    boundary + the quarantine scan.  Records the per-superstep snapshot
    overhead, the recovery time (restore the *first* snapshot and resume
    to the fixpoint), and the deterministic halves gated by
    scripts/bench_check.py: ``resume_bitwise`` (the resumed fixpoint
    equals the resident loop's bitwise), ``chunk_retraces`` (chunked
    windows reuse one compile), and ``quarantined`` (0 on the clean path).
    """
    import tempfile
    import time

    from repro.checkpoint import CheckpointManager
    from repro.runtime import QuarantinePolicy

    eng = BSPEngine(pg)
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, pg.num_vertices, size=(q, 1))
    from repro.algorithms.bfs import multi_source_state
    state0 = {"level": jnp.asarray(multi_source_state(pg, sources))}
    ref_state, ref_steps = eng.execute(BFS_PROGRAM, dict(state0))

    def wall(fn, iters=3):
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return sorted(times)[len(times) // 2]

    # warm the chunked windows, then hold the compile-cache baseline
    eng.execute(BFS_PROGRAM, dict(state0), chunk=chunk)
    entries0 = BSPEngine._run_chunk._cache_size()
    bare_s = wall(lambda: eng.execute(
        BFS_PROGRAM, dict(state0), chunk=chunk))

    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=4096)   # keep every snapshot
        quar = QuarantinePolicy(superstep_budget=int(pg.num_vertices))
        quar.begin(q)
        ckpt_ms = []

        def on_chunk(snap):
            t0 = time.perf_counter()
            mgr.save_tree(snap["step"],
                          {"state": snap["state"], "fin": snap["fin"],
                           "steps_q": snap["steps_q"]}, blocking=True)
            ckpt_ms.append((time.perf_counter() - t0) * 1e3)
            return quar.scan(snap)

        t0 = time.perf_counter()
        st, sq, info = eng.execute(
            BFS_PROGRAM, dict(state0), chunk=chunk,
            on_chunk=on_chunk)
        ckpt_run_s = time.perf_counter() - t0

        # recovery: restore the FIRST snapshot, resume to the fixpoint
        like = {"state": {"level": np.zeros_like(np.asarray(st["level"]))},
                "fin": np.zeros(q, bool), "steps_q": np.zeros(q, np.int32)}
        t0 = time.perf_counter()
        step, tree = mgr.restore_tree(like, chunk)
        final, fsq, _ = eng.execute(
            BFS_PROGRAM, tree["state"], chunk=chunk,
            start_step=step, fin=tree["fin"], steps_q=tree["steps_q"])
        recovery_s = time.perf_counter() - t0

    resume_bitwise = bool(
        np.array_equal(np.asarray(final["level"]),
                       np.asarray(ref_state["level"]))
        and np.array_equal(np.asarray(fsq), np.asarray(ref_steps))
        and np.array_equal(np.asarray(st["level"]),
                           np.asarray(ref_state["level"])))
    supersteps = max(info["final_step"], 1)
    return dict(
        scale=scale, parts=parts, strategy=strategy, algorithm="bfs",
        combine="min", mode="checkpoint", block_e=None, q=q,
        checkpoint_every=chunk, v_max=pg.v_max,
        supersteps=info["final_step"], chunks=info["chunks"],
        chunked_ms=bare_s * 1e3,
        chunked_ckpt_ms=ckpt_run_s * 1e3,
        ckpt_ms_per_superstep=sum(ckpt_ms) / supersteps,
        ckpt_overhead_ratio=(ckpt_run_s / max(bare_s, 1e-12)),
        recovery_ms=recovery_s * 1e3,
        snapshots=len(ckpt_ms),
        resume_bitwise=int(resume_bitwise),
        quarantined=len(quar.quarantined),
        chunk_retraces=BSPEngine._run_chunk._cache_size() - entries0)


def bench_verify_cell(g, pg, scale: int, parts: int, strategy: str,
                      seed: int, chunk: int = 2, q: int = 8) -> dict:
    """One integrity cell: what the silent-corruption defense costs
    (docs/robustness.md, "Silent faults").

    Runs a Q-query BFS batch through the chunked mode bare, then with the
    in-loop invariant monitor armed, and finally certifies every harvested
    fixpoint with the O(V+E) result certifier.  The monitor cost is
    measured *inside* ``observe`` (pure host NumPy at window boundaries)
    and the certifier cost as the wall time of ``certify_batch`` — both
    are the actual added work, not a noisy whole-run diff.  Deterministic
    halves gated by scripts/bench_check.py: ``certified_ok == q`` (a clean
    fixpoint always certifies) and ``monitors_fired == 0`` (no false
    positives); the timing half gates ``verify_overhead_ratio`` — the
    ISSUE contract is <= 0.10 of the bare chunked run.
    """
    import time

    from repro.algorithms.bfs import gather_batch, multi_source_state
    from repro.runtime import ResultCertifier, monitor_for

    eng = BSPEngine(pg)
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, pg.num_vertices, size=(q, 1))
    state0 = {"level": jnp.asarray(multi_source_state(pg, sources))}

    def wall(fn, iters=3):
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return sorted(times)[len(times) // 2]

    eng.execute(BFS_PROGRAM, dict(state0), chunk=chunk)  # warm the windows
    bare_s = wall(lambda: eng.execute(
        BFS_PROGRAM, dict(state0), chunk=chunk))

    mon = monitor_for("bfs", chunk=chunk)
    mon_s = [0.0]
    observe = mon.observe

    def timed_observe(snap):
        t0 = time.perf_counter()
        rec = observe(snap)
        mon_s[0] += time.perf_counter() - t0
        return rec

    mon.observe = timed_observe
    st, _, info = eng.execute(
        BFS_PROGRAM, dict(state0), chunk=chunk, monitor=mon)

    certifier = ResultCertifier("bfs", g)
    levels = gather_batch(pg, st["level"])
    t0 = time.perf_counter()
    verdicts = certifier.certify_batch(levels,
                                       sources=sources.reshape(-1))
    certify_s = time.perf_counter() - t0

    return dict(
        scale=scale, parts=parts, strategy=strategy, algorithm="bfs",
        combine="min", mode="verify", block_e=None, q=q,
        checkpoint_every=chunk, v_max=pg.v_max,
        supersteps=info["final_step"], chunks=info["chunks"],
        chunked_ms=bare_s * 1e3,
        monitor_ms=mon_s[0] * 1e3,
        certify_ms=certify_s * 1e3,
        certify_ms_per_query=certify_s * 1e3 / q,
        verify_overhead_ratio=(mon_s[0] + certify_s) / max(bare_s, 1e-12),
        monitors_fired=info["monitors_fired"],
        certified_ok=sum(1 for v in verdicts if v.ok),
        certify_failed=[v.reason() for v in verdicts if not v.ok])


def bench_continuous_cell(pg, scale: int, parts: int, strategy: str,
                          seed: int, chunk: int = 2, q: int = 8,
                          stream_factor: int = 8) -> dict:
    """One continuous-batching cell: q/s and p99-under-load of a resident
    ``ServeSession`` (slot refill at chunk boundaries) vs fixed-batch
    drain at the same Q, over a ``stream_factor``x-Q stream submitted up
    front.

    Timing is CPU-noisy; the deterministic halves are gated instead
    (refill decisions depend only on superstep-indexed convergence, so
    they are reproducible for a fixed seed): ``bitwise`` (every
    completion equals its drain-batch row), ``retraces`` (0 after
    warmup), ``refills`` (== stream - Q: every extra query rode a freed
    slot) and ``min_slot_refills``.
    """
    import time

    from repro.runtime import ServeSession, drain_reference

    eng = BSPEngine(pg)
    rng = np.random.default_rng(seed)
    stream = rng.integers(0, pg.num_vertices, size=stream_factor * q)

    # warm every compile outside the timed runs: one throwaway session
    # (chunk jit + slot swap + one refill cycle) and one drain batch
    ws = ServeSession(eng, "bfs", slots=q, chunk=chunk)
    ws.submit(np.resize(stream, 2 * q))
    ws.drain()
    drain_reference(eng, "bfs", stream[:q], q)

    # fixed-batch drain baseline: a query's latency is its batch's
    # completion time (batch-synchronous serving)
    drain_lat = []
    want = []
    t0 = time.perf_counter()
    for i in range(0, len(stream), q):
        want.append(drain_reference(eng, "bfs", stream[i:i + q], q))
        done_ms = (time.perf_counter() - t0) * 1e3
        drain_lat.extend([done_ms] * q)
    drain_wall = time.perf_counter() - t0
    want = np.concatenate(want, axis=0)

    session = ServeSession(eng, "bfs", slots=q, chunk=chunk)
    qids = session.submit(stream)
    t0 = time.perf_counter()
    rep = session.drain()
    cont_wall = time.perf_counter() - t0
    results = {r["query"]: r["result"] for r in session.poll()}
    bitwise = int(
        len(results) == len(stream)
        and all(np.array_equal(results[qid], row)
                for qid, row in zip(qids, want)))
    cont_lat = sorted(session._latency_ms.values())

    def pct(vals, p):
        return float(np.percentile(vals, p, method="nearest"))

    return dict(
        scale=scale, parts=parts, strategy=strategy, algorithm="bfs",
        combine="min", mode="continuous", block_e=None, q=q,
        stream=len(stream), chunk=chunk, v_max=pg.v_max,
        windows=rep["windows"], supersteps=rep["final_step"],
        drain_qps=len(stream) / drain_wall,
        drain_p50_ms=pct(drain_lat, 50), drain_p99_ms=pct(drain_lat, 99),
        continuous_qps=len(stream) / cont_wall,
        continuous_p50_ms=pct(cont_lat, 50),
        continuous_p99_ms=pct(cont_lat, 99),
        refills=rep["refills"],
        min_slot_refills=rep["min_slot_refills"],
        max_slot_refills=rep["max_slot_refills"],
        retraces=rep["retraces"], bitwise=bitwise)


def bench_oocore_cell(pg, scale: int, parts: int, strategy: str, seed: int,
                      block_e: int, win_blocks: int = 8,
                      backend: str = "fused", iters: int = 10) -> dict:
    """One out-of-core cell: the tiered engine (cold partitions host-resident,
    streamed through the superstep in double-buffered windows) vs the
    all-resident engine on the same partitioned graph.

    The HBM budget is *probed*: a throwaway plan with an unbounded budget
    yields the per-split byte table, and the cell pins the budget to the
    ``parts//2``-hot row — half the partitions are forced host-tier, so the
    cell always streams.  Deterministic halves gated by
    scripts/bench_check.py and asserted here: the streamed fixpoint is
    bitwise identical to the resident one for a sum-combine program
    (PageRank — the FMA/layout-sensitive case) and a min-combine one (BFS),
    arena HBM stays under the budget, and repeat runs add zero
    compile-cache entries (``retraces``).  The recorded byte fields
    (``hbm_resident_bytes``, ``host_bytes``, ``streamed_bytes_per_superstep``,
    ``window_count``) are plan-deterministic for a pinned seed.
    """
    import time

    from repro.core.partition import build_tier_plan
    from repro.algorithms.bfs import bfs_batched
    from repro.algorithms.pagerank import pagerank

    if backend == "fused":
        bkw = dict(fused=True, block_e=block_e)
    elif backend == "hybrid":
        bkw = dict(backend="hybrid", block_e=block_e)
    else:
        bkw = dict(block_e=block_e)
    probe = build_tier_plan(pg, 1 << 60, block_e=block_e,
                            win_blocks=win_blocks,
                            fused=backend != "reference")
    budget = int(probe.table[parts // 2]["hbm_bytes"])
    res_eng = BSPEngine(pg, **bkw)
    tier_eng = BSPEngine(pg, tiered=budget, win_blocks=win_blocks, **bkw)
    stats = tier_eng.tiered_stats()

    rng = np.random.default_rng(seed)
    sources = rng.integers(0, pg.num_vertices, size=4)
    ranks_res = pagerank(res_eng, iters)
    ranks_tier = pagerank(tier_eng, iters)
    lv_res, st_res = bfs_batched(res_eng, sources)
    lv_tier, st_tier = bfs_batched(tier_eng, sources)
    bitwise = bool(np.array_equal(ranks_res, ranks_tier)
                   and np.array_equal(lv_res, lv_tier)
                   and np.array_equal(st_res, st_tier))

    # warm runs above compiled every window; repeats must add no entries
    entries0 = tier_eng.tiered_cache_entries()
    pagerank(tier_eng, iters)
    bfs_batched(tier_eng, rng.integers(0, pg.num_vertices, size=4))
    retraces = tier_eng.tiered_cache_entries() - entries0

    def wall(fn, iters_=3):
        times = []
        for _ in range(iters_):
            t0 = time.perf_counter()
            fn()
            times.append(time.perf_counter() - t0)
        return sorted(times)[len(times) // 2]

    resident_s = wall(lambda: pagerank(res_eng, iters))
    tiered_s = wall(lambda: pagerank(tier_eng, iters))

    residency = tier_eng.residency_bytes()
    return dict(
        scale=scale, parts=parts, strategy=strategy, algorithm="pagerank",
        combine="sum", mode="oocore", block_e=block_e, backend=backend,
        win_blocks=win_blocks, v_max=pg.v_max,
        hbm_budget=budget, bitwise=int(bitwise), retraces=int(retraces),
        resident_ms=resident_s * 1e3, tiered_ms=tiered_s * 1e3,
        stream_penalty=tiered_s / max(resident_s, 1e-12),
        residency_hbm_bytes=int(residency["hbm_bytes"]),
        residency_host_bytes=int(residency["host_bytes"]),
        **stats)


def bench_distributed_cell(pg, scale: int, parts: int, strategy: str,
                           alg: str, n_dev: int) -> dict:
    """One multi-device cell: sharded fused vs sharded hybrid superstep,
    plus the per-superstep wire accounting (paper §3.4 aggregation-β)."""
    from repro.core.bsp import DistributedBSPEngine

    mesh = jax.make_mesh((n_dev,), ("parts",))
    fus = DistributedBSPEngine(pg, mesh, fused=True)
    hyb = DistributedBSPEngine(pg, mesh, backend="hybrid")
    program, state = _program_and_state(pg, parts, alg)

    shd, _ = hyb._hybrid_dist_for(program)
    # Independent wire accounting straight from the partition outbox maps
    # (not the engine's own counters): cross-device used slots × 4B.
    pl = parts // n_dev
    om = pg.fwd.outbox_mask
    cross_slots = int(om.sum() - sum(
        int(om[s * pl:(s + 1) * pl, s * pl:(s + 1) * pl].sum())
        for s in range(n_dev)))
    plan = hyb.hybrid_plan()
    e4 = pg.num_edges * 4.0
    rec = dict(
        scale=scale, parts=parts, strategy=strategy, algorithm=alg,
        combine=program.combine, mode="distributed", devices=n_dev,
        block_e=None, v_max=pg.v_max, o_max=pg.fwd.o_max,
        beta=pg.beta_with_reduction,
        # wire traffic per superstep, totalled over shards:
        # fused/reference exchange ships the full [pl, P, o_max] tensor;
        # the hybrid exchange ships only the used cross-device slot blocks
        # (exchanged_bytes = aggregated payload, outbox slots × 4B;
        # exchange_buffer_bytes = the shard-uniform padded SPMD buffer).
        full_exchange_bytes=int(parts * parts * pg.fwd.o_max * 4),
        exchanged_bytes=int(shd.wire_slots_used * 4),
        cross_slots_bytes=int(cross_slots * 4),
        exchange_buffer_bytes=int(n_dev * shd.wire_values_per_superstep()
                                  * 4),
        beta_slots_bytes=pg.beta_with_reduction * e4,
        beta_edges_bytes=pg.beta_no_reduction * e4,
        hybrid_k_per_shard=[r["k_dense"] for r in plan["per_shard"]],
        predicted_makespan=plan["makespan"],
        predicted_t_comm=max(r["t_comm"] for r in plan["per_shard"]),
    )
    step0 = jnp.int32(0)
    for name, eng in (("dist_fused", fus), ("dist_hybrid", hyb)):
        fn = eng.superstep(program)
        rec[f"{name}_ms"] = timeit(fn, state, step0, warmup=1, iters=5) * 1e3
    rec["dist_speedup"] = rec["dist_fused_ms"] / max(rec["dist_hybrid_ms"],
                                                     1e-12)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scales", type=int, nargs="+", default=[10, 11])
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--edge-factor", type=int, default=8)
    # 256 keeps [block_e, span] inside the VMEM budget (ops.fused_span_limit)
    # for the spans these scales produce, so every cell measures the kernel.
    ap.add_argument("--block-e", type=int, default=256)
    ap.add_argument("--out", default=str(
        Path(__file__).resolve().parents[1] / "BENCH_superstep.json"))
    ap.add_argument("--no-assert", action="store_true",
                    help="record HLO counts without failing on violations")
    ap.add_argument("--quick", action="store_true",
                    help="smallest scale only (keeps the CI job under ~5min)")
    ap.add_argument("--hybrid", action="store_true",
                    help="also time the hybrid degree-split backend")
    ap.add_argument("--batched", action="store_true",
                    help="add the query-throughput column: batched BFS at "
                         "Q in {1,8,32} vs Q sequential runs, with "
                         "amortization + retrace assertions")
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=[1, 8, 32],
                    help="Q values for --batched")
    ap.add_argument("--batched-backend", default="reference",
                    choices=("reference", "fused", "hybrid"),
                    help="engine backend for the --batched column")
    ap.add_argument("--dopt", action="store_true",
                    help="add the direction-optimized traversal column "
                         "(top-down vs auto BFS, deterministic "
                         "edges-examined counters + bitwise/retrace guards)")
    ap.add_argument("--dopt-backend", default="reference",
                    choices=["reference", "fused", "hybrid"],
                    help="engine backend for the --dopt column")
    ap.add_argument("--mutations", action="store_true",
                    help="add the dynamic-graph column: in-place mutation "
                         "edges/s, incremental-vs-cold supersteps, and the "
                         "zero-retrace guard on a resident DynamicGraph")
    ap.add_argument("--mutations-backend", default="reference",
                    choices=("reference", "fused", "hybrid"),
                    help="engine backend for the --mutations column")
    ap.add_argument("--checkpoint", action="store_true",
                    help="add the fault-tolerance column: per-superstep "
                         "snapshot overhead + recovery time of the chunked "
                         "run mode, with the bitwise-resume and clean-path "
                         "zero-quarantine guards")
    ap.add_argument("--checkpoint-every", type=int, default=2,
                    help="supersteps per chunk for --checkpoint")
    ap.add_argument("--verify", action="store_true",
                    help="add the integrity column: in-loop invariant "
                         "monitor + result-certifier overhead on the "
                         "chunked run mode, with the clean-certification, "
                         "zero-monitor-fire, and <=10%% overhead guards")
    ap.add_argument("--continuous", action="store_true",
                    help="add the continuous-batching column: resident-"
                         "session q/s and p99-under-load vs fixed-batch "
                         "drain at the same Q, with the bitwise-parity, "
                         "zero-retrace and refill-count guards")
    ap.add_argument("--oocore", action="store_true",
                    help="add the out-of-core column: tiered engine with a "
                         "probed HBM budget forcing half the partitions "
                         "host-tier vs the all-resident engine, with the "
                         "bitwise-parity, under-budget and zero-retrace "
                         "guards")
    ap.add_argument("--oocore-backend", default="fused",
                    choices=("reference", "fused", "hybrid"),
                    help="engine backend for the --oocore column")
    ap.add_argument("--win-blocks", type=int, default=8,
                    help="double-buffered window size (edge blocks) for the "
                         "--oocore column")
    ap.add_argument("--distributed", action="store_true",
                    help="add multi-device cells (sharded fused vs sharded "
                         "hybrid + exchanged-bytes accounting)")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count for --distributed")
    ap.add_argument("--seed", type=int, default=1,
                    help="RMAT topology seed (pinned for reproducible cells)")
    args = ap.parse_args(argv)
    if args.quick:
        args.scales = [min(args.scales)]

    if args.distributed and len(jax.devices()) < args.devices:
        # Re-exec with the forced host device count (it must be set before
        # the jax runtime initializes, so a fresh subprocess is the only
        # reliable way from an already-imported process).  The sentinel env
        # var prevents unbounded recursion when the flag cannot take effect
        # (e.g. a GPU/TPU backend ignores forced *host* devices).
        import os
        import subprocess
        if os.environ.get("_SUPERSTEP_BENCH_REEXEC"):
            print(f"--distributed needs >= {args.devices} devices but the "
                  f"re-exec still sees {len(jax.devices())} "
                  f"({jax.default_backend()} backend); forced host devices "
                  f"only apply to CPU — run with fewer --devices or on CPU",
                  file=sys.stderr)
            return 2
        env = dict(
            os.environ,
            _SUPERSTEP_BENCH_REEXEC="1",
            XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                       f" --xla_force_host_platform_device_count="
                       f"{args.devices}").strip())
        r = subprocess.run([sys.executable, __file__]
                           + list(argv if argv is not None else sys.argv[1:]),
                           env=env)
        return r.returncode

    results = []
    failures = []
    for scale in args.scales:
        g = G.rmat(scale, args.edge_factor, seed=args.seed)
        # distributed cells need num_parts % devices == 0
        parts_dist = (args.parts if args.parts % args.devices == 0
                      else args.devices)
        for strategy in PT.STRATEGIES:
            pg = PT.partition(g, args.parts, strategy)
            pg_dist = None
            if args.distributed:
                pg_dist = (pg if parts_dist == args.parts
                           else PT.partition(g, parts_dist, strategy))
            for alg in ("pagerank", "bfs"):
                rec = bench_cell(pg, scale, args.parts, strategy, alg,
                                 args.block_e, hybrid=args.hybrid)
                results.append(rec)
                if args.distributed:
                    drec = bench_distributed_cell(pg_dist, scale, parts_dist,
                                                  strategy, alg, args.devices)
                    results.append(drec)
                    print(f"scale={scale} {strategy:>4} {alg:>8} "
                          f"[{args.devices}dev]: "
                          f"fused={drec['dist_fused_ms']:.2f}ms "
                          f"hybrid={drec['dist_hybrid_ms']:.2f}ms "
                          f"wire={drec['exchanged_bytes']}B "
                          f"(buf={drec['exchange_buffer_bytes']}B, "
                          f"full={drec['full_exchange_bytes']}B, "
                          f"β·E·4={drec['beta_slots_bytes']:.0f}B) "
                          f"k={drec['hybrid_k_per_shard']}", flush=True)
                    # §3.4 claim: the aggregated exchange payload must stay
                    # within β_with_reduction·|E|·4 — wire traffic scales
                    # with unique boundary pairs, not per-edge messages.
                    # The falsifiable half: the engine's own slot counter
                    # must match the cross-device slot count derived
                    # independently from the partition outbox maps — an
                    # engine regression that shipped per-edge values (or
                    # dropped slots) breaks the equality.
                    if drec["exchanged_bytes"] != drec["cross_slots_bytes"]:
                        failures.append(
                            f"exchange payload ({drec['exchanged_bytes']}B) "
                            f"!= cross-device outbox slots × 4B "
                            f"({drec['cross_slots_bytes']}B) in "
                            f"{strategy}/{alg} — source-side aggregation "
                            f"is no longer slot-exact")
                    if drec["exchanged_bytes"] > drec["beta_slots_bytes"]:
                        failures.append(
                            f"exchange payload ({drec['exchanged_bytes']}B) "
                            f"exceeds the aggregation bound "
                            f"(beta_wr*E*4={drec['beta_slots_bytes']:.0f}B) "
                            f"in {strategy}/{alg}")
                    if (drec["exchange_buffer_bytes"]
                            >= drec["full_exchange_bytes"]):
                        failures.append(
                            f"compact exchange buffer not smaller than the "
                            f"full outbox tensor in {strategy}/{alg}: {drec}")
                print(f"scale={scale} {strategy:>4} {alg:>8}: "
                      f"ref={rec['ref_ms']:.2f}ms fused={rec['fused_ms']:.2f}ms "
                      f"({rec['speedup']:.2f}x) span={rec['span']} "
                      f"active={rec['fused_active']} "
                      f"msg_arrays ref={rec['ref_hlo_msg_arrays']} "
                      f"fused={rec['fused_hlo_msg_arrays']}", flush=True)
                # Structural claim: when the kernel is active it never
                # materializes the message array; the reference always does
                # (it's the array being eliminated).
                if rec["fused_active"] and rec["fused_hlo_msg_arrays"] != 0:
                    failures.append(f"fused HLO materializes [Pl, e_max] f32 "
                                    f"arrays in {rec}")
                if rec["ref_hlo_msg_arrays"] == 0:
                    failures.append(f"reference HLO unexpectedly clean "
                                    f"(check the detector) in {rec}")
            if args.dopt:
                drec = bench_dopt_cell(g, pg, scale, args.parts, strategy,
                                       args.seed, backend=args.dopt_backend,
                                       block_e=args.block_e)
                results.append(drec)
                print(f"scale={scale} {strategy:>4} dopt"
                      f"[{drec['backend']}]: topdown "
                      f"{drec['topdown_ms']:.1f}ms/"
                      f"{drec['topdown_edges']}e vs dopt "
                      f"{drec['dopt_ms']:.1f}ms/{drec['dopt_edges']}e "
                      f"(saved {drec['edges_saved_ratio']:.1%}, "
                      f"switches={drec['dopt_switches']}, "
                      f"retraces={drec['retraces']}, "
                      f"bitwise={drec['bitwise']})", flush=True)
                # Direction-optimization contract, all halves deterministic
                # (the counters are superstep-indexed int32 sums — no
                # timing noise): auto must beat top-down on examined edges
                # via at least one real switch, stay bitwise-identical to
                # the numpy oracle, respect the once-per-edge push bound,
                # and never retrace across a switch.
                if not drec["bitwise"]:
                    failures.append(
                        f"dopt {strategy}: push/auto BFS diverged from the "
                        f"reference fixpoint — direction is no longer a "
                        f"pure performance choice")
                if drec["retraces"] != 0:
                    failures.append(
                        f"dopt {strategy}: {drec['retraces']} compile-cache "
                        f"entries added across direction switches — "
                        f"direction is no longer traced-carry data")
                if drec["dopt_edges"] >= drec["topdown_edges"]:
                    failures.append(
                        f"dopt {strategy}: auto examined "
                        f"{drec['dopt_edges']} edges, not fewer than "
                        f"top-down's {drec['topdown_edges']} — the fitted "
                        f"crossover no longer wins on the scale-free graph")
                if drec["dopt_switches"] == 0:
                    failures.append(
                        f"dopt {strategy}: auto never left push on the "
                        f"scale-free graph (0 switches)")
                if drec["topdown_switches"] != 0:
                    failures.append(
                        f"dopt {strategy}: forced push reported "
                        f"{drec['topdown_switches']} switches")
                if drec["topdown_edges"] > drec["edges_bound"]:
                    failures.append(
                        f"dopt {strategy}: top-down examined "
                        f"{drec['topdown_edges']} edges, above the "
                        f"once-per-edge bound {drec['edges_bound']} — the "
                        f"push counter is over-charging")
            if args.mutations:
                mrec = bench_mutations_cell(g, scale, args.parts, strategy,
                                            args.seed,
                                            backend=args.mutations_backend,
                                            block_e=args.block_e)
                results.append(mrec)
                print(f"scale={scale} {strategy:>4} mutations: "
                      f"{mrec['mutation_edges_per_sec']:.0f} edges/s "
                      f"applied ({mrec['apply_ms_per_batch']:.1f} ms/batch "
                      f"of {mrec['mutation_batch']}), incremental "
                      f"{mrec['incremental_steps']} vs cold "
                      f"{mrec['cold_steps']} supersteps, "
                      f"retraces={mrec['retraces']} "
                      f"compactions={mrec['compactions']}", flush=True)
                # Dynamic contract, deterministic halves: mutation batches
                # must reuse the compiled loops (no compaction and no
                # spare-ELL-overflow split rebuild => no cache growth),
                # warm starts must be bitwise-exact and never run MORE
                # supersteps than cold recomputes.
                if (mrec["compactions"] == 0
                        and mrec["hybrid_rebuilds"] == 0
                        and mrec["retraces"] != 0):
                    failures.append(
                        f"mutations {strategy}: {mrec['retraces']} "
                        f"compile-cache entries added across mutation "
                        f"batches — the dynamic payload is no longer "
                        f"shape-stable")
                if not mrec["warm_bitwise_equal"]:
                    failures.append(
                        f"mutations {strategy}: warm-start BFS diverged "
                        f"from the cold rerun (monotone window)")
                if mrec["incremental_steps"] > mrec["cold_steps"]:
                    failures.append(
                        f"mutations {strategy}: incremental refresh ran "
                        f"{mrec['incremental_steps']} supersteps, more "
                        f"than cold {mrec['cold_steps']}")
            if args.oocore:
                orec = bench_oocore_cell(pg, scale, args.parts, strategy,
                                         args.seed, args.block_e,
                                         win_blocks=args.win_blocks,
                                         backend=args.oocore_backend)
                results.append(orec)
                print(f"scale={scale} {strategy:>4} oocore: "
                      f"hbm={orec['hbm_resident_bytes']}B "
                      f"(budget {orec['hbm_budget']}B) "
                      f"host={orec['host_bytes']}B, streams "
                      f"{orec['streamed_bytes_per_superstep']}B/superstep "
                      f"over {orec['window_count']} windows "
                      f"({orec['num_hot']} hot/{orec['num_cold']} cold); "
                      f"tiered {orec['tiered_ms']:.1f} vs resident "
                      f"{orec['resident_ms']:.1f} ms "
                      f"({orec['stream_penalty']:.2f}x), "
                      f"bitwise={orec['bitwise']} "
                      f"retraces={orec['retraces']}", flush=True)
                # Out-of-core contract, deterministic halves: the streamed
                # fixpoint is bitwise identical to the resident one, the
                # arena stays under the forced budget, the cell genuinely
                # streams (>= 1 host-tier partition), and steady-state
                # repeats add no compile-cache entries.
                if not orec["bitwise"]:
                    failures.append(
                        f"oocore {strategy}: streamed fixpoint diverged "
                        f"from the resident engine (PageRank/BFS bitwise)")
                if orec["hbm_resident_bytes"] > orec["hbm_budget"]:
                    failures.append(
                        f"oocore {strategy}: arena hbm "
                        f"{orec['hbm_resident_bytes']}B exceeds the "
                        f"budget {orec['hbm_budget']}B")
                if orec["num_cold"] < 1:
                    failures.append(
                        f"oocore {strategy}: no host-tier partitions — "
                        f"the cell never streamed")
                if orec["retraces"] != 0:
                    failures.append(
                        f"oocore {strategy}: {orec['retraces']} "
                        f"compile-cache entries added across repeat runs "
                        f"— the window schedule is no longer shape-stable")
            if args.checkpoint:
                crec = bench_checkpoint_cell(pg, scale, args.parts, strategy,
                                             args.seed,
                                             chunk=args.checkpoint_every)
                results.append(crec)
                print(f"scale={scale} {strategy:>4} checkpoint: "
                      f"{crec['ckpt_ms_per_superstep']:.2f} ms/superstep "
                      f"snapshot overhead ({crec['snapshots']} snapshots, "
                      f"{crec['ckpt_overhead_ratio']:.2f}x bare chunked), "
                      f"recovery {crec['recovery_ms']:.0f} ms, "
                      f"resume_bitwise={crec['resume_bitwise']} "
                      f"quarantined={crec['quarantined']} "
                      f"chunk_retraces={crec['chunk_retraces']}", flush=True)
                # Fault-tolerance contract, deterministic halves: the
                # resumed fixpoint is bitwise identical to the resident
                # loop, chunk windows reuse one compile, and nothing is
                # quarantined on a clean run.
                if not crec["resume_bitwise"]:
                    failures.append(
                        f"checkpoint {strategy}: resumed fixpoint is not "
                        f"bitwise identical to the resident while_loop")
                if crec["quarantined"] != 0:
                    failures.append(
                        f"checkpoint {strategy}: {crec['quarantined']} "
                        f"queries quarantined on the clean path")
                if crec["chunk_retraces"] != 0:
                    failures.append(
                        f"checkpoint {strategy}: chunked windows retraced "
                        f"{crec['chunk_retraces']}x after warmup")
            if args.verify:
                vrec = bench_verify_cell(g, pg, scale, args.parts, strategy,
                                         args.seed,
                                         chunk=args.checkpoint_every)
                results.append(vrec)
                print(f"scale={scale} {strategy:>4} verify: "
                      f"certify {vrec['certify_ms']:.2f} ms "
                      f"({vrec['certify_ms_per_query']:.2f} ms/query), "
                      f"monitor {vrec['monitor_ms']:.2f} ms, "
                      f"overhead {vrec['verify_overhead_ratio']:.3f}x "
                      f"bare chunked ({vrec['chunked_ms']:.2f} ms); "
                      f"certified {vrec['certified_ok']}/{vrec['q']} "
                      f"monitors_fired={vrec['monitors_fired']}", flush=True)
                # Integrity contract: clean fixpoints certify, monitors
                # never fire on a clean run, and the whole defense stays
                # within 10% of the bare chunked window.
                if vrec["certified_ok"] != vrec["q"]:
                    failures.append(
                        f"verify {strategy}: "
                        f"{vrec['q'] - vrec['certified_ok']} clean "
                        f"fixpoints failed certification "
                        f"({vrec['certify_failed']})")
                if vrec["monitors_fired"] != 0:
                    failures.append(
                        f"verify {strategy}: {vrec['monitors_fired']} "
                        f"invariant monitors fired on a clean run")
                if vrec["verify_overhead_ratio"] > 0.10:
                    failures.append(
                        f"verify {strategy}: monitor+certifier overhead "
                        f"{vrec['verify_overhead_ratio']:.3f}x exceeds the "
                        f"0.10x bare-chunked contract")
            if args.continuous:
                srec = bench_continuous_cell(pg, scale, args.parts, strategy,
                                             args.seed,
                                             chunk=args.checkpoint_every)
                results.append(srec)
                print(f"scale={scale} {strategy:>4} continuous: "
                      f"{srec['continuous_qps']:.0f} q/s vs drain "
                      f"{srec['drain_qps']:.0f} q/s; p99 "
                      f"{srec['continuous_p99_ms']:.0f} vs "
                      f"{srec['drain_p99_ms']:.0f} ms; "
                      f"refills={srec['refills']} "
                      f"(min/slot={srec['min_slot_refills']}), "
                      f"retraces={srec['retraces']} "
                      f"bitwise={srec['bitwise']}", flush=True)
                # Continuous-batching contract, deterministic halves
                # (refill decisions are superstep-indexed, so they are
                # reproducible; CPU timing is noisy and only recorded):
                # every completion bitwise equals drain-batch, every
                # extra query rode a freed slot, slots actually cycled,
                # and nothing retraced after warmup.
                if not srec["bitwise"]:
                    failures.append(
                        f"continuous {strategy}: completions diverge from "
                        f"drain-batch run_batched")
                if srec["retraces"] != 0:
                    failures.append(
                        f"continuous {strategy}: {srec['retraces']} "
                        f"compile-cache entries added across refill "
                        f"cycles — the slot swap is no longer "
                        f"shape-stable")
                if srec["refills"] != srec["stream"] - srec["q"]:
                    failures.append(
                        f"continuous {strategy}: {srec['refills']} refills "
                        f"for a {srec['stream']}-query stream over "
                        f"{srec['q']} slots — freed slots are not being "
                        f"refilled")
                if srec["min_slot_refills"] < 3:
                    failures.append(
                        f"continuous {strategy}: a slot was refilled only "
                        f"{srec['min_slot_refills']}x over a "
                        f"{srec['stream'] // srec['q']}x-Q stream — "
                        f"refill is not reaching every slot")
            if args.batched:
                for q in args.batch_sizes:
                    brec = bench_batched_cell(pg, scale, args.parts,
                                              strategy, q, args.block_e,
                                              args.seed,
                                              backend=args.batched_backend)
                    results.append(brec)
                    print(f"scale={scale} {strategy:>4} batched[Q={q:>2}]: "
                          f"{brec['batched_ms']:.1f}ms/batch "
                          f"{brec['batched_ms_per_query']:.2f}ms/q vs seq "
                          f"{brec['seq_ms_per_query']:.2f}ms/q "
                          f"(amortization {brec['amortization']:.2f}x, "
                          f"{brec['queries_per_sec']:.0f} q/s, "
                          f"retraces={brec['retraces']})", flush=True)
                    # Serving contract, deterministic half: same-Q batches
                    # with different sources share one compiled while_loop
                    # (the compile-cache-hit assertion; holds everywhere).
                    if brec["retraces"] != 0:
                        failures.append(
                            f"batched Q={q} {strategy} retraced the "
                            f"compiled loop {brec['retraces']}x — the "
                            f"query batch is no longer shape-stable")
                    # Throughput half: on a real accelerator one while_loop
                    # dispatch + one kernel-launch sequence replace Q of
                    # each, so Q >= 8 must amortize strictly below the
                    # sequential per-query time.  Interpret-mode CPU
                    # executes Q× Pallas grid cells in Python and scales
                    # compute linearly, inverting the ratio (see module
                    # docstring) — there the field is baseline-gated by
                    # bench_check instead of absolutely asserted.
                    if (jax.default_backend() == "tpu" and q >= 8
                            and brec["amortization"] <= 1.0):
                        failures.append(
                            f"batched Q={q} {strategy} amortized "
                            f"{brec['batched_ms_per_query']:.2f}ms/query, "
                            f"not below sequential "
                            f"{brec['seq_ms_per_query']:.2f}ms/query")

    out = dict(backend=jax.default_backend(),
               interpret=jax.default_backend() != "tpu",
               block_e=args.block_e, parts=args.parts,
               edge_factor=args.edge_factor, seed=args.seed,
               devices=(args.devices if args.distributed else 1),
               results=results)
    Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out} ({len(results)} cells)")
    if failures and not args.no_assert:
        for f in failures:
            print("FAIL:", f, file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
