"""Shared benchmark helpers: timing, CSV emission, workload cache."""
from __future__ import annotations

import functools
import time
from typing import Callable

import jax

ROWS = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (after warmup; blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = ""):
    """Print the scaffold's ``name,us_per_call,derived`` CSV row."""
    row = f"{name},{seconds*1e6:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@functools.lru_cache(maxsize=8)
def workload(scale: int, kind: str = "rmat", weighted: bool = False):
    from repro.core import graph as G
    g = (G.rmat if kind == "rmat" else G.uniform)(scale, 16, seed=1)
    return g.with_uniform_weights(seed=1) if weighted else g
