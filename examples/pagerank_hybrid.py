"""Hybrid two-engine PageRank — the paper's CPU/GPU split on TPU paths.

Splits a scale-free graph by degree: the dense high-degree block goes to the
MXU path (Pallas dense-block GEMM), the sparse remainder to the ELL/VPU path
(Pallas row-blocked gather kernel).  Shows the perf-model prediction and
validates against the numpy oracle.

  PYTHONPATH=src python examples/pagerank_hybrid.py
"""
import numpy as np

from repro.core import graph as G
from repro.core.hybrid import degree_split, hybrid_pagerank
from repro.core.perf_model import mxu_crossover_density
from repro.algorithms import pagerank_reference

g = G.rmat(scale=12, edge_factor=16, seed=3)
print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,}")
print(f"MXU crossover density: {mxu_crossover_density():.2e}")

for k_dense in (0, 256, 1024):
    hg = degree_split(g, k_dense)
    pred = hg.predicted_makespan(num_chips=1)
    ranks = hybrid_pagerank(hg, num_iterations=15)
    err = np.abs(ranks - pagerank_reference(g, 15)).max()
    print(f"K={k_dense:5d}: dense block holds {hg.dense_fraction:.1%} of "
          f"edges at density {hg.dense_density:.3f} | predicted makespan "
          f"{pred['makespan']*1e6:.2f}us (dense {pred['t_dense']*1e6:.2f} + "
          f"sparse {pred['t_sparse']*1e6:.2f}) | max err vs oracle {err:.2e}")
print("OK")
