"""Hybrid two-engine PageRank — the paper's CPU/GPU split on TPU paths.

Splits a scale-free graph by degree: the dense high-degree block goes to the
MXU path (Pallas dense-block GEMM), the sparse remainder to the ELL/VPU path
(Pallas row-blocked gather kernel).  Shows the perf-model prediction and
validates against the numpy oracle.

  PYTHONPATH=src python examples/pagerank_hybrid.py
"""
import numpy as np

from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.core.hybrid import auto_degree_split, degree_split, hybrid_pagerank
from repro.core.perf_model import mxu_crossover_density
from repro.algorithms import pagerank_reference
from repro.algorithms.pagerank import pagerank

g = G.rmat(scale=12, edge_factor=16, seed=3)
print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,}")
print(f"MXU crossover density: {mxu_crossover_density():.2e}")

for k_dense in (0, 256, 1024):
    hg = degree_split(g, k_dense)
    pred = hg.predicted_makespan(num_chips=1)
    ranks = hybrid_pagerank(hg, num_iterations=15)
    err = np.abs(ranks - pagerank_reference(g, 15)).max()
    print(f"K={k_dense:5d}: dense block holds {hg.dense_fraction:.1%} of "
          f"edges at density {hg.dense_density:.3f} | predicted makespan "
          f"{pred['makespan']*1e6:.2f}us (dense {pred['t_dense']*1e6:.2f} + "
          f"sparse {pred['t_sparse']*1e6:.2f}) | max err vs oracle {err:.2e}")

# The perf model picks the split itself (paper Eq. 4's role) ...
hg = auto_degree_split(g)
print(f"auto:  model chose K={hg.k_dense} ({hg.mode}) over "
      f"{[r['k_dense'] for r in hg.model_table]}")

# ... and the same split is a first-class BSPEngine backend, so every
# VertexProgram (not just PageRank) can run through it.
eng = BSPEngine(PT.partition(g, 4, PT.HIGH), backend="hybrid")
ranks = pagerank(eng, num_iterations=15)
err = np.abs(ranks - pagerank_reference(g, 15)).max()
plan = eng.hybrid_plan()
print(f"BSPEngine(backend='hybrid'): K={plan['k_dense']} ({plan['mode']}), "
      f"max err vs oracle {err:.2e}")
print("OK")
