"""End-to-end training driver example (deliverable b): trains a ~100M-param
model for a few hundred steps on CPU with checkpointing + watchdog + restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

This wraps the production driver (repro.launch.train); the model is the
xlstm-125m architecture at a width that lands near 100M params on CPU
budget.  On a real pod the same driver takes --production-mesh.
"""
import sys

sys.argv = [sys.argv[0], "--arch", "tinyllama-1.1b", "--smoke",
            "--steps", sys.argv[sys.argv.index("--steps") + 1]
            if "--steps" in sys.argv else "200",
            "--batch", "16", "--seq", "128", "--microbatches", "2",
            "--lr", "3e-3", "--ckpt-dir", "/tmp/repro_example_ckpt",
            "--ckpt-every", "50"]

from repro.launch.train import main  # noqa: E402

raise SystemExit(main())
