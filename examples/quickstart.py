"""Quickstart: the TOTEM engine end to end in ~40 lines.

Generates a scale-free RMAT graph, partitions it HIGH (the paper's winning
strategy: high-degree vertices on the bottleneck engine), runs all five
paper algorithms through the BSP engine, and checks one against its oracle.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.core.perf_model import speedup, PAPER_C
from repro.algorithms import (bfs, bfs_batched, bfs_reference, pagerank,
                              sssp, connected_components,
                              betweenness_centrality,
                              betweenness_centrality_batched)
from repro.algorithms.cc import symmetrize

# 1. A scale-free graph (paper Table 2 parameters, reduced scale).
g = G.rmat(scale=12, edge_factor=16, seed=7)
print(f"RMAT12: |V|={g.num_vertices:,} |E|={g.num_edges:,} "
      f"max_deg={g.out_degrees().max()}")

# 2. Partition by degree (paper §6.2): HIGH → partition 0.
pg = PT.partition(g, num_parts=2, strategy=PT.HIGH, cpu_edge_fraction=0.7,
                  include_reverse=True)
print(f"alpha={pg.alpha.round(2)}  beta: raw={pg.beta_no_reduction:.2%} "
      f"reduced={pg.beta_with_reduction:.2%}  (paper Fig. 4)")
print(f"model predicts {speedup(0.7, pg.beta_with_reduction, 1e9, PAPER_C):.2f}x "
      "hybrid speedup at alpha=0.7 (paper Eq. 4)")

# 3. Run the algorithms on the BSP engine.
engine = BSPEngine(pg)
src = int(np.argmax(g.out_degrees()))

levels, steps = bfs(engine, src)
assert np.array_equal(levels, bfs_reference(g, src))
print(f"BFS     : {np.isfinite(levels).sum():,} reached in {steps} supersteps ✓oracle")

ranks = pagerank(engine, num_iterations=20)
print(f"PageRank: top vertex {int(np.argmax(ranks))} rank={ranks.max():.2e}")

gw = g.with_uniform_weights(seed=1)
engw = BSPEngine(PT.partition(gw, 2, PT.HIGH))
dist, _ = sssp(engw, src)
print(f"SSSP    : mean finite distance {dist[np.isfinite(dist)].mean():.1f}")

engs = BSPEngine(PT.partition(symmetrize(g), 2, PT.HIGH))
labels, _ = connected_components(engs)
print(f"CC      : {len(np.unique(labels))} components")

bc, _ = betweenness_centrality(engine, src)
print(f"BC      : max centrality {bc.max():.1f}")

# 4. Batched queries (docs/serving.md): state grows a leading query axis, so
#    one resident graph + one compiled while_loop serve many sources at once.
hubs = np.argsort(-g.out_degrees())[:8]
levels8, steps8 = bfs_batched(engine, hubs)
assert np.array_equal(levels8[0], bfs(engine, int(hubs[0]))[0])
print(f"BFS x8  : one run, per-query supersteps {steps8.tolist()}")
bc8, _ = betweenness_centrality_batched(engine, hubs)
print(f"BC  x8  : batched contributions, max {bc8.max(axis=1).round(1)}")

# 5. Dynamic graphs (docs/dynamic.md): edge mutations apply in place (delta
#    slots + tombstones, shapes fixed, zero retraces), and monotone
#    algorithms warm-start from their previous fixpoints.
from repro.core.dynamic import DynamicGraph
from repro.core.graph import MutationBatch
from repro.algorithms import bfs_incremental

dg = DynamicGraph(g, num_parts=2, strategy=PT.HIGH, mutation_capacity=64)
dyn_engine = BSPEngine(dg)
prev, _ = bfs_batched(dyn_engine, hubs[:4])
mark = dg.mark()
rng = np.random.default_rng(0)
dg.apply_mutations(MutationBatch(rng.integers(0, g.num_vertices, 32),
                                 rng.integers(0, g.num_vertices, 32),
                                 np.ones(32, dtype=bool)))
dirty, monotone = dg.dirty_since(mark)
fresh, inc_steps = bfs_incremental(dyn_engine, prev, dirty)
cold, cold_steps = bfs_batched(dyn_engine, hubs[:4])
assert monotone and np.array_equal(fresh, cold)
print(f"Dynamic : 32 edges inserted in place; warm refresh "
      f"{inc_steps.max()} vs cold {cold_steps.max()} supersteps ✓bitwise")
print("OK")
