"""Node-failure drill: inject failures mid-training, verify the restart path
reproduces the failure-free trajectory bit-exactly (seekable data + atomic
checkpoints).

  PYTHONPATH=src python examples/fault_tolerance_drill.py
"""
import shutil

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.models import api
from repro.optim.adamw import AdamW
from repro.runtime import FaultInjector, run_with_restarts
from repro import configs

cfg = configs.get_smoke("tinyllama-1.1b")
model = api.build(cfg)
opt = AdamW(learning_rate=1e-3)
step_fn = jax.jit(api.make_train_step(model, opt, microbatches=1))
stream = TokenStream(cfg, batch=8, seq=64)


def make_runner(injector=None):
    def one_step(step, state):
        if injector:
            injector.maybe_fail(step)
        p, o, m = step_fn(state["params"], state["opt_state"],
                          stream.batch_at(step))
        return {"params": p, "opt_state": o}, {k: float(v)
                                               for k, v in m.items()}
    return one_step


def fresh_state():
    params = model.init(jax.random.key(0))
    return {"params": params, "opt_state": opt.init(params)}


for d in ("/tmp/ft_clean", "/tmp/ft_faulty"):
    shutil.rmtree(d, ignore_errors=True)

clean, _ = run_with_restarts(make_runner(), fresh_state(), 30,
                             CheckpointManager("/tmp/ft_clean"),
                             checkpoint_every=10)

injector = FaultInjector({12, 23})
faulty, summary = run_with_restarts(make_runner(injector), fresh_state(), 30,
                                    CheckpointManager("/tmp/ft_faulty"),
                                    checkpoint_every=10)
print(f"injected failures: {summary['failures']} at "
      f"{[r['step'] for r in summary['restarts']]}")

for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_leaves_with_path(clean["params"]),
        jax.tree_util.tree_leaves_with_path(faulty["params"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=str(pa))
print("restarted run is BIT-IDENTICAL to the failure-free run ✓")
