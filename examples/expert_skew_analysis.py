"""Expert-load skew ↔ vertex-degree skew: the paper's insight on MoE.

TOTEM's thesis: scale-free degree skew is an *opportunity* — partition by
the skew and give each side to the engine that handles it best (§6.2).  An
MoE layer routing Zipf-distributed tokens shows the same skew in expert
load; this script measures it and evaluates the TOTEM makespan model on the
resulting placement question (which experts should share a shard).

  PYTHONPATH=src python examples/expert_skew_analysis.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.perf_model import makespan
from repro.data import TokenStream
from repro.models import api
from repro.models.moe import expert_load_stats

cfg = configs.get_smoke("olmoe-1b-7b")
model = api.build(cfg)
params = model.init(jax.random.key(0))

# route one Zipf batch (data/tokens.py — vocabulary skew) through layer 0
stream = TokenStream(cfg, batch=32, seq=64)
tokens = stream.batch_at(0)["tokens"][:, :-1]
x = jnp.take(params["embed"], tokens, axis=0)
wg = params["layers"]["moe_wg"][0]
logits = x.reshape(-1, cfg.d_model) @ wg
stats = expert_load_stats(logits, cfg)
counts = np.asarray(stats["counts"])
order = np.argsort(-counts)
print(f"experts={cfg.moe_experts} top_k={cfg.moe_top_k} tokens={logits.shape[0]}")
print(f"expert load max/mean = {float(stats['max_over_mean']):.2f} "
      f"(uniform would be 1.0)")
print("hottest 5 experts carry "
      f"{counts[order[:5]].sum() / counts.sum():.1%} of the load")

# TOTEM makespan view (Eq. 2): expert placement across 2 shards.
# Load-oblivious placement can co-locate the hot experts (worst case);
# skew-aware LPT placement balances them — the HIGH-partitioning move.
half = cfg.moe_experts // 2
worst = [counts[order[:half]].sum(), counts[order[half:]].sum()]
greedy = [0.0, 0.0]
for c in counts[order]:                                     # LPT greedy
    greedy[int(np.argmin(greedy))] += c
rate = 1.0  # tokens/s per shard (relative)
m_worst = makespan(worst, [0, 0], [rate] * 2, 1)
m_lpt = makespan(greedy, [0, 0], [rate] * 2, 1)
print(f"makespan, hot experts co-located : {m_worst:.0f} token-units")
print(f"makespan, skew-aware (LPT)       : {m_lpt:.0f} token-units "
      f"→ {m_worst/m_lpt:.2f}x better")
print("(the moe_local dispatch in models/moe.py is the communication-side "
      "half of this story — see EXPERIMENTS.md §Perf cell 2)")
