# Convenience targets; PYTHONPATH=src is the repo's only install step.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test bench bench-check

test:            ## tier-1 suite
	$(PY) -m pytest -x -q

bench:           ## reference-vs-fused superstep timings -> BENCH_superstep.json
	$(PY) benchmarks/superstep_bench.py

# Optional CI gate: compare a fresh run against the previous baseline
# (first run seeds the baseline instead of failing).
bench-check: bench
	@if [ -f BENCH_superstep.prev.json ]; then \
	  $(PY) scripts/bench_check.py BENCH_superstep.json BENCH_superstep.prev.json; \
	else \
	  cp BENCH_superstep.json BENCH_superstep.prev.json; \
	  echo "bench_check: seeded baseline BENCH_superstep.prev.json"; \
	fi
