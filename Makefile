# Convenience targets; PYTHONPATH=src is the repo's only install step.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

BASELINE := BENCH_superstep.prev.json
# Interpret-mode CPU timings swing ±30%+ with machine load; the wide default
# catches step-function regressions without flaking on noise (tighten on
# real TPU runs: make bench-check BENCH_THRESHOLD=0.20).
BENCH_THRESHOLD ?= 0.75

.PHONY: test lint bench bench-quick bench-batched bench-dist bench-dynamic \
	bench-checkpoint bench-continuous bench-oocore bench-dopt bench-gate \
	bench-check serve serve-mutate serve-continuous serve-oocore chaos \
	corrupt-drill ci

test:            ## tier-1 suite
	$(PY) -m pytest -x -q

lint:            ## fast critical-rule lint (skips if ruff absent)
	@if command -v ruff >/dev/null 2>&1; then \
	  ruff check .; \
	else \
	  echo "lint: ruff not installed, skipping (pip install -r requirements-ci.txt)"; \
	fi

bench:           ## reference-vs-fused superstep timings -> BENCH_superstep.json
	$(PY) benchmarks/superstep_bench.py

bench-quick:     ## smallest scale only (the CI bench job; batched + dynamic + checkpoint + continuous + verify + oocore + dopt)
	$(PY) benchmarks/superstep_bench.py --quick --batched --mutations \
	  --checkpoint --continuous --verify --oocore --dopt

bench-batched:   ## query-throughput column only (Q in {1,8,32}) + gate
	$(PY) benchmarks/superstep_bench.py --quick --batched
	$(MAKE) bench-gate

bench-dynamic:   ## dynamic-graph column (mutation edges/s, warm-start) + gate
	$(PY) benchmarks/superstep_bench.py --quick --mutations
	$(MAKE) bench-gate

serve:           ## batched query-serving driver (resident graph, q/s report)
	$(PY) -m repro.launch.graph_serve --scale 12 --batch 32 --alg bfs

serve-mutate:    ## mutating serving driver (resident DynamicGraph)
	$(PY) -m repro.launch.graph_serve --scale 12 --batch 32 --alg bfs \
	  --mutate --churn 1.0

bench-checkpoint: ## fault-tolerance column (snapshot overhead, recovery) + gate
	$(PY) benchmarks/superstep_bench.py --quick --checkpoint
	$(MAKE) bench-gate

serve-continuous: ## continuous-batching serving driver (resident ServeSession)
	$(PY) -m repro.launch.graph_serve --scale 12 --batch 32 --alg bfs \
	  --continuous

bench-continuous: ## continuous-batching column (q/s + p99 vs drain) + gate
	$(PY) benchmarks/superstep_bench.py --quick --continuous
	$(MAKE) bench-gate

serve-oocore:    ## out-of-core serving driver (forced HBM budget, tiered engine)
	$(PY) -m repro.launch.graph_serve --smoke --graph uniform --alg bfs \
	  --backend fused --block-e 128 --win-blocks 4 --hbm-budget 45000

bench-oocore:    ## out-of-core column (tiered vs resident, parity + budget) + gate
	$(PY) benchmarks/superstep_bench.py --quick --oocore
	$(MAKE) bench-gate

bench-dopt:      ## direction-optimized column (top-down vs auto BFS edge counters) + gate
	$(PY) benchmarks/superstep_bench.py --quick --dopt
	$(MAKE) bench-gate

chaos:           ## fault-injection drill: crash/recover/replay, parity asserts
	$(PY) -m repro.launch.graph_serve --smoke --chaos --alg bfs \
	  --backend fused

corrupt-drill:   ## silent-corruption drill: every injection detected or masked
	$(PY) -m repro.launch.graph_serve --smoke --corrupt --alg bfs
	$(PY) -m repro.launch.graph_serve --smoke --corrupt --alg sssp

bench-dist:      ## multi-device column (8 forced host devices, quick scale)
	$(PY) benchmarks/superstep_bench.py --quick --distributed --devices 8 \
	  --out BENCH_superstep_dist.json

bench-gate:      ## diff BENCH_superstep.json vs the baseline (seeds if absent)
	$(PY) scripts/bench_check.py BENCH_superstep.json \
	  --baseline $(BASELINE) --seed-missing --threshold $(BENCH_THRESHOLD)

bench-check: bench
	$(MAKE) bench-gate

# Mirror of .github/workflows/ci.yml for local runs: lint + tier-1 tests,
# then the quick bench and the regression gate.
ci: lint test
	$(MAKE) bench-quick
	$(MAKE) bench-gate
