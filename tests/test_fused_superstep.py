"""Fused superstep path == reference path, for every algorithm.

The fused Pallas kernel (kernels/fused_superstep.py) must be a pure
performance substitution: ``min``-combine algorithms (BFS, SSSP, CC) are
compared *exactly* — a min over any reduction order is order-insensitive —
while ``sum``-combine algorithms (PageRank, BC) are compared to tight
tolerances, since reassociating an f32 sum legitimately moves the last ulp.
Also covers the span-overflow fallback (adversarial gappy destinations) and
the block-metadata invariants it keys off.
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.algorithms import (bfs, pagerank, sssp, connected_components,
                              betweenness_centrality)
from repro.algorithms.cc import symmetrize

INTERP = dict(interpret=True)
SCALE = 10
PARTS = 4
BLOCK_E = 256  # small blocks → many grid cells, still 128-aligned


@pytest.fixture(scope="module", params=PT.STRATEGIES)
def engines(request):
    """(reference, fused) engine pair per partitioning strategy."""
    g = G.rmat(SCALE, 4, seed=13).with_uniform_weights(seed=1)
    pg = PT.partition(g, PARTS, request.param, include_reverse=True)
    return (BSPEngine(pg, **INTERP),
            BSPEngine(pg, fused=True, block_e=BLOCK_E, **INTERP))


@pytest.fixture(scope="module", params=PT.STRATEGIES)
def cc_engines(request):
    g = symmetrize(G.rmat(SCALE, 4, seed=13))
    pg = PT.partition(g, PARTS, request.param)
    return (BSPEngine(pg, **INTERP),
            BSPEngine(pg, fused=True, block_e=BLOCK_E, **INTERP))


def test_bfs_parity(engines):
    ref, fus = engines
    lr, sr = bfs(ref, 0)
    lf, sf = bfs(fus, 0)
    np.testing.assert_array_equal(lr, lf)   # min combine: exact
    assert sr == sf


def test_sssp_parity(engines):
    ref, fus = engines
    dr, _ = sssp(ref, 0)
    df, _ = sssp(fus, 0)
    np.testing.assert_array_equal(dr, df)   # min combine: exact


def test_pagerank_parity(engines):
    ref, fus = engines
    pr = pagerank(ref, num_iterations=10)
    pf = pagerank(fus, num_iterations=10)
    np.testing.assert_allclose(pr, pf, rtol=1e-6, atol=1e-9)


def test_bc_parity(engines):
    ref, fus = engines
    br, sr = betweenness_centrality(ref, 0)
    bf, sf = betweenness_centrality(fus, 0)
    assert sr == sf
    np.testing.assert_allclose(br, bf, rtol=1e-5, atol=1e-5)


def test_cc_parity(cc_engines):
    ref, fus = cc_engines
    cr, _ = connected_components(ref)
    cf, _ = connected_components(fus)
    np.testing.assert_array_equal(cr, cf)   # min combine: exact


# ---------------------------------------------------------------------------
# span-overflow fallback
# ---------------------------------------------------------------------------

def _gappy_graph(n=512, hub_edges=64, seed=5):
    """A hub fanning out to destinations spread across the id space: one
    sorted edge block then spans ~the whole segment range."""
    rng = np.random.default_rng(seed)
    src = np.full(hub_edges, 0, dtype=np.int64)
    dst = np.sort(rng.choice(np.arange(1, n), size=hub_edges, replace=False))
    extra_src = rng.integers(0, n, size=n)
    extra_dst = rng.integers(0, n, size=n)
    return G.from_edge_list(np.concatenate([src, extra_src]),
                            np.concatenate([dst, extra_dst]), n)


def test_span_overflow_triggers_fallback():
    g = _gappy_graph()
    pg = PT.partition(g, 2, PT.RAND)
    blk = PT.build_block_metadata(pg.fwd, block_e=128)
    assert not blk.fused_ok(max_span=8)     # adversarial spans exceed bound
    eng = BSPEngine(pg, fused=True, block_e=128, max_span=8, **INTERP)
    ref = BSPEngine(pg, **INTERP)
    lr, _ = bfs(ref, 0)
    lf, _ = bfs(eng, 0)
    np.testing.assert_array_equal(lr, lf)   # fallback is exact


def test_span_limit_respects_vmem_budget():
    from repro.kernels.ops import fused_span_limit
    # Caller bound wins when blocks are small …
    assert fused_span_limit(128, "sum", max_span=4096) == 4096
    # … the VMEM budget wins when blocks are large (8 MiB / 4B / block_e) …
    assert fused_span_limit(1024, "sum", max_span=4096) == 2048
    # … and min-combine's two [block_e, span] arrays halve the limit.
    assert fused_span_limit(1024, "min", max_span=4096) == 1024
    assert fused_span_limit(256, "sum", max_span=4096) == 4096


def test_vmem_budget_fallback_parity():
    """span fits max_span but busts the [block_e, span] VMEM budget →
    byte-gated fallback, identical results."""
    g = G.rmat(SCALE, 4, seed=13)
    pg = PT.partition(g, PARTS, PT.HIGH)
    blk = PT.build_block_metadata(pg.fwd, block_e=1024)
    from repro.kernels.ops import fused_span_limit
    if blk.span <= fused_span_limit(1024, "min"):
        pytest.skip("graph too benign to bust the budget")
    ref = BSPEngine(pg, **INTERP)
    fus = BSPEngine(pg, fused=True, block_e=1024, **INTERP)
    lr, _ = bfs(ref, 0)
    lf, _ = bfs(fus, 0)
    np.testing.assert_array_equal(lr, lf)


def test_fallback_engine_matches_for_weighted_min():
    g = _gappy_graph().with_uniform_weights(seed=2)
    pg = PT.partition(g, 2, PT.RAND)
    ref = BSPEngine(pg, **INTERP)
    fb = BSPEngine(pg, fused=True, block_e=128, max_span=8, **INTERP)
    dr, _ = sssp(ref, 0)
    df, _ = sssp(fb, 0)
    np.testing.assert_array_equal(dr, df)


# ---------------------------------------------------------------------------
# block metadata invariants
# ---------------------------------------------------------------------------

def test_block_metadata_invariants():
    g = G.rmat(9, 8, seed=11)
    pg = PT.partition(g, PARTS, PT.HIGH)
    blk = PT.build_block_metadata(pg.fwd, block_e=256)
    assert blk.e_pad % blk.block_e == 0
    assert blk.span % 128 == 0 and blk.span >= blk.span_req
    # local offsets reconstruct dst_ext for every real edge
    nb = blk.num_blocks
    ids = (np.repeat(blk.base, blk.block_e, axis=1) + blk.local)
    e_max = pg.fwd.e_max
    real = blk.mask[:, :e_max].astype(bool)
    np.testing.assert_array_equal(ids[:, :e_max][real],
                                  pg.fwd.dst_ext[real])
    # local offsets always inside the compiled span
    assert blk.local.min() >= 0 and blk.local.max() < blk.span
    # per-partition histogram accounts for every block
    hist = blk.span_histogram()
    assert hist.shape[0] == pg.num_parts and int(hist.sum()) == \
        pg.num_parts * nb


def test_padding_edges_never_widen_span():
    """A partition with very few edges still gets span == one lane tile."""
    src = np.array([0, 1], dtype=np.int64)
    dst = np.array([1, 0], dtype=np.int64)
    g = G.from_edge_list(src, dst, 4)
    pg = PT.partition(g, 2, PT.RAND)
    blk = PT.build_block_metadata(pg.fwd, block_e=128)
    assert blk.span == 128
    assert blk.span_req <= 2
