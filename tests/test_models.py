"""Model-stack correctness: loss sanity, serve-path consistency, invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import api
from repro.models.common import ArchConfig

F32 = dict(compute_dtype="float32")


def _cfg(family, **kw):
    base = dict(name=f"t-{family}", family=family, n_layers=4, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
                microbatches=1, **F32)
    base.update(kw)
    return ArchConfig(**base)


CFGS = {
    "dense": _cfg("dense"),
    "moe": _cfg("moe", n_kv_heads=4, moe_experts=8, moe_top_k=2, d_ff=64),
    "local_global": _cfg("dense", n_layers=6, local_window=8,
                         local_global_ratio=5),
    "audio": _cfg("audio", n_layers=2, n_kv_heads=4, enc_dec=True,
                  frontend="audio"),
    "vlm": _cfg("vlm", n_kv_heads=4, frontend="vision", frontend_len=8),
    "ssm": _cfg("ssm", n_kv_heads=4, d_ff=0, slstm_every=2,
                sub_quadratic=True),
    "hybrid": _cfg("hybrid", n_kv_heads=4, ssm_state=16, attn_every=2,
                   sub_quadratic=True),
}
TRAIN = api.ShapeSpec("t", "train", 32, 4)


@pytest.fixture(scope="module")
def built():
    out = {}
    for name, cfg in CFGS.items():
        model = api.build(cfg)
        out[name] = (model, model.init(jax.random.key(hash(name) % 1000)))
    return out


@pytest.mark.parametrize("name", list(CFGS))
def test_loss_finite_and_near_uniform(built, name):
    model, params = built[name]
    batch = api.synth_batch(model.cfg, TRAIN)
    loss = float(model.loss(params, batch))
    assert np.isfinite(loss)
    # fresh init ≈ uniform prediction: loss ≈ ln(vocab)
    assert abs(loss - np.log(model.cfg.vocab)) < 1.5


@pytest.mark.parametrize("name", list(CFGS))
def test_train_step_reduces_loss_and_no_nans(built, name):
    model, params = built[name]
    from repro.optim.adamw import AdamW
    opt = AdamW(learning_rate=1e-2, warmup_steps=1)
    step = jax.jit(api.make_train_step(model, opt))
    opt_state = opt.init(params)
    batch = api.synth_batch(model.cfg, TRAIN)
    losses = []
    for _ in range(4):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]  # memorizes the fixed batch
    for leaf in jax.tree.leaves(params):
        assert not np.any(np.isnan(np.asarray(leaf)))


@pytest.mark.parametrize("name", ["dense", "moe", "local_global", "ssm",
                                  "hybrid", "vlm"])
def test_prefill_decode_matches_parallel_forward(built, name):
    """serve path == train-path logits, token by token."""
    model, params = built[name]
    cfg = model.cfg
    rng = np.random.default_rng(0)
    s = 8
    batch = api.synth_batch(cfg, api.ShapeSpec("t", "train", s + 1, 2))
    tokens = batch["tokens"][:, : s + 1]
    full = dict(batch, tokens=tokens)
    # parallel logits at position s-1 predict token s
    loss_inputs = dict(full, tokens=tokens)
    # use prefill on the first s tokens then decode one step
    pf_batch = {k: (v[:, :s] if k == "tokens" else v)
                for k, v in full.items()}
    logits_pf, cache = model.prefill(params, pf_batch, max_len=s + 4)
    logits_dec, cache2 = model.decode_step(params, cache, tokens[:, s])
    assert logits_pf.shape == (2, cfg.vocab)
    assert logits_dec.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits_pf)).all()
    assert np.isfinite(np.asarray(logits_dec)).all()
    extra = cfg.frontend_len if cfg.frontend == "vision" else 0
    assert int(cache2["len"]) == s + 1 + extra


def test_transformer_decode_matches_prefill_shifted(built):
    """Decoding token t after prefill[0:t] == prefill[0:t+1]'s last logits."""
    model, params = built["dense"]
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 256, (2, 9)), jnp.int32)
    lg_a, cache = model.prefill(params, {"tokens": toks[:, :8]}, max_len=9)
    lg_b, _ = model.decode_step(params, cache, toks[:, 8])
    lg_full, _ = model.prefill(params, {"tokens": toks}, max_len=9)
    np.testing.assert_allclose(np.asarray(lg_b), np.asarray(lg_full),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_parallel_equals_sequential():
    from repro.models import xlstm as X
    cfg = _cfg("ssm", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
               d_ff=0, vocab=64, slstm_every=0)
    model = api.build(cfg)
    params = model.init(jax.random.key(1))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 64, (2, 8)),
                       jnp.int32)
    lp, _ = X._forward(params, cfg, toks)
    c = X.init_cache(cfg, 2, 8)
    outs = []
    for i in range(8):
        lg, c = X._forward(params, cfg, toks[:, i: i + 1], c)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(lp), rtol=1e-4, atol=1e-4)


def test_zamba_parallel_equals_sequential():
    from repro.models import zamba as Z
    cfg = CFGS["hybrid"]
    model = api.build(cfg)
    params = model.init(jax.random.key(2))
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 256, (2, 8)),
                       jnp.int32)
    lp, _ = Z._forward(params, cfg, toks, ssd_chunk=4)
    c = Z.init_cache(cfg, 2, 8)
    outs = []
    for i in range(8):
        lg, c = Z._forward(params, cfg, toks[:, i: i + 1], c)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(lp), rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    """SSD result must not depend on the chunk size (property test)."""
    from repro.models.mamba2 import ssd
    rng = np.random.default_rng(5)
    b, s, h, p, n = 2, 32, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(b, s, h))), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cc = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y1, f1 = ssd(x, a, bb, cc, chunk=4)
    y2, f2 = ssd(x, a, bb, cc, chunk=16)
    y3, f3 = ssd(x, a, bb, cc, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y3), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f3), rtol=1e-4,
                               atol=1e-5)


def test_ssd_matches_naive_recurrence():
    from repro.models.mamba2 import ssd
    rng = np.random.default_rng(6)
    b, s, h, p, n = 1, 16, 2, 4, 3
    x = rng.normal(size=(b, s, h, p)).astype(np.float32)
    a = -np.abs(rng.normal(size=(b, s, h))).astype(np.float32)
    bb = rng.normal(size=(b, s, n)).astype(np.float32)
    cc = rng.normal(size=(b, s, n)).astype(np.float32)
    # naive recurrence oracle
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        state = state * np.exp(a[:, t])[:, :, None, None] + \
            np.einsum("bhp,bn->bhpn", x[:, t], bb[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, cc[:, t])
    y, final = ssd(jnp.asarray(x), jnp.asarray(a), jnp.asarray(bb),
                   jnp.asarray(cc), chunk=4)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(final), state, rtol=1e-4,
                               atol=1e-5)


def test_moe_capacity_and_dropping():
    from repro.models import moe
    cfg = CFGS["moe"]
    t = 32 * 4
    c = moe.capacity(t, cfg)
    assert c >= t * cfg.moe_top_k / cfg.moe_experts
    # all-same-token input routes everything to the same experts → drops
    model = api.build(cfg)
    params = model.init(jax.random.key(0))
    batch = {"tokens": jnp.zeros((4, 33), jnp.int32)}
    loss = float(model.loss(params, batch))
    assert np.isfinite(loss)
