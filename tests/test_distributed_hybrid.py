"""Distributed hybrid backend: parity, planning, and exchange contracts.

The parity matrix — all five algorithms × RAND/HIGH/LOW × {1, 2, 4} forced
host devices against the single-device reference — runs in subprocesses
(``repro.launch.hybrid_selftest``) so the forced device count never leaks
into this process's jax runtime.  The in-process tests cover the pieces
that don't need a multi-device runtime: the comm-aware perf model, the
per-shard split/exchange preprocessing, and the ``_dist_exchange`` shape
validation (the silent-misroute bugfix).
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core import perf_model
from repro.core.hybrid import shard_degree_split, shard_plan_inputs

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(ndev: int, module: str, *args, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    return subprocess.run([sys.executable, "-m", module, *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


@pytest.mark.parametrize("ndev", [1, 2, 4])
def test_distributed_hybrid_parity(ndev):
    """5 algorithms × 3 strategies vs the single-device reference; the
    1-device run additionally covers the P=1 empty-outbox edge case."""
    r = _run(ndev, "repro.launch.hybrid_selftest", "--parts", "4")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "HYBRID SELFTEST OK" in r.stdout
    if ndev == 1:
        assert "empty-outbox edge case" in r.stdout


# ---------------------------------------------------------------------------
# comm-aware perf model (Eq. 1's |E_p^b|/c term)
# ---------------------------------------------------------------------------

def test_comm_term_raises_predicted_makespan():
    g = G.rmat(9, 4, seed=13)
    pg = PT.partition(g, 4, PT.RAND)
    ranks, edges, slots, nverts = shard_plan_inputs(pg, 4)
    assert sum(edges) + sum(slots) > 0
    quiet = perf_model.rank_k_dense(ranks[0], edges[0], [0, 128])
    loud = perf_model.rank_k_dense(ranks[0], edges[0], [0, 128],
                                   boundary_slots=1e6)
    for a, b in zip(quiet, loud):
        assert b["t_comm"] > a["t_comm"]
        assert b["makespan"] > a["makespan"]
        assert b["makespan"] == pytest.approx(
            a["makespan"] + b["t_comm"] - a["t_comm"])


def test_per_shard_k_is_argmin_of_comm_inclusive_makespan():
    g = G.rmat(9, 4, seed=13)
    pg = PT.partition(g, 4, PT.HIGH)
    ranks, edges, slots, nverts = shard_plan_inputs(pg, 4)
    cands = [perf_model.k_dense_candidates(n) for n in nverts]
    plan = perf_model.plan_shards(ranks, edges, slots, cands)
    assert len(plan["per_shard"]) == 4
    for rec in plan["per_shard"]:
        best = min(rec["table"], key=lambda r: r["makespan"])
        assert rec["k_dense"] == best["k_dense"]
        assert rec["t_comm"] == pytest.approx(
            rec["boundary_slots"] * 4.0
            / (perf_model.TPU_ICI_LINK_BW * perf_model.TPU_ICI_LINKS))
    assert plan["makespan"] == max(r["makespan"] for r in plan["per_shard"])


def test_partitioning_strategy_changes_per_shard_splits():
    """HIGH concentrates high-degree vertices on shard 0, LOW the reverse —
    the per-shard split decisions must differ (the paper's §3.4/§6.2
    strategy-sensitivity argument)."""
    g = G.rmat(9, 4, seed=13)
    ks = {}
    for strategy in (PT.HIGH, PT.LOW):
        pg = PT.partition(g, 4, strategy)
        ranks, edges, slots, nverts = shard_plan_inputs(pg, 4)
        cands = [perf_model.k_dense_candidates(n) for n in nverts]
        plan = perf_model.plan_shards(ranks, edges, slots, cands)
        ks[strategy] = [r["k_dense"] for r in plan["per_shard"]]
    assert ks[PT.HIGH] != ks[PT.LOW]


def test_plan_shards_honours_forced_k():
    g = G.rmat(9, 4, seed=13)
    pg = PT.partition(g, 4, PT.RAND)
    ranks, edges, slots, nverts = shard_plan_inputs(pg, 4)
    plan = perf_model.plan_shards(ranks, edges, slots,
                                  [[0, 64, 128]] * 4, k_dense=64)
    assert all(r["k_dense"] == 64 for r in plan["per_shard"])


# ---------------------------------------------------------------------------
# per-shard split + compact exchange preprocessing
# ---------------------------------------------------------------------------

def test_compact_exchange_ships_fewer_values_than_full_tensor():
    """The compact maps move β_with_reduction·|E|-scale slot counts; the
    dense [pl, P, o_max] tensor the non-hybrid exchange ships is strictly
    larger."""
    g = G.rmat(9, 4, seed=13)
    pg = PT.partition(g, 4, PT.RAND)
    shd = shard_degree_split(pg, 4, "min", [0, 0, 0, 0])
    full = shd.parts_per_shard * shd.num_parts * shd.o_max
    assert 0 < shd.wire_values_per_superstep() < full
    # every real send slot appears exactly once across send+local maps
    used = int(pg.fwd.outbox_mask.sum())
    sent = int((shd.send_idx < shd.num_slots).sum())
    local = int((shd.loc_idx < shd.num_slots).sum())
    assert sent + local == used


def test_shard_split_covers_every_edge_exactly_once():
    g = G.rmat(9, 4, seed=13)
    pg = PT.partition(g, 4, PT.HIGH)
    shd = shard_degree_split(pg, 2, "plus_times", [64, 64])
    dense_edges = int(shd.dense.sum())          # multiplicity counts
    ell_edges = int((shd.ell_col < shd.n_max).sum())
    boundary = int(shd.b_mask.sum())
    assert dense_edges + ell_edges + boundary == g.num_edges


def test_use_reverse_requires_rev_arrays():
    g = G.rmat(8, 4, seed=7)
    pg = PT.partition(g, 2, PT.RAND)            # no include_reverse
    with pytest.raises(ValueError, match="include_reverse"):
        shard_degree_split(pg, 2, "plus_times", [0, 0], use_reverse=True)


# ---------------------------------------------------------------------------
# _dist_exchange validation (bugfix: silent misroute on uneven pl)
# ---------------------------------------------------------------------------

def test_dist_exchange_rejects_inconsistent_outbox_shape():
    import jax
    import jax.numpy as jnp
    from repro.core.bsp import DistributedBSPEngine

    g = G.rmat(8, 4, seed=7)
    pg = PT.partition(g, 2, PT.RAND)
    mesh = jax.make_mesh((1,), ("parts",))
    eng = DistributedBSPEngine(pg, mesh)
    del jax
    # peer axis != n_dev * pl → previously reshaped into garbage routing;
    # the validation fires before the collective, so no mesh context needed.
    with pytest.raises(ValueError, match="peer axis"):
        eng._dist_exchange(jnp.zeros((2, 3, pg.fwd.o_max), jnp.float32))


def test_run_rejects_mis_sharded_state():
    import jax
    import jax.numpy as jnp
    from repro.core.bsp import DistributedBSPEngine
    from repro.algorithms.bfs import BFS_PROGRAM

    g = G.rmat(8, 4, seed=7)
    pg = PT.partition(g, 2, PT.RAND)
    mesh = jax.make_mesh((1,), ("parts",))
    eng = DistributedBSPEngine(pg, mesh)
    bad = {"level": jnp.zeros((3, pg.v_max), jnp.float32)}  # 3 != num_parts
    with pytest.raises(ValueError, match="num_parts"):
        eng.execute(BFS_PROGRAM, bad)


def test_mesh_must_divide_num_parts():
    import jax
    from repro.core.bsp import DistributedBSPEngine

    g = G.rmat(8, 4, seed=7)
    pg = PT.partition(g, 3, PT.RAND)
    mesh = jax.make_mesh((1,), ("parts",))  # 3 % 1 == 0: fine
    DistributedBSPEngine(pg, mesh)
    if len(jax.devices()) >= 2:
        mesh2 = jax.make_mesh((2,), ("parts",))
        with pytest.raises(ValueError, match="mesh axis"):
            DistributedBSPEngine(pg, mesh2)
