"""Direction-optimized traversal: parity, per-query votes, zero-retrace
switching, mutation round-trips, and the fitted crossover.

Direction (top-down push vs bottom-up pull) is a pure performance choice
for min combines — both directions reduce the same value multiset per
destination, so every cell of the auto/push/pull × backend × device-count
matrix must agree *bitwise* (docs/traversal.md).  The multi-device matrix
runs in subprocesses (``repro.launch.direction_selftest``) so forced host
device counts never leak into this process's jax runtime.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core import perf_model
from repro.core.bsp import BSPEngine

SRC = str(Path(__file__).resolve().parents[1] / "src")
INTERP = dict(interpret=True)


def _run(ndev: int, module: str, *args, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    return subprocess.run([sys.executable, "-m", module, *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


@pytest.mark.parametrize("ndev", [1, 2, 4])
def test_direction_parity_matrix(ndev):
    """bfs/sssp/cc × {reference, fused, hybrid} × {push, pull, auto} vs
    the single-device push baseline — bitwise, per device count."""
    r = _run(ndev, "repro.launch.direction_selftest", "--parts", "4")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DIRECTION SELFTEST OK" in r.stdout


def _star_and_chain(spokes=29, chain=10):
    """Hub 0 → spokes (one dense-frontier superstep) plus a disjoint
    directed chain (a frontier of exactly one vertex per superstep)."""
    n = 1 + spokes + chain
    hub_src = np.zeros(spokes, np.int64)
    hub_dst = np.arange(1, 1 + spokes)
    c0 = 1 + spokes
    ch_src = np.arange(c0, n - 1)
    ch_dst = np.arange(c0 + 1, n)
    g = G.from_edge_list(np.concatenate([hub_src, ch_src]),
                         np.concatenate([hub_dst, ch_dst]), n)
    return g, c0


def test_per_query_direction_vote():
    """Satellite: the frontier-density vote is per *query*, not a batch
    aggregate — in one batch, the hub query (dense frontier superstep)
    switches direction while the chain query (always-sparse frontier)
    never leaves push."""
    from repro.algorithms.bfs import bfs_batched, bfs_reference

    g, c0 = _star_and_chain()
    pg = PT.partition(g, 2, PT.RAND)
    eng = BSPEngine(pg, pull_threshold=0.3, **INTERP)
    lv, _ = bfs_batched(eng, [0, c0])
    ref = np.stack([bfs_reference(g, s) for s in (0, c0)])
    np.testing.assert_array_equal(lv, ref)
    st = eng.last_direction_stats
    assert st is not None
    # hub: density 1/n → push, spokes/n → pull, 0 → push: ≥ 2 switches
    assert st["switches"][0] >= 1
    # chain: one-vertex frontier forever stays under the 0.3 crossover
    assert st["switches"][1] == 0
    assert (st["direction"][1] == 0).all()
    assert (st["edges_examined"] > 0).all()


def test_switching_never_retraces():
    """A direction flip is `lax.cond` data inside one compiled while_loop:
    a warm same-Q batch that switches adds zero jit cache entries."""
    from repro.algorithms.bfs import bfs_batched

    g, c0 = _star_and_chain()
    pg = PT.partition(g, 2, PT.RAND)
    eng = BSPEngine(pg, pull_threshold=0.3, **INTERP)
    bfs_batched(eng, [0, c0])                       # compiles
    assert eng.last_direction_stats["switches"][0] >= 1
    before = BSPEngine._run_batched._cache_size()
    bfs_batched(eng, [0, c0 + 1])                   # same Q, still switches
    assert eng.last_direction_stats["switches"][0] >= 1
    assert BSPEngine._run_batched._cache_size() == before


@pytest.mark.parametrize("direction", ["auto", "push", "pull"])
def test_dynamic_mutation_roundtrip_both_layouts(direction):
    """Mutate → rerun parity through the transposed/push arenas: inserts
    and deletes reach the pull ELL *and* the push arena through the one
    compiled scatter, in every direction mode."""
    from repro.algorithms.bfs import bfs_batched, bfs_reference
    from repro.core.dynamic import DynamicGraph

    g = G.rmat(7, 6, seed=5)
    rng = np.random.default_rng(0)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=64)
    eng = BSPEngine(dg, backend="hybrid", direction=direction, **INTERP)
    lv0, _ = bfs_batched(eng, [0, 3])
    np.testing.assert_array_equal(
        lv0, np.stack([bfs_reference(g, s) for s in (0, 3)]))

    ins_s = rng.integers(0, g.num_vertices, 30)
    ins_d = rng.integers(0, g.num_vertices, 30)
    ei = rng.choice(g.col.size, 10, replace=False)
    del_s = np.repeat(np.arange(g.num_vertices), np.diff(g.row_ptr))[ei]
    batch = G.MutationBatch(
        src=np.concatenate([ins_s, del_s]),
        dst=np.concatenate([ins_d, g.col[ei]]),
        insert=np.concatenate([np.ones(30, bool), np.zeros(10, bool)]))
    dg.apply_mutations(batch)

    lv1, _ = bfs_batched(eng, [0, 3])
    g2 = dg.mutated_csr()
    np.testing.assert_array_equal(
        lv1, np.stack([bfs_reference(g2, s) for s in (0, 3)]))
    assert (eng.last_direction_stats["edges_examined"] > 0).all()


def test_sum_combines_are_ineligible():
    """Direction optimization is min-semiring-only: a partial bottom-up
    scan would double-count a sum.  PageRank runs untouched and reports
    no direction stats."""
    from repro.algorithms.pagerank import (initial_state,
                                           make_pagerank_program)
    from repro.core.bsp import batch_state

    g = G.rmat(7, 4, seed=3)
    pg = PT.partition(g, 2, PT.RAND)
    eng = BSPEngine(pg, direction="pull", **INTERP)
    program = make_pagerank_program(g.num_vertices)
    out = eng.execute(program, batch_state(initial_state(pg)),
                      num_steps=3)
    assert eng.last_direction_stats is None
    assert np.isfinite(np.asarray(out["rank"])).all()


def test_pull_threshold_monotone_in_degree():
    """The fitted crossover must not *rise* with average degree: denser
    graphs amortize a bottom-up scan sooner, never later."""
    degs = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0]
    for backend in ("hybrid", "fused", "reference"):
        thrs = [perf_model.fit_pull_threshold(d, 32, backend=backend)
                for d in degs]
        assert all(a >= b for a, b in zip(thrs, thrs[1:])), (backend, thrs)
        assert all(1e-4 <= t <= 0.9 for t in thrs), (backend, thrs)
    shard = perf_model.fit_shard_pull_thresholds(
        [2.0, 8.0, 32.0], [16, 16, 16], backend="fused")
    assert shard.shape == (3,) and shard.dtype == np.float32
    assert shard[0] >= shard[1] >= shard[2]


def test_bottomup_early_exit_exact_for_uniform_frontier():
    """With a uniform frontier the first live parent *is* the row min, so
    early exit returns bitwise-identical values while scanning no more
    slots than the full pass."""
    from repro.kernels.ops import bottomup_scan_op

    rng = np.random.default_rng(7)
    v, kmax, nx = 24, 6, 32
    col = rng.integers(0, nx, (v, kmax)).astype(np.int32)
    col[rng.random((v, kmax)) < 0.3] = nx            # sentinel slots
    kreal = (col != nx).sum(axis=1).astype(np.int32)
    x = np.full((2, nx + 1), np.inf, np.float32)
    frontier = rng.random((2, nx)) < 0.4
    x[:, :nx][frontier] = 5.0                        # uniform message
    y0, s0 = bottomup_scan_op(col, None, x, kreal, semiring="min",
                              early_exit=False, interpret=True)
    y1, s1 = bottomup_scan_op(col, None, x, kreal, semiring="min",
                              early_exit=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    assert (np.asarray(s1) <= np.asarray(s0)).all()
    assert np.asarray(s1).sum() < np.asarray(s0).sum()


def test_uniform_frontier_flags():
    """BFS declares the uniform frontier (early-exit licence); CC and
    SSSP frontiers carry distinct values and must not."""
    from repro.algorithms.bfs import BFS_PROGRAM, BFS_RELAX_PROGRAM
    from repro.algorithms.cc import CC_PROGRAM
    from repro.algorithms.sssp import SSSP_PROGRAM

    assert BFS_PROGRAM.edge_msg.frontier_uniform
    assert not BFS_RELAX_PROGRAM.edge_msg.frontier_uniform
    assert not CC_PROGRAM.edge_msg.frontier_uniform
    assert not SSSP_PROGRAM.edge_msg.frontier_uniform
