"""Substrate tests: checkpoint/restart, elastic reshard, watchdog, data
determinism, gradient compression, optimizer behaviour."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stub shim

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.models import api
from repro.models.common import ArchConfig
from repro.optim.adamw import AdamW, apply_updates, global_norm
from repro.optim.compression import compress_int8, decompress_int8
from repro.runtime import FaultInjector, StepWatchdog, run_with_restarts

CFG = ArchConfig(name="tt", family="dense", n_layers=2, d_model=32,
                 n_heads=2, n_kv_heads=2, d_ff=64, vocab=128, head_dim=16,
                 microbatches=1, compute_dtype="float32")


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        model = api.build(CFG)
        params = model.init(jax.random.key(0))
        opt = AdamW()
        opt_state = opt.init(params)
        mgr = CheckpointManager(tmp_path)
        mgr.save(7, params, opt_state)
        step, tree = mgr.restore({"params": params,
                                  "opt_state": opt_state})
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomicity_tmp_never_visible(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"w": jnp.ones((4,))})
        files = [p.name for p in tmp_path.iterdir()]
        assert not any(f.endswith(".tmp") for f in files)
        assert mgr.latest_step() == 1

    def test_async_save_and_retention(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in range(5):
            mgr.save(s, {"w": jnp.full((8,), float(s))}, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 4
        ckpts = sorted(tmp_path.glob("step_*.npz"))
        assert len(ckpts) == 2  # retention

    @pytest.mark.filterwarnings(
        "ignore::pytest.PytestUnhandledThreadExceptionWarning")
    def test_crash_mid_save_keeps_previous_checkpoint(self, tmp_path,
                                                      monkeypatch):
        """A crash during the npz write must not tear the latest pointer:
        the partial file stays a ``.tmp``, never a published step."""
        mgr = CheckpointManager(tmp_path)
        mgr.save_tree(1, {"w": jnp.ones((4,))})
        assert mgr.latest_step() == 1

        real_savez = np.savez

        def torn_savez(f, **arrs):
            f.write(b"PK\x03\x04 torn")       # partial bytes, then die
            raise OSError("disk died mid-write")

        monkeypatch.setattr(np, "savez", torn_savez)
        mgr.save_tree(2, {"w": jnp.full((4,), 2.0)}, blocking=False)
        mgr.wait()                             # crash happens on the thread
        monkeypatch.setattr(np, "savez", real_savez)

        assert mgr.latest_step() == 1          # step 2 never published
        step, tree = mgr.restore_tree({"w": np.zeros(4, np.float32)})
        assert step == 1
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.ones(4))
        published = list(tmp_path.glob("step_*.npz"))
        assert all("00000002" not in p.name for p in published)

    def test_save_tree_arbitrary_pytree_roundtrip(self, tmp_path):
        """save_tree/restore_tree handle non-train-shaped pytrees (the
        serving snapshot shape) including manifest extra metadata."""
        mgr = CheckpointManager(tmp_path)
        tree = {"standing": np.arange(12.0, dtype=np.float32).reshape(3, 4),
                "dyn": {"fwd": {"col": np.arange(5, dtype=np.int32)},
                        "counts": (np.int64(7), np.int64(9))}}
        mgr.save_tree(4, tree, extra={"round": 2, "acked": 2})
        step, got = mgr.restore_tree(jax.tree.map(np.zeros_like, tree))
        assert step == 4
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert mgr.manifest_extra(4) == {"round": 2, "acked": 2}

    def test_elastic_reshard_restore(self, tmp_path):
        """Checkpoint written unsharded restores under a different mesh."""
        from repro.checkpoint.manager import restore_resharded
        from jax.sharding import PartitionSpec as P
        mgr = CheckpointManager(tmp_path)
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        mgr.save(3, tree["w"])
        mesh = jax.make_mesh((1,), ("data",))
        step, placed = restore_resharded(
            mgr, {"params": tree["w"]}, mesh,
            {"params": P("data", None)})
        assert step == 3
        np.testing.assert_array_equal(np.asarray(placed["params"]),
                                      np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

class TestRestart:
    def test_restart_reproduces_failure_free_run(self, tmp_path):
        """Injected failures must not change the final state (determinism)."""
        def make_step(injector=None):
            def step(i, state):
                if injector:
                    injector.maybe_fail(i)
                return {"params": {"w": state["params"]["w"] + i}}, {"i": i}
            return step

        init = {"params": {"w": jnp.zeros(())}}
        clean, _ = run_with_restarts(
            make_step(), init, 20, CheckpointManager(tmp_path / "a"),
            checkpoint_every=5)
        inj = FaultInjector({7, 13})
        faulty, summary = run_with_restarts(
            make_step(inj), init, 20, CheckpointManager(tmp_path / "b"),
            checkpoint_every=5)
        assert summary["failures"] == 2
        assert float(clean["params"]["w"]) == float(faulty["params"]["w"])

    def test_gives_up_after_max_failures(self, tmp_path):
        inj = FaultInjector(set(range(100)))
        inj.fired = set()  # re-fire every time

        def step(i, state):
            raise RuntimeError("always down")

        with pytest.raises(RuntimeError):
            run_with_restarts(step, {"params": {"w": jnp.zeros(())}}, 5,
                              CheckpointManager(tmp_path), max_failures=2)

    def test_restarts_generic_pytree_state(self, tmp_path):
        """State is any pytree, not the train-shaped dict — a serving
        carry {standing results, counters} restarts identically."""
        def make_step(injector=None):
            def step(i, state):
                if injector:
                    injector.maybe_fail(i)
                return {"res": state["res"] + i,
                        "meta": (state["meta"][0] + 1,)}, {}
            return step

        init = {"res": jnp.zeros((2, 3)), "meta": (jnp.zeros((), jnp.int32),)}
        clean, _ = run_with_restarts(
            make_step(), init, 9, CheckpointManager(tmp_path / "a"),
            checkpoint_every=3)
        faulty, summary = run_with_restarts(
            make_step(FaultInjector({4})), init, 9,
            CheckpointManager(tmp_path / "b"), checkpoint_every=3)
        assert summary["failures"] == 1
        np.testing.assert_array_equal(np.asarray(clean["res"]),
                                      np.asarray(faulty["res"]))
        assert int(clean["meta"][0]) == int(faulty["meta"][0]) == 9

    def test_non_retryable_surfaces_immediately(self, tmp_path):
        """Programming bugs are not in the retryable whitelist: no restart
        is burned, the error propagates on the first occurrence."""
        calls = []

        def step(i, state):
            calls.append(i)
            raise ValueError("a bug, not a dead worker")

        with pytest.raises(ValueError):
            run_with_restarts(step, {"w": jnp.zeros(())}, 5,
                              CheckpointManager(tmp_path), max_failures=3)
        assert calls == [0]   # never retried


class TestWatchdog:
    def test_flags_straggler(self):
        wd = StepWatchdog(warmup_steps=3)
        flagged = []
        wd.on_straggler = lambda s, d, m: flagged.append(s)
        for i in range(20):
            wd.report(i, 0.1 + 0.001 * (i % 3))
        assert not flagged
        wd.report(20, 1.0)  # 10× slower
        assert flagged == [20]

    def test_ewma_tracks_drift(self):
        wd = StepWatchdog(warmup_steps=2, alpha=0.5)
        for i in range(30):
            wd.report(i, 0.1 if i < 15 else 0.2)
        assert 0.15 < wd.mean_step_s <= 0.21


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_seekable_determinism(self):
        s1 = TokenStream(CFG, batch=4, seq=16, seed=3)
        s2 = TokenStream(CFG, batch=4, seq=16, seed=3)
        np.testing.assert_array_equal(np.asarray(s1.batch_at(9)["tokens"]),
                                      np.asarray(s2.batch_at(9)["tokens"]))

    def test_steps_differ(self):
        s = TokenStream(CFG, batch=4, seq=16, seed=3)
        a = np.asarray(s.batch_at(0)["tokens"])
        b = np.asarray(s.batch_at(1)["tokens"])
        assert (a != b).any()

    def test_zipf_skew(self):
        """Heavy-hitter tokens exist — the degree-skew analogue."""
        s = TokenStream(CFG, batch=64, seq=64, seed=0)
        toks = np.asarray(s.batch_at(0)["tokens"]).ravel()
        counts = np.bincount(toks, minlength=CFG.vocab)
        assert counts.max() > 20 * max(np.median(counts), 1)


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

class TestOptim:
    def test_adamw_descends_quadratic(self):
        opt = AdamW(learning_rate=0.1, weight_decay=0.0, warmup_steps=1)
        p = {"w": jnp.array([3.0, -2.0])}
        st_ = opt.init(p)
        for _ in range(200):
            g = {"w": 2 * p["w"]}
            up, st_ = opt.update(g, st_, p)
            p = apply_updates(p, up)
        assert float(jnp.abs(p["w"]).max()) < 0.05

    def test_clipping_bounds_update(self):
        opt = AdamW(learning_rate=1.0, clip_norm=1.0, warmup_steps=1)
        p = {"w": jnp.zeros(4)}
        st_ = opt.init(p)
        g = {"w": jnp.full(4, 1e6)}
        up, _ = opt.update(g, st_, p)
        assert np.isfinite(np.asarray(up["w"])).all()

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 2000))
    def test_int8_compression_bounded_error(self, seed, n):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=n) * rng.uniform(0.1, 100),
                        jnp.float32)
        q, s, meta = compress_int8(x)
        deq = decompress_int8(q, s, meta)
        # per-block max error ≤ scale/2 = |block|_max / 254
        blocks = np.asarray(x)
        err = np.abs(np.asarray(deq) - blocks)
        assert err.max() <= np.abs(blocks).max() / 254 + 1e-6

    def test_error_feedback_converges(self):
        """Quantized-gradient SGD with error feedback still descends."""
        w = np.array([5.0, -5.0, 2.0], dtype=np.float32)
        e = np.zeros_like(w)
        for _ in range(300):
            g = 2 * w
            q, s, meta = compress_int8(jnp.asarray(g + e))
            deq = np.asarray(decompress_int8(q, s, meta))
            e = g + e - deq
            w = w - 0.05 * deq
        assert np.abs(w).max() < 0.1
