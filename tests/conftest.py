"""Shared test fixtures/shims.

``hypothesis`` is an optional dev dependency (requirements-dev.txt).  Modules
that mix property-based and plain tests import the decorators from here so a
bare environment skips only the ``@given`` tests instead of the whole module
(pure property modules use ``pytest.importorskip`` at module level instead).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # bare env: stub the decorators
    class _Strategies:
        """Swallows strategy construction (evaluated at module import)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (requirements-dev.txt)")(f)
