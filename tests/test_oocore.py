"""Out-of-core tiered memory (docs/memory.md).

The contract under test: with an HBM budget that forces cold partitions
into host DRAM, the fused superstep streams their edge arenas through
bounded double-buffered windows and still reaches the *bitwise identical*
fixpoint the all-resident engine reaches — on every backend, for every
algorithm — while steady-state supersteps add zero compile-cache entries
(the windows reuse one trace; only the partition/window *data* changes).

The budget in these tests is probed, not hardcoded: a throwaway
``build_tier_plan`` with an unbounded budget yields the per-split byte
table and the tests pin the budget to a row that leaves >= 2 partitions
host-tier, so the assertions track layout changes instead of rotting.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.core.dynamic import DynamicGraph
from repro.core.graph import MutationBatch
from repro.core.partition import build_tier_plan
from repro.core.perf_model import choose_tier_split
from repro.algorithms.bfs import bfs_batched
from repro.algorithms.sssp import sssp_batched
from repro.algorithms.cc import connected_components
from repro.algorithms.pagerank import pagerank
from repro.algorithms.bc import betweenness_centrality_batched

BACKENDS = {
    "reference": {},
    "fused": dict(backend="fused"),
    "hybrid": dict(backend="hybrid"),
}
SOURCES = [0, 3]
# uniform degrees keep destination runs short, so the block-granularity
# clean-cut windows exist at this tiny smoke scale (rmat's power-law runs
# need the larger block_e/win_blocks real runs use)
BLOCK_E, WIN_BLOCKS = 128, 2


@pytest.fixture(scope="module")
def pg():
    g = G.uniform(8, 6, seed=0).with_uniform_weights()
    return PT.partition(g, 4, PT.HIGH, include_reverse=True)


_BUDGETS: dict = {}


def _budget(pg, backend: str) -> int:
    """The 2-hot row's exact arena bytes: partitions beyond the densest two
    are forced host-tier.  Probed per arena flavor — the engine plans
    reference-backend arenas without block metadata, so a fused-flavor
    budget would hold *all* reference partitions and nothing would stream."""
    fused = backend != "reference"
    if fused not in _BUDGETS:
        probe = build_tier_plan(pg, 1 << 60, block_e=BLOCK_E,
                                win_blocks=WIN_BLOCKS, fused=fused)
        _BUDGETS[fused] = int(probe.table[2]["hbm_bytes"])
    return _BUDGETS[fused]


def _run(eng, alg):
    if alg == "bfs":
        return np.asarray(bfs_batched(eng, SOURCES)[0])
    if alg == "sssp":
        return np.asarray(sssp_batched(eng, SOURCES)[0])
    if alg == "cc":
        return np.asarray(connected_components(eng)[0])
    if alg == "pagerank":
        return np.asarray(pagerank(eng, 20))
    return np.asarray(betweenness_centrality_batched(eng, SOURCES)[0])


def _bitwise(a, b) -> bool:
    return a.shape == b.shape and bool(
        np.all((a == b) | (np.isnan(a) & np.isnan(b))))


@pytest.mark.parametrize("backend", list(BACKENDS))
@pytest.mark.parametrize("alg", ["bfs", "sssp", "cc", "pagerank", "bc"])
def test_streamed_matches_resident_bitwise(pg, backend, alg):
    """The tentpole claim: streaming changes *where* edges live, never a
    single bit of the fixpoint — including the sum-combine programs whose
    rounding order the clean-cut windows and pinned FMAs preserve."""
    bkw = BACKENDS[backend]
    resident = _run(BSPEngine(pg, interpret=True, **bkw), alg)
    tiered_eng = BSPEngine(pg, interpret=True, tiered=_budget(pg, backend),
                           block_e=BLOCK_E, win_blocks=WIN_BLOCKS, **bkw)
    assert len(tiered_eng.tier_plan.cold) >= 2
    streamed = _run(tiered_eng, alg)
    assert _bitwise(resident, streamed)


@pytest.mark.parametrize("backend", list(BACKENDS))
def test_zero_retraces_across_windows(pg, backend):
    """Steady state adds no compile-cache entries: every window of every
    cold partition reuses the same traced superstep (static per-block
    metadata, donated accumulator), run after run."""
    eng = BSPEngine(pg, backend=None if backend == "reference" else backend,
                    interpret=True, tiered=_budget(pg, backend),
                    block_e=BLOCK_E, win_blocks=WIN_BLOCKS)
    assert eng.tier_plan.fwd.num_windows >= 3   # genuinely multi-window
    counts = []
    for _ in range(4):
        if backend == "hybrid":
            pagerank(eng, 3)                    # sum path (dense block)
        bfs_batched(eng, SOURCES)               # min path
        counts.append(eng.tiered_cache_entries())
    # first run compiles; every later run must hit the caches exactly
    assert counts[1:] == [counts[1]] * (len(counts) - 1), counts


def test_mutation_roundtrip_on_host_tier_partition(pg):
    """A mutation batch touching a *host-tier* partition round-trips: the
    resident and tiered dynamic engines apply the same batch (inserts into
    the delta overlay, deletes as tombstones in the streamed arena) and
    reconverge to the same fixpoint."""
    g = G.uniform(8, 6, seed=0).with_uniform_weights()
    dg_res = DynamicGraph(g, 4, PT.HIGH, mutation_capacity=64,
                          include_reverse=True)
    dg_tier = DynamicGraph(g, 4, PT.HIGH, mutation_capacity=64,
                           include_reverse=True)
    probe = build_tier_plan(dg_tier.pg, 1 << 60, block_e=BLOCK_E,
                            win_blocks=WIN_BLOCKS, fused=False,
                            dynamic=dg_tier)
    bdg = int(probe.table[2]["hbm_bytes"])
    eng_res = BSPEngine(dg_res, interpret=True)
    eng_tier = BSPEngine(dg_tier, interpret=True, tiered=bdg,
                         block_e=BLOCK_E, win_blocks=WIN_BLOCKS)
    cold_p = int(eng_tier.tier_plan.cold[0])

    # two inserts plus two deletes of existing edges into the cold partition
    src_all, dst_all = g.edge_sources(), g.col
    part_of = eng_tier.pg.assignment.part_of
    sel = np.where(part_of[dst_all] == cold_p)[0][:2]
    assert len(sel) == 2
    batch = MutationBatch(
        np.concatenate([np.array([1, 3], np.int64), src_all[sel]]),
        np.concatenate([np.array([7, 9], np.int64), dst_all[sel]]),
        np.array([True, True, False, False]),
        np.ones(4, np.float32))
    for d in (dg_res, dg_tier):
        d.apply_mutations(batch)
    res = np.asarray(bfs_batched(eng_res, SOURCES)[0])
    tier = np.asarray(bfs_batched(eng_tier, SOURCES)[0])
    assert _bitwise(res, tier)


def test_choose_tier_split_monotone():
    """A bigger budget keeps a superset of partitions hot (the split is a
    densest-first prefix, so feasibility can only grow with the budget)."""
    part_bytes = [700, 300, 1100, 500]
    window = 100
    prev: set = set()
    for budget_b in range(200, 3000, 100):
        try:
            hot, _ = choose_tier_split(part_bytes, budget_b,
                                       window_bytes=window)
        except ValueError:
            assert budget_b < 2 * window    # below the double-buffer floor
            continue
        cur = set(int(p) for p in hot)
        assert prev <= cur, (budget_b, prev, cur)
        prev = cur
    assert prev == {0, 1, 2, 3}             # unbounded end keeps all hot


def test_all_cold_completes_at_4x_capacity(pg):
    """The capacity claim: a graph >= 4x the device arena budget still
    completes (every partition host-tier, only the double-buffer and hot
    metadata resident) and stays bitwise."""
    # win_blocks=3: all-cold also windows the densest partition, whose
    # clean boundaries are sparser than the cold partitions' (the window
    # must span past its longest destination runs)
    wb = 3
    probe = build_tier_plan(pg, 1 << 60, block_e=BLOCK_E, win_blocks=wb)
    row0 = probe.table[0]                   # all-cold: buffers only
    budget0 = int(row0["hbm_bytes"])
    assert row0["host_bytes"] >= 4 * budget0
    eng = BSPEngine(pg, backend="fused", interpret=True, tiered=budget0,
                    block_e=BLOCK_E, win_blocks=wb)
    assert len(eng.tier_plan.hot) == 0
    stats = eng.tiered_stats()
    assert stats["hbm_resident_bytes"] <= budget0
    resident = _run(BSPEngine(pg, backend="fused", interpret=True), "bfs")
    assert _bitwise(resident, _run(eng, "bfs"))


def test_budget_below_buffer_floor_raises(pg):
    with pytest.raises(ValueError, match="double-buffer"):
        BSPEngine(pg, interpret=True, tiered=64, block_e=BLOCK_E,
                  win_blocks=WIN_BLOCKS)


def test_residency_split_admission_fields(pg):
    """``residency_bytes`` splits the footprint per tier; serving admission
    charges only the HBM side (docs/memory.md, "Two accountings")."""
    eng = BSPEngine(pg, interpret=True, tiered=_budget(pg, "reference"),
                    block_e=BLOCK_E, win_blocks=WIN_BLOCKS)
    r = eng.residency_bytes()
    assert r["hbm_bytes"] + r["host_bytes"] == r["total_bytes"]
    assert r["host_bytes"] > 0
    all_res = BSPEngine(pg, interpret=True).residency_bytes()
    assert all_res["host_bytes"] == 0
    # streaming trades resident HBM for host DRAM plus the window buffers
    assert r["hbm_bytes"] < all_res["hbm_bytes"]
