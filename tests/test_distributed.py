"""Multi-device integration tests — run in subprocesses so the forced device
count never leaks into this process's jax runtime."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(ndev: int, module: str, *args, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    return subprocess.run([sys.executable, "-m", module, *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def test_bsp_engine_distributed_matches_local():
    r = _run(8, "repro.launch.selftest")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SELFTEST OK" in r.stdout


def test_train_driver_on_multi_device_mesh():
    """The end-to-end driver runs sharded over 4 devices."""
    r = _run(4, "repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
             "--steps", "6", "--batch", "4", "--seq", "32",
             "--ckpt-dir", "/tmp/ckpt_dist_test")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "done: final loss" in r.stdout
