"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.algorithms import (bfs, bfs_reference, pagerank,
                              pagerank_reference)


def random_graph(draw):
    n = draw(st.integers(8, 80))
    m = draw(st.integers(1, 6)) * n
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m).astype(np.int64)
    dst = rng.integers(0, n, size=m).astype(np.int64)
    return G.from_edge_list(src, dst, n)


graphs = st.composite(lambda draw: random_graph(draw))()


@settings(max_examples=12, deadline=None)
@given(g=graphs, parts=st.integers(1, 3),
       strategy=st.sampled_from(PT.STRATEGIES))
def test_bfs_engine_equals_oracle_on_random_graphs(g, parts, strategy):
    eng = BSPEngine(PT.partition(g, parts, strategy))
    got, steps = bfs(eng, source=0)
    want = bfs_reference(g, 0)
    np.testing.assert_array_equal(got, want)
    # level-monotonicity: supersteps == max finite level (or 1 if isolated)
    finite = want[np.isfinite(want)]
    assert steps >= finite.max()


@settings(max_examples=10, deadline=None)
@given(g=graphs, parts=st.integers(1, 3))
def test_pagerank_mass_and_oracle(g, parts):
    eng = BSPEngine(PT.partition(g, parts, PT.RAND))
    got = pagerank(eng, num_iterations=8)
    want = pagerank_reference(g, num_iterations=8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)
    # rank mass is bounded by 1 (dangling mass leaks, never grows)
    assert got.sum() <= 1.0 + 1e-4
    assert (got >= 0).all()


@settings(max_examples=12, deadline=None)
@given(g=graphs, parts=st.integers(1, 4),
       strategy=st.sampled_from(PT.STRATEGIES),
       seed=st.integers(0, 100))
def test_partition_conservation_properties(g, parts, strategy, seed):
    pg = PT.partition(g, parts, strategy, seed=seed)
    # every vertex exactly once
    seen = np.concatenate(pg.assignment.l2g)
    assert sorted(seen.tolist()) == list(range(g.num_vertices))
    # every edge exactly once
    assert int(pg.fwd.num_edges.sum()) == g.num_edges
    # reduction can only reduce boundary traffic
    assert pg.beta_with_reduction <= pg.beta_no_reduction + 1e-12
    # alpha sums to 1
    assert abs(pg.alpha.sum() - 1.0) < 1e-9 or g.num_edges == 0
