"""Silent-corruption defense: certifier soundness, invariant monitors,
checksummed exchange, and checkpoint integrity.

The contract under test (docs/robustness.md "Silent faults"):

- every registered certifier **accepts** the clean fixpoint its engine
  (or reference oracle) produces, across backends, and **rejects** a
  minimal single-value perturbation with a named invariant — the
  certifiers have teeth and no clean false positives;
- the in-loop ``InvariantMonitor`` fires on semiring violations
  (monotonicity regressions, illegal non-finite values, frontier
  regressions) and stays silent on legal transitions, including across a
  slot-refill ``rebase``;
- the checksummed exchange raises ``ExchangeCorruption`` when a payload
  is corrupted on the wire, and a clean replay is bitwise identical to
  an uninjected run;
- a torn checkpoint is rejected at *restore* time
  (``CheckpointCorruption``) and the previous snapshot restores bitwise;
- ``nonfinite_queries`` applies semiring-aware finiteness (``+inf`` is
  legal under min, poison under sum);
- the end-to-end ``--corrupt`` drill passes in a subprocess (the CI
  corruption-drill job).
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.algorithms.bc import bc_reference
from repro.algorithms.bfs import (BFS_PROGRAM, bfs, gather_batch,
                                  multi_source_state)
from repro.algorithms.cc import connected_components, symmetrize
from repro.algorithms.pagerank import pagerank_reference
from repro.algorithms.sssp import sssp
from repro.checkpoint.manager import CheckpointCorruption, CheckpointManager
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.runtime import (ExchangeCorruption, FaultInjector,
                           InvariantMonitor, ResultCertifier, certify, chaos,
                           monitor_for, nonfinite_queries)

SRC = str(Path(__file__).resolve().parents[1] / "src")

BACKENDS = [
    pytest.param({}, id="reference"),
    pytest.param({"fused": True, "block_e": 128}, id="fused"),
    pytest.param({"backend": "hybrid"}, id="hybrid"),
]


@pytest.fixture(scope="module")
def g():
    return G.rmat(7, 8, seed=0)


@pytest.fixture(scope="module")
def pg(g):
    return PT.partition(g, 2, "high")


# ---------------------------------------------------------------------------
# certifier soundness: clean fixpoints pass, minimal perturbations fail
# ---------------------------------------------------------------------------

class TestCertifierSoundness:
    @pytest.mark.parametrize("kw", BACKENDS)
    def test_bfs_accepts_engine_fixpoint(self, g, pg, kw):
        engine = BSPEngine(pg, **kw)
        levels, _ = bfs(engine, 3)
        v = certify("bfs", g, levels, source=3)
        assert v.ok, v.summary()

    def test_bfs_rejects_off_by_one(self, g, pg):
        levels, _ = bfs(BSPEngine(pg), 3)
        wrong = np.asarray(levels, np.float64).copy()
        vtx = int(np.flatnonzero(np.isfinite(wrong) & (wrong > 0))[0])
        wrong[vtx] -= 1.0
        v = certify("bfs", g, wrong, source=3)
        assert not v.ok
        assert {"edge_span", "parent_witness"} & {c.name for c in v.failed()}

    def test_bfs_rejects_fractional_level(self, g, pg):
        levels, _ = bfs(BSPEngine(pg), 3)
        wrong = np.asarray(levels, np.float64).copy()
        wrong[int(np.flatnonzero(np.isfinite(wrong) & (wrong > 0))[0])] += 0.5
        assert "integral_nonneg" in certify("bfs", g, wrong,
                                            source=3).reason()

    @pytest.mark.parametrize("kw", BACKENDS)
    def test_sssp_accepts_engine_fixpoint(self, kw, g):
        gw = g.with_uniform_weights(seed=1)
        pgw = PT.partition(gw, 2, "high")
        dists, _ = sssp(BSPEngine(pgw, **kw), 3)
        v = certify("sssp", gw, dists, source=3)
        assert v.ok, v.summary()

    def test_sssp_rejects_slack_distance(self, g):
        gw = g.with_uniform_weights(seed=1)
        pgw = PT.partition(gw, 2, "high")
        dists, _ = sssp(BSPEngine(pgw), 3)
        wrong = np.asarray(dists, np.float64).copy()
        vtx = int(np.flatnonzero(np.isfinite(wrong) & (wrong > 0))[0])
        wrong[vtx] += 1.0          # no in-edge achieves the inflated value
        v = certify("sssp", gw, wrong, source=3)
        assert not v.ok
        assert ({"no_relaxable_edge", "tight_witness"}
                & {c.name for c in v.failed()})

    def test_sssp_rejects_all_zeros(self, g):
        # no-relaxable-edge alone accepts the all-zeros state; the tight
        # witness kills it
        gw = g.with_uniform_weights(seed=1)
        v = certify("sssp", gw, np.zeros(gw.num_vertices), source=3)
        assert "tight_witness" in v.reason()

    def test_cc_accepts_engine_fixpoint(self, g):
        gs = symmetrize(g)
        pgs = PT.partition(gs, 2, "high")
        labels, _ = connected_components(BSPEngine(pgs))
        v = certify("cc", gs, labels)
        assert v.ok, v.summary()

    def test_cc_rejects_split_component(self, g):
        gs = symmetrize(g)
        pgs = PT.partition(gs, 2, "high")
        labels, _ = connected_components(BSPEngine(pgs))
        wrong = np.asarray(labels, np.float64).copy()
        vtx = int(np.flatnonzero(wrong < np.arange(gs.num_vertices))[0])
        wrong[vtx] = vtx           # non-root member claims to be its own root
        v = certify("cc", gs, wrong)
        assert not v.ok
        assert ({"endpoint_agreement", "root_fixpoint"}
                & {c.name for c in v.failed()})

    def test_pagerank_accepts_reference_fixpoint(self, g):
        rank = np.asarray(pagerank_reference(g, num_iterations=20))
        v = certify("pagerank", g, rank, num_iterations=20)
        assert v.ok, v.summary()

    def test_pagerank_rejects_mass_and_sign_violations(self, g):
        rank = np.asarray(pagerank_reference(g, num_iterations=20),
                          np.float64)
        assert "mass_conservation" in certify("pagerank", g, rank * 1.5,
                                              num_iterations=20).reason()
        neg = rank.copy()
        neg[0] = -0.1
        assert "finite_nonneg" in certify("pagerank", g, neg,
                                          num_iterations=20).reason()

    def test_bc_accepts_reference_and_rejects_perturbation(self, g):
        bcv = np.asarray(bc_reference(g, 3), np.float64)
        assert certify("bc", g, bcv, source=3).ok
        wrong = bcv.copy()
        wrong[int(np.argmax(wrong))] += 1.0
        v = certify("bc", g, wrong, source=3)
        assert "pair_recompute" in v.reason()

    def test_certifier_batch_and_unknown_algorithm(self, g, pg):
        levels, _ = bfs(BSPEngine(pg), 3)
        cert = ResultCertifier("bfs", g)
        verdicts = cert.certify_batch(np.stack([levels, levels]),
                                      sources=[3, 3])
        assert len(verdicts) == 2 and all(v.ok for v in verdicts)
        with pytest.raises(ValueError, match="no certifier registered"):
            ResultCertifier("nope", g)


# ---------------------------------------------------------------------------
# invariant monitor: fabricated window snapshots
# ---------------------------------------------------------------------------

def _snap(level, fin, steps, step):
    return dict(state={"level": np.asarray(level, np.float32)},
                finished=np.asarray(fin, bool),
                steps_q=np.asarray(steps, np.int32), step=step)


class TestInvariantMonitor:
    def test_monotone_run_is_silent(self):
        mon = InvariantMonitor(keys=("level",), combine="min", chunk=4)
        inf = np.inf
        mon.observe(_snap([[0, inf, inf], [0, inf, inf]],
                          [False, False], [4, 4], 4))
        rec = mon.observe(_snap([[0, 1, inf], [0, 1, 2]],
                                [False, True], [8, 6], 8))
        assert rec["violations"] == 0 and mon.violations == 0

    def test_monotonicity_regression_fires(self):
        mon = InvariantMonitor(keys=("level",), combine="min", chunk=4)
        mon.observe(_snap([[0, 1, 2]], [False], [4], 4))
        rec = mon.observe(_snap([[0, 3, 2]], [False], [8], 8))
        assert rec["violations"] == 1
        assert rec["checks"][0]["check"] == "monotonicity"
        assert rec["checks"][0]["slots"] == [0]

    def test_rebase_suppresses_refilled_slot_only(self):
        mon = InvariantMonitor(keys=("level",), combine="min", chunk=4)
        mon.observe(_snap([[0, 1], [0, 1]], [False, False], [4, 4], 4))
        mon.rebase([True, False])   # slot 0 refilled: new tenant, new frame
        rec = mon.observe(_snap([[5, 9], [0, 9]], [False, False],
                                [1, 8], 8))
        fired = {c["check"]: c["slots"] for c in rec["checks"]}
        assert fired == {"monotonicity": [1]}

    def test_finiteness_scoped_to_unfinished(self):
        mon = InvariantMonitor(keys=("level",), combine="min")
        nan = np.nan
        rec = mon.observe(_snap([[0, nan], [0, nan]], [False, True],
                                [2, 2], 2))
        fired = {c["check"]: c["slots"] for c in rec["checks"]}
        assert fired == {"finiteness": [0]}   # finished slot 1 is frozen

    def test_sum_combine_rejects_inf(self):
        mon = InvariantMonitor(keys=("level",), combine="sum")
        rec = mon.observe(_snap([[0, np.inf]], [False], [1], 1))
        assert rec["checks"][0]["check"] == "finiteness"

    def test_frontier_sanity(self):
        mon = InvariantMonitor(keys=("level",), combine="min", chunk=2)
        mon.observe(_snap([[0, 1]], [True], [4], 4))
        rec = mon.observe(_snap([[0, 1]], [False], [3], 6))
        fired = {c["check"] for c in rec["checks"]}
        assert fired == {"finished_regressed", "steps_delta"}
        rec = mon.observe(_snap([[0, 1]], [False], [9], 8))
        assert {c["check"] for c in rec["checks"]} == {"steps_delta"}

    def test_monitor_for_profiles(self):
        assert monitor_for("bfs", chunk=4).combine == "min"
        assert monitor_for("pagerank").combine == "sum"
        with pytest.raises(ValueError, match="no monitor profile"):
            monitor_for("nope")


# ---------------------------------------------------------------------------
# semiring-aware finiteness (quarantine net)
# ---------------------------------------------------------------------------

def test_nonfinite_queries_semantics():
    state = {"x": np.array([[0.0, 1.0], [0.0, np.inf], [np.nan, 1.0]],
                           np.float32)}
    assert nonfinite_queries(state, combine="min").tolist() == \
        [False, False, True]      # +inf is the legal "unreached" value
    assert nonfinite_queries(state, combine="sum").tolist() == \
        [False, True, True]       # any non-finite is an escaped overflow


# ---------------------------------------------------------------------------
# checksummed exchange + in-loop monitor, through the real engine
# ---------------------------------------------------------------------------

class TestExchangeIntegrity:
    @pytest.mark.parametrize("kw", BACKENDS[:2])   # hybrid has no wire here
    def test_corrupted_payload_detected_then_replay_is_bitwise(self, pg, kw):
        engine = BSPEngine(pg, **kw)

        def run():
            st, steps_q, _ = engine.execute(
                BFS_PROGRAM, {"level": multi_source_state(pg, [1, 2])},
                chunk=2)
            return gather_batch(pg, st["level"]), np.asarray(steps_q)

        clean, steps = run()
        inj = FaultInjector(
            sites={"exchange.payload": [{"step": 0, "flag": True}]})
        with chaos.active(inj):
            with pytest.raises(ExchangeCorruption):
                run()
        replay, replay_steps = run()   # the RestartPolicy path: rerun clean
        assert np.array_equal(replay, clean)
        assert np.array_equal(replay_steps, steps)

    def test_state_corruption_trips_in_loop_monitor(self, pg):
        engine = BSPEngine(pg)
        inj = FaultInjector(
            sites={"state.corrupt": [{"step": 0, "flag": True}]})
        with chaos.active(inj):
            _, _, info = engine.execute(
                BFS_PROGRAM, {"level": multi_source_state(pg, [1, 2])},
                chunk=2, max_chunks=4,
                monitor=monitor_for("bfs", chunk=2))
        assert info["monitors_fired"] >= 1

    def test_clean_run_fires_no_monitors(self, pg):
        engine = BSPEngine(pg)
        _, _, info = engine.execute(
            BFS_PROGRAM, {"level": multi_source_state(pg, [1, 2])},
            chunk=2, monitor=monitor_for("bfs", chunk=2))
        assert info["monitors_fired"] == 0


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------

def test_torn_checkpoint_rejected_at_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    rng = np.random.default_rng(0)
    tree = {"state": rng.standard_normal(64).astype(np.float32)}
    mgr.save_tree(0, tree)
    inj = FaultInjector(
        sites={"checkpoint.torn": [{"step": 1, "flag": True}]})
    with chaos.active(inj):
        mgr.save_tree(1, tree)
    with pytest.raises(CheckpointCorruption):
        mgr.restore_tree(tree)
    # verify=False documents the failure mode the checksums exist to stop
    _, torn = mgr.restore_tree(tree, verify=False)
    assert not np.array_equal(torn["state"], tree["state"])
    _, good = mgr.restore_tree(tree, step=0)
    assert np.array_equal(good["state"], tree["state"])


# ---------------------------------------------------------------------------
# the end-to-end drill (the CI corruption-drill job, in a subprocess)
# ---------------------------------------------------------------------------

def test_corrupt_drill_smoke():
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.graph_serve", "--smoke",
         "--corrupt", "--alg", "bfs"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "CORRUPT OK" in r.stdout
    assert "0 false positives" in r.stdout
