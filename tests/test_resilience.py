"""Fault-tolerant serving runtime: checkpointable chunked supersteps,
failure injection + recovery, and deadline-driven degradation.

The contract under test (docs/robustness.md):

- the chunked run mode is **bitwise identical** to the resident
  while_loop, per backend, and a chunk carry resumes mid-run — including
  through a ``CheckpointManager`` round trip and across a different device
  count (``repro.launch.ft_selftest`` subprocesses);
- engine rebuild after a restart reuses the module-level jit caches
  (restart ≠ recompile);
- injected faults (worker death, mid-mutation crash, kernel fault,
  poisoned query) are recovered through bounded retry + mutation-log
  replay with zero lost mutations and bitwise parity on surviving
  queries (the ``--chaos`` drill);
- the SLA layer: admission control rejects with a reason, NaN and
  over-budget queries are quarantined without pinning their batch, the
  degradation ladder falls back to the reference backend;
- malformed inputs fail fast with actionable errors instead of device
  asserts.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms.bfs import (BFS_PROGRAM, bfs_batched, gather_batch,
                                  multi_source_state)
from repro.checkpoint import CheckpointManager
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.core.dynamic import DynamicGraph
from repro.core.graph import MutationBatch
from repro.data.graphs import edge_stream
from repro.runtime import (AdmissionController, DegradationLadder,
                           FaultInjector, QuarantinePolicy, WorkerFailure,
                           chaos)

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(ndev: int, module: str, *args, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    return subprocess.run([sys.executable, "-m", module, *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def _setup(scale=7, parts=2, queries=3, seed=0):
    g = G.rmat(scale, 8, seed=seed)
    pg = PT.partition(g, parts, "high")
    rng = np.random.default_rng(seed + 1)
    sources = rng.integers(0, g.num_vertices, size=(queries, 1))
    return g, pg, sources


# ---------------------------------------------------------------------------
# chunked run mode: parity + resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [{}, {"fused": True, "block_e": 128},
                                {"backend": "hybrid"}],
                         ids=["reference", "fused", "hybrid"])
def test_chunked_matches_resident_loop(kw):
    """run_batched_chunked chains windows of the same compiled body — the
    fixpoint and per-query superstep counts are bitwise identical."""
    g, pg, sources = _setup()
    eng = BSPEngine(pg, **kw)
    state0 = {"level": jnp.asarray(multi_source_state(pg, sources))}
    ref_state, ref_steps = eng.execute(BFS_PROGRAM, dict(state0))
    st, sq, info = eng.execute(BFS_PROGRAM, dict(state0),
                                           chunk=2)
    np.testing.assert_array_equal(np.asarray(st["level"]),
                                  np.asarray(ref_state["level"]))
    np.testing.assert_array_equal(np.asarray(sq), np.asarray(ref_steps))
    assert info["finished"].all() and info["chunks"] >= 2


def test_chunk_carry_resumes_through_checkpoint(tmp_path):
    """Persist the carry after one chunk via save_tree, restore into a
    fresh engine, resume with a *different* chunk size — still bitwise."""
    g, pg, sources = _setup()
    eng = BSPEngine(pg)
    state0 = {"level": jnp.asarray(multi_source_state(pg, sources))}
    ref_state, ref_steps = eng.execute(BFS_PROGRAM, dict(state0))

    st, sq, info = eng.execute(BFS_PROGRAM, dict(state0),
                                           chunk=2, max_chunks=1)
    mgr = CheckpointManager(tmp_path)
    mgr.save_tree(info["final_step"],
                  {"state": st, "fin": info["finished"], "steps_q": sq},
                  extra={"step": info["final_step"]})

    like = {"state": {"level": np.zeros_like(np.asarray(st["level"]))},
            "fin": np.zeros(len(sources), bool),
            "steps_q": np.zeros(len(sources), np.int32)}
    step, tree = CheckpointManager(tmp_path).restore_tree(like)
    eng2 = BSPEngine(pg)          # a restarted process rebuilds the engine
    final, fsq, _ = eng2.execute(
        BFS_PROGRAM, tree["state"], chunk=3, start_step=step,
        fin=tree["fin"], steps_q=tree["steps_q"])
    np.testing.assert_array_equal(np.asarray(final["level"]),
                                  np.asarray(ref_state["level"]))
    np.testing.assert_array_equal(np.asarray(fsq), np.asarray(ref_steps))


def test_dynamic_chunked_parity_and_no_recompile_on_rebuild():
    """Chunked == resident on a mutated DynamicGraph, and rebuilding the
    engine (the restart path) adds zero chunk-jit cache entries."""
    from repro.core import bsp

    g, _, sources = _setup()
    dg = DynamicGraph(g, 2, "high", mutation_capacity=64)
    dg.apply_mutations(edge_stream(g, 1, 32, churn=1.0, seed=3)[0])
    eng = BSPEngine(dg)
    state0 = {"level": jnp.asarray(multi_source_state(eng.pg, sources))}
    ref_state, ref_steps = eng.execute(BFS_PROGRAM, dict(state0))
    st, sq, _ = eng.execute(BFS_PROGRAM, dict(state0),
                                        chunk=2)
    np.testing.assert_array_equal(np.asarray(st["level"]),
                                  np.asarray(ref_state["level"]))
    np.testing.assert_array_equal(np.asarray(sq), np.asarray(ref_steps))

    entries = bsp._run_dyn_chunk_jit._cache_size()
    eng2 = BSPEngine(dg)          # restart: same shapes, same trace
    st2, sq2, _ = eng2.execute(BFS_PROGRAM, dict(state0),
                                           chunk=2)
    np.testing.assert_array_equal(np.asarray(st2["level"]),
                                  np.asarray(st["level"]))
    assert bsp._run_dyn_chunk_jit._cache_size() == entries


@pytest.mark.parametrize("ndev", [1, 2])
def test_elastic_snapshot_resumes_on_fewer_devices(tmp_path, ndev):
    """A 4-device chunked snapshot resumes bitwise on 1 and 2 devices
    (forced host platform device counts, fresh subprocesses)."""
    snap = _run(4, "repro.launch.ft_selftest", "--mode", "snapshot",
                "--ckpt", str(tmp_path))
    assert snap.returncode == 0, snap.stderr[-3000:]
    assert "FT SNAPSHOT OK devices=4" in snap.stdout
    res = _run(ndev, "repro.launch.ft_selftest", "--mode", "resume",
               "--ckpt", str(tmp_path))
    assert res.returncode == 0, res.stderr[-3000:]
    assert f"FT RESUME OK devices=4->{ndev}" in res.stdout


# ---------------------------------------------------------------------------
# SLA: quarantine, admission, degradation
# ---------------------------------------------------------------------------

def test_quarantine_kills_nan_query_and_freezes_rest():
    """A NaN-poisoned query is force-finished at the first chunk boundary;
    the other queries' results are bitwise unaffected."""
    g, pg, sources = _setup(queries=3)
    eng = BSPEngine(pg)
    clean0 = {"level": jnp.asarray(multi_source_state(pg, sources))}
    ref_state, _ = eng.execute(BFS_PROGRAM, dict(clean0))

    poisoned = np.asarray(clean0["level"]).copy()
    poisoned[0] = np.nan
    quar = QuarantinePolicy()
    quar.begin(3)
    st, _, info = eng.execute(
        BFS_PROGRAM, {"level": jnp.asarray(poisoned)},
        chunk=2, on_chunk=quar.scan)
    assert [r["query"] for r in quar.quarantined] == [0]
    assert quar.quarantined[0]["reason"] == "nonfinite"
    assert info["finished"].all()
    np.testing.assert_array_equal(np.asarray(st["level"])[1:],
                                  np.asarray(ref_state["level"])[1:])


def test_quarantine_superstep_budget():
    """A query that won't converge inside the budget is quarantined with
    reason ``superstep_budget``; queries that finish in time are not."""
    # a directed path: BFS from vertex 0 needs num_vertices-1 supersteps,
    # from the tail it finishes immediately
    n = 24
    g = G.from_edge_list(np.arange(n - 1), np.arange(1, n), n)
    pg = PT.partition(g, 2, "rand")
    eng = BSPEngine(pg)
    state0 = {"level": jnp.asarray(
        multi_source_state(pg, np.array([[0], [n - 1]])))}
    quar = QuarantinePolicy(superstep_budget=4)
    quar.begin(2)
    _, sq, info = eng.execute(
        BFS_PROGRAM, state0, chunk=2, on_chunk=quar.scan)
    assert [(r["query"], r["reason"]) for r in quar.quarantined] == \
        [(0, "superstep_budget")]
    assert info["finished"].all()
    assert int(sq[0]) <= 6        # killed at a chunk boundary, not at n-1


def test_admission_rejects_overflow_with_reason():
    ctl = AdmissionController(capacity=2)
    assert ctl.offer(1) and ctl.offer(2)
    assert not ctl.offer(3)
    assert ctl.rejected[0]["reason"] == "queue_full"
    assert ctl.take(4) == [1, 2]


def test_serve_reports_admission_and_sla():
    g, pg, _ = _setup()
    eng = BSPEngine(pg)
    from repro.launch.graph_serve import serve
    sources = np.arange(8) % g.num_vertices
    rep = serve(eng, "bfs", sources, batch=2, deadline_ms=1e7,
                queue_capacity=4)
    assert rep["admission"]["admitted"] == 4
    assert rep["admission"]["rejected"] == 4
    assert rep["admission"]["reject_reasons"] == ["queue_full"]
    assert rep["sla"]["met"] == 4 and rep["sla"]["misses"] == 0


def test_degradation_ladder_falls_back_then_propagates_bugs():
    calls = []

    def flaky():
        calls.append("p")
        raise WorkerFailure("kernel died")

    ladder = DegradationLadder(retries=1)
    out = ladder.run(flaky, lambda: "reference", label="batch0")
    assert out == "reference" and calls == ["p", "p"]
    assert len(ladder.downgrades) == 1

    def buggy():
        raise ValueError("bad program")

    with pytest.raises(ValueError):        # not retryable, no fallback
        ladder.run(buggy, lambda: "reference")
    assert len(ladder.downgrades) == 1


# ---------------------------------------------------------------------------
# chaos sites + injector matching
# ---------------------------------------------------------------------------

def test_chaos_site_scoped_injection():
    inj = FaultInjector(sites={
        "exchange": [{"at": 2}],
        "worker.chunk": [{"shard": 1}],
        "query.poison": [{"round": 3, "flag": True}]})
    with chaos.active(inj):
        assert not chaos.visit("exchange", axis="parts")   # visit 0
        assert not chaos.visit("exchange", axis="parts")   # visit 1
        with pytest.raises(WorkerFailure):
            chaos.visit("exchange", axis="parts")          # visit 2: armed
        chaos.visit("exchange", axis="parts")      # specs fire once
        with pytest.raises(WorkerFailure):
            chaos.visit("worker.chunk", shards=(0, 1))
        assert not chaos.visit("query.poison", round=2)
        assert chaos.visit("query.poison", round=3)
    assert not chaos.registry._injectors          # context manager removes
    assert len(inj.site_fired) == 3


def test_injected_shard_failure_recovered_by_chunk_retry():
    """A worker death inside the chunked loop is retryable: rerun from the
    persisted carry, result still bitwise equal to the clean run."""
    g, pg, sources = _setup()
    eng = BSPEngine(pg)
    state0 = {"level": jnp.asarray(multi_source_state(pg, sources))}
    ref_state, ref_steps = eng.execute(BFS_PROGRAM, dict(state0))

    carry = dict(state=dict(state0), step=0,
                 fin=np.zeros(len(sources), bool),
                 steps_q=np.zeros(len(sources), np.int32))

    def on_chunk(snap):
        carry.update(snap)

    inj = FaultInjector(sites={"superstep.chunk": [{"chunk": 1}]})
    with chaos.active(inj):
        with pytest.raises(WorkerFailure):
            eng.execute(BFS_PROGRAM, dict(state0),
                                    chunk=2, on_chunk=on_chunk)
        st, sq, _ = eng.execute(   # resume from last good carry
            BFS_PROGRAM, carry["state"], chunk=2,
            start_step=carry["step"], fin=carry["fin"],
            steps_q=carry["steps_q"])
    np.testing.assert_array_equal(np.asarray(st["level"]),
                                  np.asarray(ref_state["level"]))
    np.testing.assert_array_equal(np.asarray(sq), np.asarray(ref_steps))


def test_chaos_drill_smoke():
    """The full ``--chaos`` drill: clean vs injected session, recovery,
    zero lost mutations, parity (the CI chaos job, in a subprocess)."""
    r = _run(1, "repro.launch.graph_serve", "--smoke", "--chaos",
             "--alg", "bfs", "--backend", "fused")
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    assert "CHAOS OK" in r.stdout


# ---------------------------------------------------------------------------
# input validation: fail fast with actionable errors
# ---------------------------------------------------------------------------

class TestValidation:
    def test_from_edge_list_rejects_out_of_range_ids(self):
        with pytest.raises(ValueError, match="must lie in"):
            G.from_edge_list([0, 7], [1, 2], num_vertices=4)
        with pytest.raises(ValueError, match="negative"):
            G.from_edge_list([0, -1], [1, 2], num_vertices=4)

    def test_from_edge_list_rejects_length_mismatch(self):
        with pytest.raises(ValueError, match="length mismatch"):
            G.from_edge_list([0, 1], [1], num_vertices=4)
        with pytest.raises(ValueError, match="weights"):
            G.from_edge_list([0, 1], [1, 2], num_vertices=4,
                             weights=np.array([1.0]))

    def test_from_edge_list_rejects_nan_weights(self):
        with pytest.raises(ValueError, match="finite"):
            G.from_edge_list([0, 1], [1, 2], num_vertices=4,
                             weights=np.array([1.0, np.nan]))

    def test_mutation_batch_rejects_malformed(self):
        with pytest.raises(ValueError):
            MutationBatch(src=np.array([0, 1]), dst=np.array([1]),
                          insert=np.array([True, True]))
        with pytest.raises(ValueError, match="negative"):
            MutationBatch(src=np.array([-2]), dst=np.array([1]),
                          insert=np.array([True]))
        with pytest.raises(ValueError, match="finite"):
            MutationBatch(src=np.array([0]), dst=np.array([1]),
                          insert=np.array([True]),
                          weight=np.array([np.inf]))

    def test_apply_mutations_rejects_out_of_range_vertex(self):
        g = G.rmat(6, 8, seed=0)
        dg = DynamicGraph(g, 2, "rand", mutation_capacity=8)
        bad = MutationBatch(src=np.array([g.num_vertices + 3]),
                            dst=np.array([0]), insert=np.array([True]))
        with pytest.raises(ValueError, match="num_vertices"):
            dg.apply_mutations(bad)
