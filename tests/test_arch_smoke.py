"""Per-architecture smoke tests (spec f): reduced config of the same family,
one forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.optim.adamw import AdamW

SMOKE_TRAIN = api.ShapeSpec("smoke_train", "train", 32, 4)


@pytest.mark.parametrize("arch_id", configs.all_ids())
def test_smoke_forward_and_train_step(arch_id):
    cfg = configs.get_smoke(arch_id)
    assert cfg.family == configs.get(arch_id).family
    model = api.build(cfg)
    params = model.init(jax.random.key(0))
    batch = api.synth_batch(cfg, SMOKE_TRAIN)

    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))

    opt = AdamW(warmup_steps=1)
    step = jax.jit(api.make_train_step(model, opt, microbatches=1))
    params2, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    for path, leaf in jax.tree_util.tree_leaves_with_path(params2):
        arr = np.asarray(leaf)
        assert not np.any(np.isnan(arr)), path


@pytest.mark.parametrize("arch_id", configs.all_ids())
def test_smoke_serve_path(arch_id):
    cfg = configs.get_smoke(arch_id)
    model = api.build(cfg)
    params = model.init(jax.random.key(1))
    batch = api.synth_batch(cfg, api.ShapeSpec("p", "prefill", 16, 2))
    logits, cache = model.prefill(params, batch, max_len=20)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, cache2 = model.decode_step(params, cache,
                                        jnp.zeros((2,), jnp.int32))
    assert logits2.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache2["len"]) == int(cache["len"]) + 1


@pytest.mark.parametrize("arch_id", configs.all_ids())
def test_full_config_matches_spec(arch_id):
    """Pin the exact public configuration values."""
    spec = {
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }[arch_id]
    cfg = configs.get(arch_id)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == spec
    if arch_id == "olmoe-1b-7b":
        assert (cfg.moe_experts, cfg.moe_top_k) == (64, 8)
    if arch_id == "qwen3-moe-235b-a22b":
        assert (cfg.moe_experts, cfg.moe_top_k) == (128, 8)
    if arch_id == "zamba2-2.7b":
        assert cfg.ssm_state == 64
    if arch_id == "gemma3-4b":
        assert (cfg.local_window, cfg.local_global_ratio) == (1024, 5)
