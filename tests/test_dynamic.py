"""Dynamic-graph subsystem: the incremental contract end to end.

The backbone is the parity matrix — every backend {reference, fused,
hybrid} × {1, 2, 4} forced host devices × {RAND, HIGH, LOW}, in
subprocesses (``repro.launch.dynamic_selftest``): apply an insert+delete
mutation stream in place, then assert mutate-then-rerun equals a
from-scratch partition+run of the mutated graph (bitwise for min/min-plus,
allclose for the f32 sum path), monotone warm-start parity vs cold, a
compaction round trip, and the zero-retrace guard across ≥3 mutation
batches.  The in-process tests cover the pieces that don't need a
multi-device runtime: ledger/mutation semantics, delta/outbox capacity and
auto-compaction, staleness signals, ``perf_model.should_resplit``, the
footprint fix, and the mutating / depth-bucketed serving smokes.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core import perf_model
from repro.core.bsp import BSPEngine
from repro.core.dynamic import CapacityError, DynamicGraph
from repro.core.graph import (EdgeLedger, MutationBatch,
                              apply_mutation_batches)
from repro.data.graphs import edge_stream

INTERP = dict(interpret=True)
SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(ndev: int, module: str, *args, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    return subprocess.run([sys.executable, "-m", module, *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


@pytest.mark.parametrize("ndev", [1, 2, 4])
def test_distributed_dynamic_parity(ndev):
    """reference/fused/hybrid × RAND/HIGH/LOW: mutate-then-rerun equals a
    from-scratch rebuild, warm-start parity, compaction round trip, and the
    retrace guard — per forced device count."""
    r = _run(ndev, "repro.launch.dynamic_selftest", "--parts", "4")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DYNAMIC SELFTEST OK" in r.stdout


# ---------------------------------------------------------------------------
# mutation semantics (ledger + canonical rebuild)
# ---------------------------------------------------------------------------

def test_ledger_fifo_delete_of_parallel_edges():
    """Deletes pop the *oldest* live instance of a (u, v) pair — base
    instances in CSR order, then inserts in arrival order."""
    g = G.from_edge_list(np.array([0, 0, 1]), np.array([1, 1, 2]), 4,
                        weights=np.array([5.0, 9.0, 1.0], np.float32))
    led = EdgeLedger(g)
    led.insert(0, 1, 2.0)
    assert led.alive_weights(0, 1) == [5.0, 9.0, 2.0]
    iid, w = led.delete(0, 1)
    assert w == 5.0                       # base instance first
    assert led.alive_weights(0, 1) == [9.0, 2.0]
    led.delete(0, 1)
    assert led.alive_weights(0, 1) == [2.0]   # then the insert
    with pytest.raises(KeyError):
        led.delete(3, 0)


def test_mutated_csr_matches_rebuild_oracle():
    g = G.rmat(7, 4, seed=3).with_uniform_weights(seed=1)
    stream = edge_stream(g, 3, 16, churn=0.6, seed=5)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=64)
    for b in stream:
        dg.apply_mutations(b)
    want = apply_mutation_batches(g, stream)
    got = dg.mutated_csr()
    np.testing.assert_array_equal(got.row_ptr, want.row_ptr)
    np.testing.assert_array_equal(got.col, want.col)
    np.testing.assert_array_equal(got.weights, want.weights)


def test_edge_stream_is_deterministic_and_deletes_are_valid():
    g = G.rmat(7, 4, seed=3)
    a = edge_stream(g, 4, 32, churn=0.5, seed=9)
    b = edge_stream(g, 4, 32, churn=0.5, seed=9)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.src, y.src)
        np.testing.assert_array_equal(x.dst, y.dst)
        np.testing.assert_array_equal(x.insert, y.insert)
    # replay never raises (every delete targets a live instance)
    apply_mutation_batches(g, a)
    assert any(x.num_deletes for x in a) and any(x.num_inserts for x in a)


def test_edge_stream_symmetric_keeps_graph_symmetric():
    from repro.algorithms.cc import symmetrize

    gs = symmetrize(G.rmat(7, 4, seed=3))
    stream = edge_stream(gs, 3, 20, churn=0.5, symmetric=True, seed=4)
    g2 = apply_mutation_batches(gs, stream)
    a = G.to_dense(g2)
    np.testing.assert_array_equal(a, a.T)


# ---------------------------------------------------------------------------
# capacity, spare slots, compaction triggers
# ---------------------------------------------------------------------------

def test_batch_larger_than_capacity_raises():
    g = G.rmat(6, 4, seed=3)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=8)
    big = MutationBatch(np.zeros(9, np.int64), np.ones(9, np.int64),
                        np.ones(9, bool))
    with pytest.raises(CapacityError):
        dg.apply_mutations(big)


def test_delta_overflow_auto_compacts():
    g = G.rmat(6, 4, seed=3)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=16, delta_slots=16)
    rng = np.random.default_rng(0)
    applied = []
    for i in range(6):                     # 6×16 inserts >> 16 delta slots
        b = MutationBatch(rng.integers(0, g.num_vertices, 16),
                          rng.integers(0, g.num_vertices, 16),
                          np.ones(16, bool))
        applied.append(b)
        dg.apply_mutations(b)
    assert dg.compactions >= 1             # overflow forced a compaction
    want = apply_mutation_batches(g, applied)
    got = dg.mutated_csr()
    np.testing.assert_array_equal(got.col, want.col)


def test_spare_outbox_slot_assignment_routes_new_boundary_edge():
    """An inserted cross-partition edge to a previously-unmessaged remote
    vertex claims a spare slot; the symmetric inbox entry must route its
    messages (BFS reaches through the new edge)."""
    from repro.algorithms.bfs import bfs

    # a path graph partitioned in halves: plenty of unmessaged remotes
    n = 32
    src = np.arange(n - 1)
    g = G.from_edge_list(src, src + 1, n)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=8)
    eng = BSPEngine(dg, **INTERP)
    part_of = dg.pg.assignment.part_of
    # find (u, v) in different partitions with no existing edge u->v
    u = int(np.argmax(part_of == 0))
    v = int(np.argmax(part_of == 1))
    dg.apply_mutations(MutationBatch([u], [v], [True]))
    lv, _ = bfs(eng, u)
    assert np.isfinite(lv[v]) and lv[v] == 1.0


def test_staleness_signals_and_should_compact():
    g = G.rmat(7, 4, seed=3)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=32, delta_slots=64)
    assert not dg.should_compact()
    rng = np.random.default_rng(1)
    for _ in range(3):
        dg.apply_mutations(MutationBatch(
            rng.integers(0, g.num_vertices, 32),
            rng.integers(0, g.num_vertices, 32), np.ones(32, bool)))
    s = dg.staleness()
    assert s["delta_occupancy"] > 0.5
    assert dg.should_compact()
    dg.compact()
    assert dg.staleness()["delta_occupancy"] == 0.0
    assert isinstance(dg.skew_drift(), float)


# ---------------------------------------------------------------------------
# perf_model.should_resplit
# ---------------------------------------------------------------------------

def test_should_resplit_false_when_current_is_argmin():
    from repro.core.hybrid import edge_max_ranks

    g = G.rmat(9, 4, seed=13)
    ranks = edge_max_ranks(g)
    cands = perf_model.k_dense_candidates(g.num_vertices)
    best, _ = perf_model.choose_k_dense(ranks, g.num_edges, cands)
    resplit, info = perf_model.should_resplit(ranks, g.num_edges, cands,
                                              current_k=best)
    assert not resplit and info["improvement"] <= 1e-12
    assert info["best_k"] == best


def test_engine_should_resplit_hybrid_wiring():
    """The engine-level vote: False for non-hybrid backends and for a
    fresh (undrifted) hybrid split; the info record lands on the engine."""
    g = G.rmat(8, 4, seed=13)
    dg = DynamicGraph(g, 2, PT.HIGH, mutation_capacity=16)
    assert not BSPEngine(dg, **INTERP).should_resplit_hybrid()
    hyb = BSPEngine(DynamicGraph(g, 2, PT.HIGH, mutation_capacity=16),
                    backend="hybrid", **INTERP)
    assert not hyb.should_resplit_hybrid()    # freshly planned = argmin
    assert hyb.last_resplit_info["improvement"] <= 1e-12


def test_should_compact_skew_drift_signal():
    g = G.rmat(7, 4, seed=3)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=32,
                      delta_slots=4096)
    assert not dg.should_compact(max_skew_drift=1e9)
    # an impossible-to-miss threshold trips as soon as any drift exists
    stream = edge_stream(g, 2, 32, churn=1.0, skew=2.0, seed=1)
    for b in stream:
        dg.apply_mutations(b)
    assert dg.should_compact(max_skew_drift=0.0) or dg.skew_drift() == 0.0


def test_should_resplit_fires_on_drifted_split():
    """Evaluating a deliberately bad split against the ladder must trip the
    threshold; a huge threshold must suppress it."""
    from repro.core.hybrid import edge_max_ranks

    g = G.rmat(9, 4, seed=13)
    ranks = edge_max_ranks(g)
    cands = perf_model.k_dense_candidates(g.num_vertices)
    best, table = perf_model.choose_k_dense(ranks, g.num_edges, cands)
    worst = max(table, key=lambda r: r["makespan"])["k_dense"]
    assert worst != best
    resplit, info = perf_model.should_resplit(ranks, g.num_edges, cands,
                                              current_k=worst)
    assert resplit and info["best_k"] == best
    quiet, _ = perf_model.should_resplit(ranks, g.num_edges, cands,
                                         current_k=worst, threshold=1e9)
    assert not quiet


# ---------------------------------------------------------------------------
# footprint fix (capacity planning must see the dynamic buffers)
# ---------------------------------------------------------------------------

def test_memory_footprint_accounts_delta_and_tombstones():
    g = G.rmat(7, 4, seed=3).with_uniform_weights(seed=1)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=32)
    static = PT.memory_footprint_bytes(dg.pg)
    dyn = PT.memory_footprint_bytes(dg.pg, dynamic=dg)
    for p in static:
        assert "delta" in dyn[p] and "tombstone" in dyn[p]
        assert dyn[p]["delta"] == dg.delta_slots * (2 * 4 + 4)  # weighted
        assert dyn[p]["tombstone"] == dg.pg.fwd.e_max
        assert dyn[p]["total"] > static[p]["total"]


# ---------------------------------------------------------------------------
# incremental API + retrace guard (single device, quick)
# ---------------------------------------------------------------------------

def test_execute_incremental_returns_none_without_incremental_form():
    from repro.algorithms.pagerank import make_pagerank_program

    g = G.rmat(6, 4, seed=3)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=8)
    eng = BSPEngine(dg, **INTERP)
    program = make_pagerank_program(g.num_vertices)
    assert program.incremental is None
    assert eng.execute(program, {},
                       incremental=np.zeros((2, 8), bool)) is None


def test_warm_start_bitwise_and_fewer_supersteps():
    from repro.algorithms import bfs_batched, bfs_incremental

    g = G.rmat(8, 4, seed=13)
    dg = DynamicGraph(g, 4, PT.HIGH, mutation_capacity=64)
    eng = BSPEngine(dg, **INTERP)
    sources = [0, 5, 40]
    prev, _ = bfs_batched(eng, sources)
    mark = dg.mark()
    stream = edge_stream(g, 1, 24, churn=1.0, seed=2)
    dg.apply_mutations(stream[0])
    dirty, monotone = dg.dirty_since(mark)
    assert monotone
    warm, wsteps = bfs_incremental(eng, prev, dirty)
    cold, csteps = bfs_batched(eng, sources)
    np.testing.assert_array_equal(warm, cold)           # bitwise
    assert int(wsteps.max()) <= int(csteps.max())


def test_dirty_since_reports_deletions_as_non_monotone():
    g = G.rmat(6, 4, seed=3)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=16)
    mark = dg.mark()
    dg.apply_mutations(MutationBatch([1], [2], [True]))
    _, mono = dg.dirty_since(mark)
    assert mono
    dg.apply_mutations(MutationBatch([int(g.edge_sources()[0])],
                                     [int(g.col[0])], [False]))
    dirty, mono = dg.dirty_since(mark)
    assert not mono and dirty[1]


def test_three_mutation_batches_do_not_retrace():
    from repro.core import bsp
    from repro.algorithms import bfs_batched

    g = G.rmat(7, 4, seed=2)
    dg = DynamicGraph(g, 2, PT.RAND, mutation_capacity=32)
    eng = BSPEngine(dg, **INTERP)
    bfs_batched(eng, [0, 1, 2, 3])                       # compiles
    before = bsp._run_dyn_jit._cache_size()
    for b in edge_stream(g, 3, 16, churn=0.7, seed=6):
        dg.apply_mutations(b)
        bfs_batched(eng, [4, 5, 6, 7])
    assert bsp._run_dyn_jit._cache_size() == before
    assert dg.compactions == 0


@pytest.mark.parametrize("backend", ["reference", "fused", "hybrid"])
def test_reverse_direction_deltas_bc_cold(backend):
    """BC exercises the *reverse* edge arrays: tombstones/deltas must track
    both directions (non-monotone program → cold rerun on the mutated
    layout)."""
    from repro.algorithms import betweenness_centrality_batched

    kw = {"reference": dict(), "fused": dict(fused=True, block_e=256),
          "hybrid": dict(backend="hybrid")}[backend]
    g = G.rmat(7, 4, seed=13)
    stream = edge_stream(g, 2, 20, churn=0.6, seed=3)
    g2 = apply_mutation_batches(g, stream)
    dg = DynamicGraph(g, 2, PT.HIGH, include_reverse=True,
                      mutation_capacity=64)
    eng = BSPEngine(dg, **kw, **INTERP)
    for b in stream:
        dg.apply_mutations(b)
    got, _ = betweenness_centrality_batched(eng, [0, 5])
    want, _ = betweenness_centrality_batched(
        BSPEngine(PT.partition(g2, 2, PT.HIGH, include_reverse=True),
                  **kw, **INTERP), [0, 5])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# serving driver smokes
# ---------------------------------------------------------------------------

def test_graph_serve_mutating_smoke(tmp_path):
    """The mutating driver: edges/s applied, warm-vs-cold superstep
    savings, zero retraces, end to end."""
    import json

    from repro.launch.graph_serve import main

    out = tmp_path / "serve_mut.json"
    assert main(["--smoke", "--mutate", "--churn", "1.0", "--alg", "bfs",
                 "--backend", "reference", "--out", str(out)]) == 0
    rep = json.loads(out.read_text())
    assert rep["retraces"] == 0
    assert rep["mutation_edges_per_sec"] > 0
    assert rep["incremental_steps"] is not None
    assert rep["incremental_steps"] <= rep["cold_steps"]
    for rnd in rep["per_round"]:
        assert rnd["refresh"].get("bitwise_equal", True)


def test_graph_serve_depth_buckets_smoke(tmp_path):
    import json

    from repro.launch.graph_serve import main

    out = tmp_path / "serve_buckets.json"
    assert main(["--smoke", "--depth-buckets", "2", "--alg", "bfs",
                 "--backend", "reference", "--out", str(out)]) == 0
    rep = json.loads(out.read_text())
    assert len(rep["buckets"]) == 2
    for b in rep["buckets"]:
        assert b["bucketed_p99_ms"] > 0 and b["baseline_p99_ms"] > 0
