"""Continuous batching: slot refill in the resident loop == drain-batch.

The serving contract for PR 7: a ``ServeSession`` that compacts finished
queries out of the ``[Q, Pl, v_max]`` state at chunk boundaries and
refills freed slots from the stream must (a) return every query's result
**bitwise** equal to plain drain-batch ``run_batched`` — per backend
{reference, fused, hybrid} and on {1, 2, 4} forced devices via the
subprocess selftest — (b) never retrace after warmup across >= 3 refill
cycles, (c) compose with mutations, quarantine, admission control and
the degradation ladder, and (d) checkpoint/restore *mid-refill* with the
occupancy mask and per-slot query ids riding the carry.  The
``engine.execute`` facade and ``ServeConfig`` validation (the
api_redesign satellites) are pinned here too.
"""
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.checkpoint import CheckpointManager
from repro.runtime import (DegradationLadder, FaultInjector,
                           QuarantinePolicy, ServeSession, WorkerFailure,
                           chaos, drain_reference, serve_with_restarts)

INTERP = dict(interpret=True)
SRC = str(Path(__file__).resolve().parents[1] / "src")

BACKENDS = {
    "reference": dict(),
    "fused": dict(fused=True, block_e=256),
    "hybrid": dict(backend="hybrid"),
}

SLOTS = 4


@pytest.fixture(scope="module")
def graph():
    return G.rmat(8, 6, seed=13).with_uniform_weights(seed=1)


@pytest.fixture(scope="module")
def pg(graph):
    return PT.partition(graph, 4, PT.HIGH)


@pytest.fixture(scope="module")
def stream(graph):
    rng = np.random.default_rng(3)
    deg = graph.out_degrees()
    # hub + fringe + random: mixed convergence, so slots free at
    # different boundaries and refill asymmetrically
    return np.concatenate([
        [int(np.argmax(deg)), int(np.argmin(deg))],
        rng.integers(0, graph.num_vertices, size=8 * SLOTS - 2)])


@pytest.mark.parametrize("backend", sorted(BACKENDS))
@pytest.mark.parametrize("alg", ["bfs", "sssp"])
def test_refill_bitwise_equals_drain_batch(pg, stream, backend, alg):
    """>= 4xQ stream through one resident session, per backend: every
    completion bitwise equal to its drain-batch row, every slot refilled,
    zero retraces after warmup."""
    eng = BSPEngine(pg, **BACKENDS[backend], **INTERP)
    want = drain_reference(eng, alg, stream, SLOTS)
    session = ServeSession(eng, alg, slots=SLOTS, chunk=2)
    qids = session.submit(stream)
    rep = session.drain()
    results = {r["query"]: r["result"] for r in session.poll()}
    assert len(results) == len(stream)
    for qid, row in zip(qids, want):
        np.testing.assert_array_equal(results[qid], row)
    assert rep["min_slot_refills"] >= 3
    assert rep["retraces"] == 0, rep
    assert rep["refills"] == len(stream) - SLOTS


@pytest.mark.parametrize("ndev", [1, 2, 4])
def test_distributed_refill_parity(ndev):
    """Distributed engines (votes psum'd across shards): subprocess
    selftest so the forced device count never leaks."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=SRC + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.continuous_selftest",
         "--parts", "4", "--batch", "4"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "CONTINUOUS SELFTEST OK" in r.stdout


def test_zero_retrace_across_refill_cycles(pg, graph):
    """>= 3 full refill cycles of every slot; the chunk jit and the slot
    swap compile once, then the cache must not grow."""
    eng = BSPEngine(pg)
    rng = np.random.default_rng(7)
    session = ServeSession(eng, "bfs", slots=SLOTS, chunk=2)
    session.submit(rng.integers(0, graph.num_vertices, size=8 * SLOTS))
    session.drain()
    assert int(session.slot_refills.min()) >= 3
    assert session.retraces() == 0
    # a second session over the same engine is fully warm: entries stay
    entries = session._cache_entries()
    s2 = ServeSession(eng, "bfs", slots=SLOTS, chunk=2)
    s2.submit(rng.integers(0, graph.num_vertices, size=4 * SLOTS))
    s2.drain()
    assert s2._cache_entries() == entries


def test_refill_composed_with_mutate(graph):
    """Mutations land in the same resident engine that is continuously
    serving: drain -> mutate -> drain waves, parity per graph version,
    zero retraces (the dynamic chunk jit carries the payload)."""
    from repro.core.dynamic import DynamicGraph
    from repro.data.graphs import edge_stream

    dg = DynamicGraph(graph, 4, "high", mutation_capacity=64)
    eng = BSPEngine(dg, **INTERP)
    mstream = edge_stream(graph, 2, 32, churn=1.0, seed=5)
    rng = np.random.default_rng(9)
    session = ServeSession(eng, "bfs", slots=SLOTS, chunk=2)
    for wave in range(3):
        if wave > 0:
            session.mutate(mstream[wave - 1])
        srcs = rng.integers(0, graph.num_vertices, size=2 * SLOTS)
        qids = session.submit(srcs)
        session.drain()
        want = drain_reference(eng, "bfs", srcs, SLOTS)
        results = {r["query"]: r["result"] for r in session.poll()}
        for qid, row in zip(qids, want):
            np.testing.assert_array_equal(results[qid], row)
    assert session.retraces() == 0


def test_checkpoint_restore_mid_refill(pg, stream):
    """Snapshot after refills have begun; a fresh session restores the
    occupancy (mask + per-slot query ids + step frames) and finishes with
    results bitwise equal to the uninterrupted run."""
    eng = BSPEngine(pg)
    want = drain_reference(eng, "sssp", stream, SLOTS)

    s1 = ServeSession(eng, "sssp", slots=SLOTS, chunk=2)
    qids = s1.submit(stream)
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=3)
        while s1.refills == 0:
            assert s1.step(), "drained before any refill"
        s1.snapshot(mgr, 1)
        assert s1.occupied.any()

        s2 = ServeSession(BSPEngine(pg), "sssp", slots=SLOTS, chunk=2)
        s2.restore(mgr)
        assert s2.occupied.tolist() == s1.occupied.tolist()
        assert s2.slot_query.tolist() == s1.slot_query.tolist()
        assert s2.refills == s1.refills
        while not s2.drained():
            s2.step()
    results = {r["query"]: r["result"] for r in s2.poll()}
    assert len(results) == len(stream)
    for qid, row in zip(qids, want):
        np.testing.assert_array_equal(results[qid], row)


def test_serve_with_restarts_resumes_refilled_occupancy(pg, stream):
    """An injected worker fault mid-session: the factory rebuilds the
    session, restore resumes the refilled occupancy, results stay
    bitwise."""
    want = drain_reference(BSPEngine(pg), "bfs", stream, SLOTS)

    def make_session():
        s = ServeSession(BSPEngine(pg), "bfs", slots=SLOTS, chunk=2)
        s.submit(stream)
        return s

    with tempfile.TemporaryDirectory() as td:
        inj = FaultInjector(sites={"superstep.chunk": [{"at": 4}]})
        with chaos.active(inj):
            session, summary = serve_with_restarts(
                make_session, CheckpointManager(td, keep=3))
    assert summary["failures"] == 1
    assert session.refills > 0
    results = {r["query"]: r["result"] for r in session.poll()}
    assert len(results) == len(stream)
    for qid, row in enumerate(want):
        np.testing.assert_array_equal(results[qid], row)


def test_ladder_handoff_carries_occupancy(pg, stream):
    """DegradationLadder threads the session API: primary dies, the
    fallback session adopts the refilled carry and finishes bitwise."""
    want = drain_reference(BSPEngine(pg), "bfs", stream, SLOTS)

    class Dying(ServeSession):
        def step(self):
            raise WorkerFailure("injected primary death")

    primary = Dying(BSPEngine(pg, fused=True, block_e=256, **INTERP),
                    "bfs", slots=SLOTS, chunk=2)
    fallback = ServeSession(BSPEngine(pg), "bfs", slots=SLOTS, chunk=2)
    primary.submit(stream)
    ladder = DegradationLadder(retries=1)
    primary.step_with_fallback(fallback, ladder)
    assert len(ladder.downgrades) == 1
    while not fallback.drained():
        fallback.step()
    results = {r["query"]: r["result"] for r in fallback.poll()}
    assert len(results) == len(stream)
    for qid, row in enumerate(want):
        np.testing.assert_array_equal(results[qid], row)


def test_quarantined_slot_is_refilled(pg, stream):
    """A tiny superstep budget quarantines deep queries; their slots go
    to the next tenants in the same window, and non-quarantined results
    stay bitwise."""
    want = drain_reference(BSPEngine(pg), "bfs", stream, SLOTS)
    quar = QuarantinePolicy(superstep_budget=2)
    session = ServeSession(BSPEngine(pg), "bfs", slots=SLOTS, chunk=2,
                           quarantine=quar)
    session.submit(stream)
    rep = session.drain()
    results = session.poll()
    assert len(results) == len(stream)          # quarantined still complete
    assert rep["quarantined"], "budget=2 should have quarantined something"
    assert rep["refills"] == len(stream) - SLOTS
    for r in results:
        if not r["quarantined"]:
            np.testing.assert_array_equal(r["result"], want[r["query"]])
    # reports name query ids, not slot indices
    assert {q["query"] for q in quar.quarantined} == set(rep["quarantined"])


def test_admission_capacity_rejects_with_reason(pg, stream):
    session = ServeSession(BSPEngine(pg), "bfs", slots=SLOTS, chunk=2,
                           queue_capacity=6)
    qids = session.submit(stream)
    rejected = [q for q in qids if q is None]
    assert len(rejected) == len(stream) - 6
    assert all(r["reason"] == "queue_full"
               for r in session.admission.rejected)
    session.drain()
    assert len(session.poll()) == 6


def test_depth_scheduler_admits_shallow_first(graph, pg):
    deg = graph.out_degrees()
    session = ServeSession(BSPEngine(pg), "bfs", slots=2, chunk=2,
                           scheduler="depth",
                           depth_key=lambda s: -int(deg[s]))
    lo, hi = int(np.argmin(deg)), int(np.argmax(deg))
    session.submit([lo, lo, lo, hi])
    # the hub (shallow BFS) must jump the fringe queries in the queue
    assert session.admission._queue[0][0][1] == hi
    session.drain()
    assert len(session.poll()) == 4

    with pytest.raises(ValueError, match="depth_key"):
        ServeSession(BSPEngine(pg), "bfs", slots=2, scheduler="depth")


# ---------------------------------------------------------------------------
# api_redesign satellites: execute facade + ServeConfig validation
# ---------------------------------------------------------------------------

def test_execute_facade_routes_all_modes(pg):
    from repro.algorithms.bfs import BFS_PROGRAM, multi_source_state

    eng = BSPEngine(pg)
    state = {"level": multi_source_state(pg, [1, 2])}
    want_state, want_steps = eng._run_batched(BFS_PROGRAM, dict(state))
    got_state, got_steps = eng.execute(BFS_PROGRAM, dict(state))
    np.testing.assert_array_equal(np.asarray(got_state["level"]),
                                  np.asarray(want_state["level"]))
    np.testing.assert_array_equal(np.asarray(got_steps),
                                  np.asarray(want_steps))

    # chunked mode returns the chunked triple
    _, steps_q, info = eng.execute(BFS_PROGRAM, dict(state), chunk=2)
    assert info["chunks"] >= 1 and info["refilled"] == 0
    np.testing.assert_array_equal(np.asarray(steps_q),
                                  np.asarray(want_steps))

    # fixed-step mode (num_steps=) routes to _run_fixed_batched
    want = eng._run_fixed_batched(BFS_PROGRAM, 3, dict(state))
    got = eng.execute(BFS_PROGRAM, dict(state), num_steps=3)
    np.testing.assert_array_equal(np.asarray(got["level"]),
                                  np.asarray(want["level"]))


def test_execute_facade_actionable_errors(pg):
    from repro.algorithms.bfs import BFS_PROGRAM, multi_source_state

    eng = BSPEngine(pg)
    state = {"level": multi_source_state(pg, [1])}
    with pytest.raises(ValueError, match="mutually exclusive"):
        eng.execute(BFS_PROGRAM, state, num_steps=3, chunk=2)
    with pytest.raises(ValueError, match="chunk="):
        eng.execute(BFS_PROGRAM, state, on_chunk=lambda s: None)
    with pytest.raises(ValueError, match="chunk="):
        eng.execute(BFS_PROGRAM, state, max_chunks=2)


def test_serve_config_validation():
    from repro.launch.graph_serve import ServeConfig

    ServeConfig(continuous=True, mutate=True).validate()      # composes
    ServeConfig(continuous=True, deadline_ms=50.0,
                queue_capacity=8, depth_buckets=2).validate()  # composes
    with pytest.raises(ValueError, match="--continuous"):
        ServeConfig(mutate=True, deadline_ms=50.0).validate()
    with pytest.raises(ValueError, match="--continuous"):
        ServeConfig(depth_buckets=2, queue_capacity=8).validate()
    with pytest.raises(ValueError, match="chaos"):
        ServeConfig(chaos=True, continuous=True).validate()
    with pytest.raises(ValueError, match="step-translatable"):
        ServeConfig(continuous=True, alg="bc").validate()
    with pytest.raises(ValueError, match="drain-batch"):
        from repro.algorithms import continuous_form
        continuous_form("ppr")
