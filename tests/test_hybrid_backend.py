"""Hybrid degree-split backend == reference path, for every algorithm.

``BSPEngine(backend="hybrid")`` must be a pure execution-path substitution:
``min``-combine algorithms (BFS, SSSP, CC) are compared *exactly* — min is
order-insensitive — while ``sum``-combine algorithms (PageRank, BC) are
compared to f32 tolerances, since the dense-block/ELL split reassociates the
sums.  Also covers the perf-model split choice (chosen |H| must be the
argmin of predicted makespan), the push/pull direction switch, and the
reference fallback for ineligible programs.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core import perf_model
from repro.core.bsp import BSPEngine
from repro.core.hybrid import auto_degree_split, edge_max_ranks
from repro.algorithms.bfs import BFS_PROGRAM, bfs
from repro.algorithms.sssp import sssp
from repro.algorithms.pagerank import pagerank
from repro.algorithms.cc import connected_components, symmetrize
from repro.algorithms.bc import betweenness_centrality

INTERP = dict(interpret=True)
SCALE = 10
PARTS = 4


@pytest.fixture(scope="module", params=PT.STRATEGIES)
def engines(request):
    """(reference, hybrid) engine pair per partitioning strategy."""
    g = G.rmat(SCALE, 4, seed=13).with_uniform_weights(seed=1)
    pg = PT.partition(g, PARTS, request.param, include_reverse=True)
    return (BSPEngine(pg, **INTERP),
            BSPEngine(pg, backend="hybrid", **INTERP))


@pytest.fixture(scope="module", params=PT.STRATEGIES)
def cc_engines(request):
    g = symmetrize(G.rmat(SCALE, 4, seed=13))
    pg = PT.partition(g, PARTS, request.param)
    return (BSPEngine(pg, **INTERP),
            BSPEngine(pg, backend="hybrid", **INTERP))


def test_bfs_parity(engines):
    ref, hyb = engines
    lr, sr = bfs(ref, 0)
    lh, sh = bfs(hyb, 0)
    np.testing.assert_array_equal(lr, lh)   # min combine: exact
    assert sr == sh


def test_sssp_parity(engines):
    ref, hyb = engines
    dr, _ = sssp(ref, 0)
    dh, _ = sssp(hyb, 0)
    np.testing.assert_array_equal(dr, dh)   # min combine: exact


def test_pagerank_parity(engines):
    ref, hyb = engines
    pr = pagerank(ref, num_iterations=10)
    ph = pagerank(hyb, num_iterations=10)
    np.testing.assert_allclose(pr, ph, rtol=1e-5, atol=1e-8)


def test_bc_parity(engines):
    ref, hyb = engines
    br, sr = betweenness_centrality(ref, 0)
    bh, sh = betweenness_centrality(hyb, 0)
    assert sr == sh
    np.testing.assert_allclose(br, bh, rtol=1e-4, atol=1e-4)


def test_cc_parity(cc_engines):
    ref, hyb = cc_engines
    cr, _ = connected_components(ref)
    ch, _ = connected_components(hyb)
    np.testing.assert_array_equal(cr, ch)   # min combine: exact


def test_bc_runs_without_include_reverse():
    """Hybrid builds its own reverse split, so BC needs no pg.rev."""
    g = G.rmat(SCALE, 4, seed=13)
    ref = BSPEngine(PT.partition(g, PARTS, PT.RAND, include_reverse=True),
                    **INTERP)
    hyb = BSPEngine(PT.partition(g, PARTS, PT.RAND), backend="hybrid",
                    **INTERP)
    br, _ = betweenness_centrality(ref, 0)
    bh, _ = betweenness_centrality(hyb, 0)
    np.testing.assert_allclose(br, bh, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# split decision: the perf model picks |H| (paper Eq. 4 role)
# ---------------------------------------------------------------------------

def test_chosen_k_dense_is_argmin_of_predicted_makespan():
    g = G.rmat(SCALE, 4, seed=13)
    cands = perf_model.k_dense_candidates(g.num_vertices)
    k, table = perf_model.choose_k_dense(edge_max_ranks(g), g.num_edges,
                                         cands)
    makespans = {rec["k_dense"]: rec["makespan"] for rec in table}
    assert set(makespans) == set(cands)
    assert k == min(makespans, key=makespans.get)


def test_engine_plan_matches_model_argmin():
    g = G.rmat(SCALE, 4, seed=13)
    eng = BSPEngine(PT.partition(g, PARTS, PT.HIGH), backend="hybrid",
                    **INTERP)
    plan = eng.hybrid_plan()
    best = min(plan["table"], key=lambda rec: rec["makespan"])
    assert plan["k_dense"] == best["k_dense"]
    assert plan["mode"] in ("sparse", "dense", "hybrid")


def test_auto_degree_split_attaches_table():
    g = G.rmat(SCALE, 4, seed=13)
    hg = auto_degree_split(g)
    assert hg.model_table is not None
    best = min(hg.model_table, key=lambda rec: rec["makespan"])
    assert hg.k_dense == best["k_dense"]


def test_split_mode_classification():
    assert perf_model.split_mode(0, 1024, e_sparse=10) == "sparse"
    assert perf_model.split_mode(1024, 1024, e_sparse=0) == "dense"
    assert perf_model.split_mode(256, 1024, e_sparse=10) == "hybrid"


def test_unskewed_candidates_are_pruned():
    full = perf_model.k_dense_candidates(1 << 12, skewed=True)
    pruned = perf_model.k_dense_candidates(1 << 12, skewed=False)
    assert len(pruned) < len(full) and pruned[0] == 0


# ---------------------------------------------------------------------------
# execution-path selection
# ---------------------------------------------------------------------------

def test_explicit_k_dense_covers_pure_sparse_and_hybrid():
    g = G.rmat(SCALE, 4, seed=13)
    pg = PT.partition(g, PARTS, PT.RAND)
    lr, _ = bfs(BSPEngine(pg, **INTERP), 0)
    for k in (0, 256):
        lh, _ = bfs(BSPEngine(pg, backend="hybrid", hybrid_k_dense=k,
                              **INTERP), 0)
        np.testing.assert_array_equal(lr, lh)


def test_push_and_pull_directions_agree():
    """Forcing always-push vs always-pull changes nothing (min is exact)."""
    g = G.rmat(SCALE, 4, seed=13)
    pg = PT.partition(g, PARTS, PT.RAND)
    # pull_threshold=0 → density < 0 never true → always pull;
    # pull_threshold=1.1 → always push.
    l_pull, s_pull = bfs(BSPEngine(pg, backend="hybrid", pull_threshold=0.0,
                                   **INTERP), 0)
    l_push, s_push = bfs(BSPEngine(pg, backend="hybrid", pull_threshold=1.1,
                                   **INTERP), 0)
    np.testing.assert_array_equal(l_pull, l_push)
    assert s_pull == s_push


def test_program_without_edge_msg_falls_back_to_reference():
    g = G.rmat(9, 4, seed=7)
    pg = PT.partition(g, 2, PT.RAND)
    eng = BSPEngine(pg, backend="hybrid", **INTERP)
    plain = dataclasses.replace(BFS_PROGRAM, edge_msg=None)
    assert not eng._uses_hybrid(plain)
    lr, _ = bfs(BSPEngine(pg, **INTERP), 0)

    import jax.numpy as jnp
    level0 = np.full((2, pg.v_max), np.inf, dtype=np.float32)
    level0[int(pg.assignment.part_of[0]), int(pg.assignment.local_id[0])] = 0.0
    from repro.core.bsp import batch_state, unbatch_state
    state, _ = eng.execute(plain,
                           batch_state({"level": jnp.asarray(level0)}))
    state = unbatch_state(state)
    np.testing.assert_array_equal(
        lr, pg.gather_global(np.asarray(state["level"])))


def test_hybrid_backend_requires_source():
    g = G.rmat(8, 4, seed=7)
    pg = PT.partition(g, 2, PT.RAND)
    pg = dataclasses.replace(pg, source=None)
    with pytest.raises(ValueError, match="source"):
        BSPEngine(pg, backend="hybrid", **INTERP)


def test_unknown_backend_rejected():
    g = G.rmat(8, 4, seed=7)
    pg = PT.partition(g, 2, PT.RAND)
    with pytest.raises(ValueError, match="backend"):
        BSPEngine(pg, backend="mxu-only", **INTERP)


def test_weighted_graph_does_not_leak_into_unweighted_programs():
    """PageRank on a weighted graph must ignore the weights (the reference
    engine's sum counts edges; the plus_times split must count, not sum w)."""
    g = G.rmat(SCALE, 4, seed=13)
    gw = g.with_uniform_weights(seed=3)
    pg = PT.partition(gw, PARTS, PT.RAND)
    pr = pagerank(BSPEngine(pg, **INTERP), num_iterations=5)
    ph = pagerank(BSPEngine(pg, backend="hybrid", **INTERP), num_iterations=5)
    np.testing.assert_allclose(pr, ph, rtol=1e-5, atol=1e-8)
