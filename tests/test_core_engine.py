"""Core engine behaviour: partitioning invariants + algorithm correctness."""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.algorithms import (
    bfs, bfs_reference, pagerank, pagerank_reference, sssp, sssp_reference,
    connected_components, cc_reference, betweenness_centrality, bc_reference)
from repro.algorithms.cc import symmetrize


@pytest.fixture(scope="module", params=["rmat", "uniform"])
def small_graph(request):
    if request.param == "rmat":
        return G.rmat(8, edge_factor=8, seed=3)
    return G.uniform(8, edge_factor=8, seed=3)


@pytest.fixture(scope="module", params=[1, 2, 3])
def num_parts(request):
    return request.param


@pytest.fixture(scope="module", params=[PT.RAND, PT.HIGH, PT.LOW])
def strategy(request):
    return request.param


def _engine(g, num_parts, strategy, **kw):
    pg = PT.partition(g, num_parts, strategy, **kw)
    return BSPEngine(pg)


class TestPartitionInvariants:
    def test_every_vertex_assigned_once(self, small_graph, num_parts,
                                        strategy):
        pg = PT.partition(small_graph, num_parts, strategy)
        seen = np.concatenate(pg.assignment.l2g)
        assert sorted(seen) == list(range(small_graph.num_vertices))

    def test_edge_conservation(self, small_graph, num_parts, strategy):
        pg = PT.partition(small_graph, num_parts, strategy)
        assert int(pg.fwd.num_edges.sum()) == small_graph.num_edges
        assert int(pg.fwd.edge_mask.sum()) == small_graph.num_edges

    def test_alpha_matches_requested_fraction(self, small_graph):
        for frac in (0.5, 0.7, 0.9):
            pg = PT.partition(small_graph, 2, PT.HIGH,
                              cpu_edge_fraction=frac)
            assert abs(pg.alpha[0] - frac) < 0.05

    def test_beta_reduction_shrinks_beta(self, small_graph, strategy):
        pg = PT.partition(small_graph, 2, strategy)
        assert pg.beta_with_reduction <= pg.beta_no_reduction + 1e-12

    def test_reduction_better_on_scale_free(self):
        """Paper Fig. 4: reduction helps much more on skewed graphs."""
        sf = PT.partition(G.rmat(10, 16, seed=1), 2, PT.RAND)
        un = PT.partition(G.uniform(10, 16, seed=1), 2, PT.RAND)
        gain_sf = sf.beta_no_reduction / max(sf.beta_with_reduction, 1e-9)
        gain_un = un.beta_no_reduction / max(un.beta_with_reduction, 1e-9)
        assert gain_sf > gain_un

    def test_high_strategy_puts_high_degree_on_p0(self, small_graph):
        pg = PT.partition(small_graph, 2, PT.HIGH, cpu_edge_fraction=0.5)
        deg = small_graph.out_degrees()
        d0 = deg[pg.assignment.l2g[0]]
        d1 = deg[pg.assignment.l2g[1]]
        if len(d0) and len(d1):
            assert d0.min() >= d1.max()

    def test_outbox_slots_sorted_and_valid(self, small_graph, num_parts):
        pg = PT.partition(small_graph, num_parts, PT.RAND)
        for p in range(num_parts):
            for q in range(num_parts):
                n = int(pg.fwd.outbox_mask[p, q].sum())
                ids = pg.fwd.outbox_dst[p, q, :n]
                assert (np.diff(ids) > 0).all()  # unique + sorted
                assert (ids < pg.assignment.part_sizes[q]).all()


class TestAlgorithms:
    def test_bfs_matches_reference(self, small_graph, num_parts, strategy):
        eng = _engine(small_graph, num_parts, strategy)
        got, _ = bfs(eng, source=0)
        want = bfs_reference(small_graph, 0)
        np.testing.assert_array_equal(got, want)

    def test_pagerank_matches_reference(self, small_graph, num_parts,
                                        strategy):
        eng = _engine(small_graph, num_parts, strategy)
        got = pagerank(eng, num_iterations=15)
        want = pagerank_reference(small_graph, num_iterations=15)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)

    def test_sssp_matches_reference(self, small_graph, num_parts, strategy):
        g = small_graph.with_uniform_weights(seed=7)
        eng = _engine(g, num_parts, strategy)
        got, _ = sssp(eng, source=0)
        want = sssp_reference(g, 0)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_cc_matches_reference(self, small_graph, num_parts, strategy):
        g = symmetrize(small_graph)
        eng = _engine(g, num_parts, strategy)
        got, _ = connected_components(eng)
        want = cc_reference(g)
        np.testing.assert_array_equal(got, want)

    def test_bc_matches_reference(self, small_graph, num_parts, strategy):
        eng = _engine(small_graph, num_parts, strategy, include_reverse=True)
        got, _ = betweenness_centrality(eng, source=0)
        want = bc_reference(small_graph, 0)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_memory_footprint_accounting():
    g = G.rmat(8, 8, seed=2)
    pg = PT.partition(g, 2, PT.LOW)
    fp = PT.memory_footprint_bytes(pg)
    for p in (0, 1):
        assert fp[p]["total"] == (fp[p]["graph"] + fp[p]["outbox"]
                                  + fp[p]["inbox"] + fp[p]["state"])
        assert fp[p]["graph"] > 0
