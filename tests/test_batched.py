"""Batched-query axis == sequential per-query runs, for every algorithm.

The query-batch refactor must be a pure *throughput* substitution: a batch
of Q queries through one ``run_batched`` while_loop has to reproduce Q
sequential single-source runs exactly — per backend {reference, fused,
hybrid}, under ``DistributedBSPEngine`` on {1, 2, 4} forced host devices
(subprocess selftest, so the device count never leaks), with mixed
convergence (early-finishing queries freeze while others continue) and the
Q=1 no-regression case.  ``bc_exact``'s chunked batched execution is held
to *bitwise* parity with the old per-source loop.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import BSPEngine
from repro.algorithms import (bc_exact, bc_exact_sequential,
                              betweenness_centrality,
                              betweenness_centrality_batched, bfs,
                              bfs_batched, personalized_pagerank,
                              personalized_pagerank_reference, sssp,
                              sssp_batched)

INTERP = dict(interpret=True)
SCALE = 9
PARTS = 4
SRC = str(Path(__file__).resolve().parents[1] / "src")

BACKENDS = {
    "reference": dict(),
    "fused": dict(fused=True, block_e=256),
    "hybrid": dict(backend="hybrid"),
}


@pytest.fixture(scope="module")
def graph():
    return G.rmat(SCALE, 4, seed=13).with_uniform_weights(seed=1)


@pytest.fixture(scope="module", params=sorted(BACKENDS))
def named_engine(request, graph):
    pg = PT.partition(graph, PARTS, PT.HIGH, include_reverse=True)
    return request.param, BSPEngine(pg, **BACKENDS[request.param], **INTERP)


@pytest.fixture(scope="module")
def engine(named_engine):
    return named_engine[1]


@pytest.fixture(scope="module")
def sources(graph):
    # Hub + low-degree tail + randoms: eccentricities differ, so the batch
    # exercises mixed convergence on every backend.
    deg = graph.out_degrees()
    rng = np.random.default_rng(3)
    return np.unique(np.concatenate(
        [[np.argmax(deg), np.argmin(deg)],
         rng.integers(0, graph.num_vertices, size=4)]))[:4]


def test_bfs_batched_matches_sequential(engine, sources):
    levels, steps = bfs_batched(engine, sources)
    assert len(set(int(s) for s in steps)) > 1, \
        f"sources should converge at different supersteps, got {steps}"
    for i, s in enumerate(sources):
        want, want_steps = bfs(engine, int(s))
        np.testing.assert_array_equal(levels[i], want)   # min: exact
        assert int(steps[i]) == want_steps


def test_sssp_batched_matches_sequential(engine, sources):
    dists, steps = sssp_batched(engine, sources)
    for i, s in enumerate(sources):
        want, want_steps = sssp(engine, int(s))
        np.testing.assert_array_equal(dists[i], want)    # min: exact
        assert int(steps[i]) == want_steps


def test_bc_batched_matches_sequential(named_engine, sources):
    name, engine = named_engine
    bcs, _ = betweenness_centrality_batched(engine, sources)
    for i, s in enumerate(sources):
        want, _ = betweenness_centrality(engine, int(s))
        if name == "hybrid":
            # The dense MXU block contracts [Q, K] @ [K, K]: a different M
            # legitimately reassociates the f32 K-reduction.
            np.testing.assert_allclose(bcs[i], want, rtol=1e-5, atol=1e-5)
        else:
            # reference/fused reduce per query in an M-independent order.
            np.testing.assert_array_equal(bcs[i], want)


def test_ppr_batched_matches_oracle_and_q1(engine, graph, sources):
    rng = np.random.default_rng(7)
    reset = rng.random((len(sources), graph.num_vertices)).astype(np.float32)
    reset /= reset.sum(axis=1, keepdims=True)
    got = personalized_pagerank(engine, reset, num_iterations=8)
    want = personalized_pagerank_reference(graph, reset, num_iterations=8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)
    # Q=1 slices of the batch == independent Q=1 runs (no cross-query talk;
    # tight allclose — the hybrid dense block's M axis reassociates f32).
    one = personalized_pagerank(engine, reset[:1], num_iterations=8)
    np.testing.assert_allclose(got[0], one[0], rtol=1e-6, atol=1e-9)


def test_q1_matches_batched_row(engine, sources):
    """Q=1 no-regression: a batch of identical queries is Q copies of the
    single-query result, and the Q=1 batch equals the public bfs()."""
    s = int(sources[0])
    levels, steps = bfs_batched(engine, [s, s, s])
    want, want_steps = bfs(engine, s)
    for i in range(3):
        np.testing.assert_array_equal(levels[i], want)
        assert int(steps[i]) == want_steps


def test_mixed_convergence_freezes_early_finishers():
    """A query in a 2-vertex islet finishes supersteps before a main-
    component query; its state must freeze bitwise at its own fixpoint."""
    base = G.rmat(8, 4, seed=5)
    n = base.num_vertices
    src = np.concatenate([base.edge_sources(), [n, n + 1]])
    dst = np.concatenate([base.col, [n + 1, n]])
    g = G.from_edge_list(src, dst, n + 2)
    eng = BSPEngine(PT.partition(g, 2, PT.RAND), **INTERP)
    hub = int(np.argmax(g.out_degrees()))
    levels, steps = bfs_batched(eng, [n, hub])
    assert int(steps[0]) < int(steps[1])
    for i, s in enumerate([n, hub]):
        want, want_steps = bfs(eng, s)
        np.testing.assert_array_equal(levels[i], want)
        assert int(steps[i]) == want_steps


def test_bc_exact_bitwise_parity_with_sequential_loop():
    """The chunked batched all-sources path == the old O(|V|)-dispatch
    loop, bitwise (including a padded tail chunk)."""
    g = G.rmat(6, 4, seed=11)
    eng = BSPEngine(PT.partition(g, 2, PT.RAND, include_reverse=True),
                    **INTERP)
    got = bc_exact(eng, chunk=24)          # 64 sources -> 2 full + padded
    want = bc_exact_sequential(eng)
    np.testing.assert_array_equal(got, want)


def test_bc_exact_single_chunk_and_default():
    g = G.rmat(6, 4, seed=11)
    eng = BSPEngine(PT.partition(g, 2, PT.HIGH, include_reverse=True),
                    **INTERP)
    np.testing.assert_array_equal(bc_exact(eng, chunk=None),
                                  bc_exact_sequential(eng))


def test_batched_runs_do_not_retrace():
    """Two same-Q batches with different sources must share one compiled
    while_loop (the serving contract: no per-query / per-batch retrace)."""
    g = G.rmat(8, 4, seed=2)
    eng = BSPEngine(PT.partition(g, 2, PT.RAND), **INTERP)
    bfs_batched(eng, [0, 1, 2, 3])                       # compiles
    before = BSPEngine._run_batched._cache_size()
    bfs_batched(eng, [4, 5, 6, 7])
    bfs_batched(eng, [9, 8, 7, 6])
    assert BSPEngine._run_batched._cache_size() == before


def test_graph_serve_smoke(tmp_path):
    """The serving driver drains a stream end to end with zero retraces."""
    from repro.launch.graph_serve import main

    out = tmp_path / "serve.json"
    assert main(["--smoke", "--alg", "bfs", "--backend", "reference",
                 "--out", str(out)]) == 0
    import json
    rep = json.loads(out.read_text())
    assert rep["retraces"] == 0
    assert rep["queries_per_sec"] > 0
    assert rep["batches"] * rep["batch"] >= rep["num_queries"]


def _run(ndev: int, module: str, *args, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""))
    return subprocess.run([sys.executable, "-m", module, *args],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


@pytest.mark.parametrize("ndev", [1, 2, 4])
def test_distributed_batched_parity(ndev):
    """bfs/sssp/bc/ppr batched through DistributedBSPEngine (fused +
    hybrid) vs the sequential single-device reference."""
    r = _run(ndev, "repro.launch.batched_selftest", "--parts", "4")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "BATCHED SELFTEST OK" in r.stdout
