"""Integrity of the multi-pod dry-run artifacts (deliverable e).

These tests validate the *recorded* sweep (experiments/dryrun/*.json) rather
than recompiling 82 cells: every (arch × shape × mesh) cell must be ok —
either compiled with sane analyses or a spec-mandated skip.  If artifacts
are missing the tests skip with the command to generate them.
"""
import glob
import json
from pathlib import Path

import pytest

from repro import configs
from repro.models import api

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
GEN_CMD = "PYTHONPATH=src:. python -m repro.launch.dryrun --all --mesh both"


def _load():
    recs = {}
    for f in glob.glob(str(DRYRUN / "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


RECS = _load()
pytestmark = pytest.mark.skipif(not RECS, reason=f"run: {GEN_CMD}")


def _cells():
    out = []
    for arch in configs.all_ids():
        for shape in api.SHAPES:
            for mesh in ("single", "multi"):
                out.append((arch, shape, mesh))
    for mesh in ("single", "multi"):
        out.append(("totem-rmat", "pagerank_superstep", mesh))
    return out


@pytest.mark.parametrize("arch,shape,mesh", _cells())
def test_cell_present_and_ok(arch, shape, mesh):
    rec = RECS.get((arch, shape, mesh))
    assert rec is not None, f"missing cell; run: {GEN_CMD}"
    assert rec.get("ok"), rec.get("error", "")[-500:]
    if rec.get("skipped"):
        # only the spec-mandated long_500k skip is allowed
        assert shape == "long_500k"
        assert not configs.get(arch).sub_quadratic
        return
    ma = rec["memory_analysis"]
    assert ma["temp_bytes"] > 0
    assert rec["cost_analysis_raw"]["flops"] > 0
    if arch != "totem-rmat":
        rf = rec["roofline"]
        assert rf["compute_s"] > 0 and rf["memory_s"] > 0
        assert rf["dominant"] in ("compute", "memory", "collective")


def test_sub_quadratic_archs_run_long_500k():
    for arch in ("xlstm-125m", "zamba2-2.7b", "gemma3-4b"):
        rec = RECS.get((arch, "long_500k", "single"))
        assert rec and rec.get("ok") and not rec.get("skipped")


def test_decode_cells_are_memory_bound():
    """Serving decode = KV/state streaming → memory must dominate."""
    for (arch, shape, mesh), rec in RECS.items():
        if shape == "decode_32k" and mesh == "single" \
                and "roofline" in rec and not rec.get("skipped"):
            assert rec["roofline"]["dominant"] == "memory", arch


def test_train_cells_are_compute_bound():
    for (arch, shape, mesh), rec in RECS.items():
        if shape == "train_4k" and mesh == "single" and "roofline" in rec:
            assert rec["roofline"]["dominant"] == "compute", arch
