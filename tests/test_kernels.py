"""Per-kernel allclose vs ref.py oracles — shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stub shim

from repro.core import graph as G
from repro.core.hybrid import degree_split, hybrid_pagerank
from repro.algorithms import pagerank_reference
from repro.kernels import ops, ref

INTERP = dict(interpret=True)


# ---------------------------------------------------------------------------
# dense_spmv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 8])
@pytest.mark.parametrize("k,n", [(128, 128), (256, 384), (300, 200),
                                 (512, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_spmv_shapes_dtypes(m, k, n, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype=dtype)
    a = jnp.asarray(rng.random(size=(k, n)) < 0.05, dtype=dtype)
    got = ops.dense_spmv_op(x, a, **INTERP)
    want = ref.dense_spmv_ref(x, a)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 4), k=st.integers(1, 200), n=st.integers(1, 200),
       seed=st.integers(0, 2**31 - 1))
def test_dense_spmv_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype=jnp.float32)
    a = jnp.asarray(rng.normal(size=(k, n)), dtype=jnp.float32)
    got = ops.dense_spmv_op(x, a, **INTERP)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.dense_spmv_ref(x, a)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ell_spmv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("combine", ["sum", "min"])
@pytest.mark.parametrize("v,kmax", [(64, 4), (500, 16), (1024, 3)])
def test_ell_spmv_shapes(combine, v, kmax):
    rng = np.random.default_rng(1)
    ident = 0.0 if combine == "sum" else np.inf
    col = rng.integers(0, v, size=(v, kmax)).astype(np.int32)
    pad = rng.random((v, kmax)) < 0.3
    col[pad] = v  # sentinel slot
    val = rng.uniform(0.5, 2.0, size=(v, kmax)).astype(np.float32)
    val[pad] = ident
    x = np.concatenate([rng.normal(size=v).astype(np.float32)
                        if combine == "sum"
                        else rng.uniform(0, 10, size=v).astype(np.float32),
                        [ident]])
    got = ops.ell_spmv_op(jnp.asarray(col), jnp.asarray(val), jnp.asarray(x),
                          combine=combine, **INTERP)
    want = ref.ell_spmv_ref(jnp.asarray(col), jnp.asarray(val),
                            jnp.asarray(x), combine=combine)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(v=st.integers(1, 300), kmax=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_ell_spmv_property_sum(v, kmax, seed):
    rng = np.random.default_rng(seed)
    col = rng.integers(0, v + 1, size=(v, kmax)).astype(np.int32)
    val = np.where(col == v, 0.0,
                   rng.normal(size=(v, kmax))).astype(np.float32)
    x = np.concatenate([rng.normal(size=v), [0.0]]).astype(np.float32)
    got = ops.ell_spmv_op(jnp.asarray(col), jnp.asarray(val), jnp.asarray(x),
                          combine="sum", **INTERP)
    want = ref.ell_spmv_ref(jnp.asarray(col), jnp.asarray(val),
                            jnp.asarray(x), combine="sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("s,d", [(256, 64), (512, 128)])
def test_flash_attention_matches_ref(causal, window, s, d):
    rng = np.random.default_rng(2)
    b, h, kv = 2, 4, 2
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, kv, s, d)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, kv, s, d)), dtype=jnp.float32)
    got = ops.flash_attention_op(q, k, v, causal=causal, window=window,
                                 block_q=128, block_k=128, **INTERP)
    kr = jnp.repeat(k, h // kv, axis=1).reshape(b * h, s, d)
    vr = jnp.repeat(v, h // kv, axis=1).reshape(b * h, s, d)
    want = ref.attention_ref(q.reshape(b * h, s, d), kr, vr, causal=causal,
                             window=window).reshape(b, h, s, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), dtype=jnp.bfloat16)
    got = ops.flash_attention_op(q, k, v, block_q=128, block_k=128, **INTERP)
    want = ref.attention_ref(q.reshape(2, 256, 64), k.reshape(2, 256, 64),
                             v.reshape(2, 256, 64)).reshape(1, 2, 256, 64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# hybrid two-engine step (integration: kernels + degree split)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k_dense", [0, 64, 256])
def test_hybrid_pagerank_matches_reference(k_dense):
    g = G.rmat(9, 8, seed=5)
    hg = degree_split(g, k_dense)
    assert hg.dense_edges + hg.sparse_edges == g.num_edges
    got = hybrid_pagerank(hg, num_iterations=10, interpret=True)
    want = pagerank_reference(g, num_iterations=10)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-6)


def test_degree_split_captures_skew():
    """On a scale-free graph a tiny dense block captures many edges."""
    g = G.rmat(10, 16, seed=4)
    hg = degree_split(g, 128)  # 128 of 1024 vertices
    assert hg.dense_fraction > 0.15
    overall_density = g.num_edges / g.num_vertices ** 2
    assert hg.dense_density > 10 * overall_density
    # above the MXU crossover: the dense path is the right engine for H×H
    from repro.core import perf_model
    assert hg.dense_density > perf_model.mxu_crossover_density()


# ---------------------------------------------------------------------------
# segment reduce (TOTEM message reduction)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("combine", ["sum", "min"])
@pytest.mark.parametrize("e,s", [(100, 10), (2048, 300), (5000, 50)])
def test_segment_reduce_matches_ref(combine, e, s):
    rng = np.random.default_rng(8)
    seg = np.sort(rng.integers(0, s, size=e)).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=e) if combine == "sum"
                       else rng.uniform(0, 100, size=e), jnp.float32)
    got = ops.segment_reduce_op(msgs, seg, s, combine=combine,
                                block_e=256, **INTERP)
    want = ref.segment_reduce_ref(msgs, jnp.asarray(seg), s, combine)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_segment_reduce_sparse_fallback():
    """Gappy ids exceed max_span → exact fallback path."""
    rng = np.random.default_rng(9)
    seg = np.sort(rng.choice(10**6, size=512, replace=False)).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=512), jnp.float32)
    got = ops.segment_reduce_op(msgs, seg, 10**6, combine="sum",
                                max_span=64, **INTERP)
    want = ref.segment_reduce_ref(msgs, jnp.asarray(seg), 10**6, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(e=st.integers(1, 600), s=st.integers(1, 80),
       seed=st.integers(0, 2**31 - 1))
def test_segment_reduce_property(e, s, seed):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, s, size=e)).astype(np.int32)
    msgs = jnp.asarray(rng.normal(size=e), jnp.float32)
    got = ops.segment_reduce_op(msgs, seg, s, combine="sum", block_e=128,
                                **INTERP)
    want = ref.segment_reduce_ref(msgs, jnp.asarray(seg), s, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_segment_reduce_on_engine_outbox_data():
    """Integration: reduce a real partition's dst_ext exactly like the BSP
    engine's compute phase."""
    g = G.rmat(9, 8, seed=11)
    import repro.core.partition as PT
    pg = PT.partition(g, 2, PT.HIGH)
    p = 0
    n_edges = int(pg.fwd.num_edges[p])
    dst = pg.fwd.dst_ext[p, :n_edges]
    order = np.argsort(dst, kind="stable")
    msgs = jnp.asarray(
        np.random.default_rng(0).normal(size=n_edges), jnp.float32)
    got = ops.segment_reduce_op(msgs[order], dst[order], pg.seg_count,
                                combine="sum", **INTERP)
    want = ref.segment_reduce_ref(msgs[order], jnp.asarray(dst[order]),
                                  pg.seg_count, "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
