"""Unit tests for the roofline toolchain: HLO collective parsing with loop
multipliers, and the analytic calculator's napkin-math invariants."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks import calculator, hlo_analysis  # noqa: E402
from repro import configs  # noqa: E402
from repro.models import api  # noqa: E402

HLO = """
ENTRY %main {
  %ar0 = f32[128,256]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8], metadata={op_name="jit(f)/psum"}
}
%body {
  %ag = f32[64,512]{1,0} all-gather(%y), replica_groups=[2,4]<=[8], dimensions={1}, metadata={op_name="jit(f)/while/body/gather"}
  %rs = bf16[32,32]{1,0} reduce-scatter(%z), replica_groups=[8,1]<=[8], metadata={op_name="jit(f)/while/body/while/body/rs"}
}
"""


class TestHloParsing:
    def test_types_and_operand_semantics(self):
        cb = hlo_analysis.collective_bytes(HLO, [1, 1, 1])
        assert cb["all-reduce"] == 128 * 256 * 4
        # all-gather operand = output / group_size (4)
        assert cb["all-gather"] == 64 * 512 * 4 / 4
        # reduce-scatter operand = output × group_size (1)
        assert cb["reduce-scatter"] == 32 * 32 * 2 * 1

    def test_depth_multipliers(self):
        cb = hlo_analysis.collective_bytes(HLO, [1, 10, 100])
        assert cb["all-gather"] == 64 * 512 * 4 / 4 * 10      # depth 1
        assert cb["reduce-scatter"] == 32 * 32 * 2 * 100      # depth 2
        assert cb["all-reduce"] == 128 * 256 * 4              # depth 0

    def test_depth_beyond_list_reuses_last(self):
        cb = hlo_analysis.collective_bytes(HLO, [1, 7])
        assert cb["reduce-scatter"] == 32 * 32 * 2 * 7


class TestCalculator:
    def test_param_count_matches_known_sizes(self):
        n = calculator.count_params(configs.get("tinyllama-1.1b"))
        assert 1.0e9 < n["total"] < 1.25e9         # "1.1B"
        n = calculator.count_params(configs.get("deepseek-67b"))
        assert 6.3e10 < n["total"] < 7.1e10        # "67B"

    def test_moe_active_params(self):
        n = calculator.count_params(configs.get("qwen3-moe-235b-a22b"))
        assert 2.2e11 < n["total"] < 2.6e11        # "235B"
        assert 1.6e10 < n["active"] < 3.0e10       # "a22b"

    def test_train_roofline_terms_positive_and_dominated(self):
        cfg = configs.get("deepseek-67b")
        r = calculator.analyze(cfg, api.SHAPES["train_4k"], 256)
        assert r.compute_s > 0 and r.memory_s > 0 and r.collective_s > 0
        assert r.dominant == "compute"             # big dense training
        assert 0.3 < r.useful_ratio <= 1.0
        assert 0 < r.mfu_bound <= 1.0

    def test_decode_is_memory_bound(self):
        cfg = configs.get("deepseek-67b")
        r = calculator.analyze(cfg, api.SHAPES["decode_32k"], 256)
        assert r.dominant == "memory"              # KV-cache streaming

    def test_scaling_with_chips(self):
        cfg = configs.get("tinyllama-1.1b")
        r1 = calculator.analyze(cfg, api.SHAPES["train_4k"], 256)
        r2 = calculator.analyze(cfg, api.SHAPES["train_4k"], 512)
        assert abs(r1.compute_s / r2.compute_s - 2.0) < 1e-6


def test_perf_model_paper_figures():
    """Pin the paper's headline model predictions (Fig. 2)."""
    from repro.core import perf_model as pm
    # alpha→0 with beta→0: speedup → 1/alpha asymptote
    assert pm.speedup(0.5, 0.0, 1e9, pm.PAPER_C) == pytest.approx(2.0)
    # paper §3.3: with beta=1.0 (worst case, e.g. a cut bipartite graph) a
    # slowdown is predicted only for alpha > ~0.7 (analytically 2/3)
    assert pm.speedup(0.5, 1.0, 1e9, pm.PAPER_C) > 1.0
    assert pm.speedup(0.75, 1.0, 1e9, pm.PAPER_C) < 1.0
