"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks
[arXiv:2411.15242; hf].

54L d_model=2560 32H (kv=32: MHA) d_ff=10240 vocab=32000, ssm_state=64.
54 mamba2 layers with the parameter-shared attention+MLP block applied every
6 layers (9 invocations).  SSM state is O(1) in sequence length → the
long_500k cell RUNS (sub_quadratic=True).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, attn_every=6,
    sub_quadratic=True,
    microbatches=8,
)

SMOKE_CONFIG = CONFIG.reduced(n_layers=4, attn_every=2, head_dim=16)
