"""The paper's own workload configs (Table 2): RMAT scale-free graphs.

RMAT parameters (A,B,C) = (0.57, 0.19, 0.19), average degree 16 — exactly
the paper's Graph500-style generator.  Scales here are reduced for the
CPU-only container (the paper's RMAT27–30 → our RMAT16–22 for runnable
benchmarks; the dry-run lowers the full-scale partition shapes without
allocation).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphWorkload:
    name: str
    scale: int               # |V| = 2^scale
    edge_factor: int = 16
    kind: str = "rmat"       # rmat | uniform


# Reduced-scale stand-ins for the paper's Table 2 workloads.
RMAT_SMALL = GraphWorkload("rmat18", 18)        # benchmark default
RMAT_MEDIUM = GraphWorkload("rmat20", 20)
RMAT_LARGE = GraphWorkload("rmat22", 22)
UNIFORM_SMALL = GraphWorkload("uniform18", 18, kind="uniform")

# Full-scale (dry-run / partition-shape math only; never allocated).
RMAT28 = GraphWorkload("rmat28", 28)
RMAT30 = GraphWorkload("rmat30", 30)

CONFIG = RMAT_SMALL
SMOKE_CONFIG = GraphWorkload("rmat10", 10)
