"""Architecture registry: one module per assigned architecture.

Each module exports ``CONFIG`` (the exact public configuration) and
``SMOKE_CONFIG`` (a reduced same-family config for CPU smoke tests).
"""
import importlib

ARCHS = [
    "seamless_m4t_large_v2",
    "deepseek_67b",
    "command_r_plus_104b",
    "tinyllama_1_1b",
    "gemma3_4b",
    "olmoe_1b_7b",
    "qwen3_moe_235b_a22b",
    "internvl2_26b",
    "xlstm_125m",
    "zamba2_2_7b",
]

# public ids (spec spelling) → module names
ARCH_IDS = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "deepseek-67b": "deepseek_67b",
    "command-r-plus-104b": "command_r_plus_104b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "gemma3-4b": "gemma3_4b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "internvl2-26b": "internvl2_26b",
    "xlstm-125m": "xlstm_125m",
    "zamba2-2.7b": "zamba2_2_7b",
    "totem-rmat": "totem_rmat",
}


def get(arch_id: str):
    """Load CONFIG by public id (e.g. --arch deepseek-67b)."""
    mod = importlib.import_module(
        f"repro.configs.{ARCH_IDS[arch_id]}")
    return mod.CONFIG


def get_smoke(arch_id: str):
    mod = importlib.import_module(
        f"repro.configs.{ARCH_IDS[arch_id]}")
    return mod.SMOKE_CONFIG


def all_ids():
    return [k for k in ARCH_IDS if k != "totem-rmat"]
