"""gemma3-4b [dense] — 5:1 local:global, 128k context
[hf:google/gemma-3-1b-pt; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.  Sliding-window
local layers (1024) with every 6th layer global.  The 5:1 pattern makes the
decode cost dominated by the local window → long_500k cell RUNS
(sub_quadratic=True, DESIGN.md §4).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144, head_dim=256,
    local_window=1024, local_global_ratio=5,
    rope_theta=1_000_000.0,
    sub_quadratic=True,
    microbatches=8,
)

SMOKE_CONFIG = CONFIG.reduced(local_window=8, local_global_ratio=2,
                              n_layers=6)
