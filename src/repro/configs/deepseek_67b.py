"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954; hf].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.  Pure full
attention → long_500k cell skipped (DESIGN.md §4).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400, head_dim=128,
    tie_embeddings=False,
    microbatches=16,
)

SMOKE_CONFIG = CONFIG.reduced(n_kv_heads=2, tie_embeddings=True)
