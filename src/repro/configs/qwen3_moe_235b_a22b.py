"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) d_ff=1536/expert vocab=151936,
MoE 128e top-8.  Pure full attention → long_500k cell skipped.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128,
    moe_experts=128, moe_top_k=8,
    tie_embeddings=False,
    microbatches=16,
)

SMOKE_CONFIG = CONFIG.reduced(tie_embeddings=True)
