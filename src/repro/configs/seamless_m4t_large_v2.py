"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.  The transformer
BACKBONE only; the audio frontend is a stub — ``input_specs()`` provides
precomputed frame embeddings (spec requirement).  24 encoder + 24 decoder
layers from the shared layer config.  Pure full attention → long_500k cell
skipped (DESIGN.md §4).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    enc_dec=True, frontend="audio",
    microbatches=16,   # 256k vocab: keep the f32 logits buffer per-mb small
)

SMOKE_CONFIG = CONFIG.reduced(n_kv_heads=4)
