"""internvl2-26b [vlm] — InternViT + InternLM2 [arXiv:2404.16821; hf].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The transformer
BACKBONE only; the vision frontend is a stub — ``input_specs()`` provides
precomputed patch embeddings (spec requirement).  Pure full attention →
long_500k cell skipped.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, head_dim=128,
    frontend="vision", frontend_len=256,
    microbatches=16,
)

SMOKE_CONFIG = CONFIG.reduced(n_kv_heads=2)
