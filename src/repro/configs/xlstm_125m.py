"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 vocab=50304.  d_ff=0: the blocks are pure mixers
with internal projection factor 2 (xLSTM paper).  Every 4th layer is sLSTM
(sequential scalar memory), the rest mLSTM (chunk-parallel matrix memory via
the SSD dual).  Recurrent state is O(1) in sequence length → the long_500k
cell RUNS (sub_quadratic=True).
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=4,
    sub_quadratic=True,
    microbatches=8,    # 50k vocab at B=256: logits dominate temp below 8 mb
)

SMOKE_CONFIG = CONFIG.reduced(d_ff=0)
