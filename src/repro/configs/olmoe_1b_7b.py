"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304,
MoE 64e top-8.  The paper-representative architecture: expert-load skew is
the direct analogue of vertex-degree skew (DESIGN.md §4).  Pure full
attention → long_500k cell skipped.
"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304, head_dim=128,
    moe_experts=64, moe_top_k=8,
    microbatches=8,
)

SMOKE_CONFIG = CONFIG.reduced()
