"""Attention: GQA, chunked (flash-style) train/prefill path, KV-cache decode.

The train/prefill path is a pure-jnp double-chunked online-softmax scan —
O(chunk²) live memory, differentiable, and tolerant of *traced* window sizes
(needed because layers are executed under ``lax.scan`` with a per-layer
local/global flag).  The Pallas ``kernels/flash_attention`` kernel is the
serving-path accelerator when the window is static; both share semantics and
are cross-checked in tests.

GQA uses grouped einsums (no materialized head repetition): q heads are
reshaped to [groups, q_per_kv].
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def pick_chunk(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is ≤ target (VLM prompts are
    seq+frontend_len, e.g. 4352 = 2^8·17, so chunks must divide exactly)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def _mask(q_pos, k_pos, window):
    """causal + optional sliding window (window<=0 → full causal)."""
    m = q_pos[:, None] >= k_pos[None, :]
    m &= jnp.where(window > 0, (q_pos[:, None] - k_pos[None, :]) < window,
                   True)
    return m


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q_offset: jax.Array | int = 0,
                      window: jax.Array | int = 0,
                      causal: bool = True,
                      q_chunk: int = 1024, k_chunk: int = 1024) -> jax.Array:
    """q: [B, Sq, G, R, D]; k, v: [B, Skv, G, D]. Returns [B, Sq, G, R, D].

    G = kv head groups, R = q heads per group.  Online softmax over k chunks
    inside a scan over q chunks; peak live logits are [B, G, R, qc, kc].
    """
    b, sq, g, r, d = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, skv)
    assert sq % q_chunk == 0 and skv % k_chunk == 0
    nq, nk = sq // q_chunk, skv // k_chunk
    scale = 1.0 / (d ** 0.5)
    window = jnp.asarray(window, jnp.int32)
    q_offset = jnp.asarray(q_offset, jnp.int32)

    qs = q.reshape(b, nq, q_chunk, g, r, d).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(b, nk, k_chunk, g, d).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, k_chunk, g, d).transpose(1, 0, 3, 2, 4)
    # qs: [nq, B, G, R, qc, D]; ks/vs: [nk, B, G, kc, D]

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def k_step(carry, ki_and_idx):
            m_run, l_run, acc = carry
            (ki, vi), jk = ki_and_idx
            k_pos = jk * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qi.astype(jnp.float32),
                           ki.astype(jnp.float32)) * scale
            msk = _mask(q_pos, k_pos, window) if causal else \
                jnp.ones((q_chunk, k_chunk), bool)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + p.sum(-1)
            acc = (acc * alpha[..., None]
                   + jnp.einsum("bgrqk,bgkd->bgrqd", p,
                                vi.astype(jnp.float32)))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, g, r, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, r, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, g, r, q_chunk, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), ((ks, vs), jnp.arange(nk)))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # outs: [nq, B, G, R, qc, D] -> [B, Sq, G, R, D]
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, g, r, d)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     cache_len: jax.Array,
                     window: jax.Array | int = 0) -> jax.Array:
    """Single-token decode. q: [B, 1, G, R, D]; caches: [B, Smax, G, D].

    ``cache_len`` is a scalar (uniform batch, as in the serving benchmark).
    Positions ≥ cache_len are masked; a positive window additionally masks
    positions older than ``cache_len - window`` (gemma3 local layers).
    """
    b, _, g, r, d = q.shape
    smax = k_cache.shape[1]
    scale = 1.0 / (d ** 0.5)
    window = jnp.asarray(window, jnp.int32)
    s = q[:, 0].astype(jnp.float32)                       # [B, G, R, D]
    logits = jnp.einsum("bgrd,bkgd->bgrk", s,
                        k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(smax)
    lo = jnp.where(window > 0, cache_len - window, 0)
    mask = (pos < cache_len) & (pos >= lo)                # [Smax]
    logits = jnp.where(mask[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p, v_cache.astype(jnp.float32))
    return out[:, None].astype(q.dtype)


def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Full (non-causal) cross attention. q: [B,Sq,G,R,D]; k,v: [B,Skv,G,D]."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
