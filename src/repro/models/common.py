"""Shared model components: config, norms, RoPE, losses, init, sharding hints.

Everything is functional: params are plain pytrees of jnp arrays, model
classes are thin namespaces of pure functions.  All layer stacks are stored
stacked on a leading L axis and executed with ``lax.scan`` so the HLO (and
hence SPMD-partitioning/compile time) is O(1) in depth — essential for the
512-device dry-run of 95-layer models.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def opt_enabled(flag: str) -> bool:
    """Beyond-paper optimization flags (§Perf hillclimbs), env-selected so a
    dry-run A/B needs no code edits: REPRO_OPTS=bf16_stack,remat_dots,...

    Flags: bf16_stack (cast layer stacks to compute dtype BEFORE the scan so
    FSDP all-gathers move bf16), remat_dots (save matmul outputs instead of
    full recompute), grad_bf16 (bf16 gradient accumulator), moe_local
    (per-data-shard MoE capacity → dispatch scatter stays shard-local),
    seq_shard (sequence-sharded residual stream).
    """
    return flag in os.environ.get("REPRO_OPTS", "").split(",")


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 → d_model // n_heads
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    # --- attention pattern ---
    local_window: int = 0         # sliding-window size for local layers
    local_global_ratio: int = 0   # N local layers per 1 global (gemma3: 5)
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0           # zamba: shared attn block every N ssm layers
    slstm_every: int = 0          # xlstm: sLSTM block every N layers
    # --- structure ---
    enc_dec: bool = False         # seamless: encoder-decoder
    frontend: str = ""            # "audio" | "vision" | ""
    frontend_len: int = 256       # prepended embedding length (vision)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    # --- training ---
    microbatches: int = 16        # grad-accumulation steps within a step
    remat: bool = True
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # --- shapes this arch supports (spec: skips noted in DESIGN.md) ---
    sub_quadratic: bool = False   # may run long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test sized config of the same family (spec requirement)."""
        base = dict(
            n_layers=min(self.n_layers, 4) or 2,
            d_model=64, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 2,
            d_ff=128 if self.d_ff else 0,
            vocab=256, head_dim=16,
            moe_experts=8 if self.moe_experts else 0,
            moe_top_k=2 if self.moe_top_k else 0,
            local_window=8 if self.local_window else 0,
            ssm_state=16 if self.ssm_state else 0,
            attn_every=2 if self.attn_every else 0,
            slstm_every=self.slstm_every and 2,
            frontend_len=8 if self.frontend else 256,
            microbatches=1,
            name=self.name + "-smoke",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(dtype)


def rope(x: jax.Array, positions: jax.Array,
         theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: [..., S, H?, D] with positions [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (np.log(theta) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    # broadcast over the head axis if present (x: [..., S, H, D])
    while angles.ndim < x.ndim:
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean cross entropy; numerically stable, vocab-shard friendly."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Sequence[int], dtype,
               fan_in: Optional[int] = None) -> jax.Array:
    fan_in = fan_in or shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, tuple(shape), jnp.float32)
            * scale).astype(dtype)


def split_keys(key: jax.Array, names: Sequence[str]) -> Dict[str, jax.Array]:
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


# ---------------------------------------------------------------------------
# Sharding hints
# ---------------------------------------------------------------------------

def logical_constraint(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Annotate activation sharding with logical axis names.

    Resolved to mesh axes by launch/sharding.py rules; a no-op when no mesh
    is active (single-device smoke tests).
    """
    from repro.launch import sharding as shd  # local import: no cycles
    return shd.constrain(x, names)
