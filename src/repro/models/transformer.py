"""Decoder-only / encoder-decoder transformer with GQA, RoPE, SwiGLU, MoE.

Covers: tinyllama, deepseek-67b, command-r-plus (dense); gemma3 (5:1
local:global sliding window); olmoe / qwen3-moe (MoE FFN); internvl2 (vision
patch-embedding stub prepended); seamless-m4t (encoder-decoder with audio
frame-embedding stub).

Layer stacks are stacked on a leading L axis and run under ``lax.scan``
(HLO size O(1) in depth).  Per-layer attention windows are scan-carried
values, so gemma3's pattern costs no extra HLO.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.common import (ArchConfig, cross_entropy_loss, dense_init,
                                 logical_constraint, opt_enabled, rms_norm,
                                 rope, split_keys)
from repro.models.moe import moe_ffn, moe_layer_params

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _layer_param_shapes(cfg: ArchConfig, cross: bool = False) -> Dict[str, tuple]:
    d, hd = cfg.d_model, cfg.hd
    h, g = cfg.n_heads, cfg.n_kv_heads
    shapes = {
        "norm1": (d,), "norm2": (d,),
        "wq": (d, h * hd), "wk": (d, g * hd), "wv": (d, g * hd),
        "wo": (h * hd, d),
    }
    if cross:
        shapes.update({"norm_x": (d,), "wq_x": (d, h * hd),
                       "wk_x": (d, g * hd), "wv_x": (d, g * hd),
                       "wo_x": (h * hd, d)})
    if cfg.is_moe:
        shapes.update(moe_layer_params(cfg))
    else:
        f = cfg.d_ff
        shapes.update({"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)})
    return shapes


def _init_stack(key, cfg: ArchConfig, n_layers: int, dtype,
                cross: bool = False) -> Params:
    shapes = _layer_param_shapes(cfg, cross)
    keys = split_keys(key, list(shapes))
    out = {}
    for name, shape in shapes.items():
        full = (n_layers,) + shape
        if name.startswith("norm"):
            out[name] = jnp.zeros(full, dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            out[name] = dense_init(keys[name], full, dtype, fan_in=fan_in)
    return out


def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    names = ["embed", "layers", "final_norm", "lm_head", "encoder",
             "enc_norm", "frontend"]
    keys = split_keys(key, names)
    params: Params = {
        "embed": dense_init(keys["embed"], (cfg.vocab, cfg.d_model), dtype,
                            fan_in=cfg.d_model),
        "layers": _init_stack(keys["layers"], cfg, cfg.n_layers, dtype,
                              cross=cfg.enc_dec),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys["lm_head"],
                                       (cfg.d_model, cfg.vocab), dtype)
    if cfg.enc_dec:
        params["encoder"] = _init_stack(keys["encoder"], cfg, cfg.n_layers,
                                        dtype)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.frontend:
        params["frontend_proj"] = dense_init(
            keys["frontend"], (cfg.d_model, cfg.d_model), dtype)
    return params


def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """Per-layer attention window (0 = full causal). gemma3: N local : 1 global."""
    if cfg.local_global_ratio and cfg.local_window:
        period = cfg.local_global_ratio + 1
        idx = np.arange(cfg.n_layers)
        return np.where((idx + 1) % period == 0, 0,
                        cfg.local_window).astype(np.int32)
    return np.zeros(cfg.n_layers, dtype=np.int32)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _qkv(h, lp, cfg: ArchConfig, prefix: str = "w"):
    b, s, _ = h.shape
    g, hd = cfg.n_kv_heads, cfg.hd
    r = cfg.n_heads // g
    q = h @ lp[prefix + "q"]
    k = h @ lp[prefix + "k"]
    v = h @ lp[prefix + "v"]
    q = logical_constraint(q, "batch", None, "heads")
    k = logical_constraint(k, "batch", None, "heads")
    v = logical_constraint(v, "batch", None, "heads")
    return (q.reshape(b, s, g, r, hd), k.reshape(b, s, g, hd),
            v.reshape(b, s, g, hd))


def _ffn(h, lp, cfg: ArchConfig):
    gate = jax.nn.silu(h @ lp["w_gate"])
    up = h @ lp["w_up"]
    inter = logical_constraint(gate * up, "batch", None, "ffn")
    return inter @ lp["w_down"]


def _decoder_layer(x, lp, window, positions, cfg: ArchConfig,
                   enc_kv: Optional[Tuple] = None,
                   causal: bool = True):
    """One pre-norm block: attn (+cross) + ffn/moe.  x: [B, S, D]."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    q, k, v = _qkv(h, lp, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = attn.chunked_attention(
        q, k, v, window=window, causal=causal,
        q_chunk=attn.pick_chunk(x.shape[1], 2048),
        k_chunk=attn.pick_chunk(x.shape[1], 1024))
    b, s = x.shape[:2]
    o = o.reshape(b, s, cfg.n_heads * cfg.hd)
    x = x + o @ lp["wo"]
    x = logical_constraint(x, "batch", "seq", None)

    if enc_kv is not None:
        h = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        qx = (h @ lp["wq_x"]).reshape(b, s, cfg.n_kv_heads,
                                      cfg.n_heads // cfg.n_kv_heads, cfg.hd)
        o = attn.cross_attention(qx, *enc_kv)
        x = x + o.reshape(b, s, -1) @ lp["wo_x"]

    h = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.is_moe:
        y = moe_ffn(h, lp, cfg)
    else:
        y = _ffn(h, lp, cfg)
    x = x + y
    return logical_constraint(x, "batch", "seq", None)


def _run_stack(x, stack: Params, cfg: ArchConfig, windows, positions,
               causal: bool = True, enc_out: Optional[jax.Array] = None):
    """scan over layers. enc_out: [B, Senc, D] for cross-attention."""
    b = x.shape[0]
    enc_kv = None
    if enc_out is not None:
        # Cross K/V are layer-specific; computed inside the scan from enc_out.
        pass

    cdt = jnp.dtype(cfg.compute_dtype)
    if opt_enabled("bf16_stack"):
        # cast BEFORE the scan: the FSDP all-gather then moves bf16 (half
        # the wire bytes and half the gathered-weight VMEM residency).
        stack = jax.tree.map(lambda w: w.astype(cdt), stack)

    def body(h, per_layer):
        lp, window = per_layer
        lp = jax.tree.map(lambda w: w.astype(cdt), lp)
        ekv = None
        if enc_out is not None:
            se = enc_out.shape[1]
            ke = (enc_out @ lp["wk_x"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
            ve = (enc_out @ lp["wv_x"]).reshape(b, se, cfg.n_kv_heads, cfg.hd)
            ekv = (ke, ve)
        h = _decoder_layer(h, lp, window, positions, cfg, enc_kv=ekv,
                           causal=causal)
        return h, None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if opt_enabled("remat_dots")
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy)
    x, _ = jax.lax.scan(body, x, (stack, jnp.asarray(windows)))
    del enc_kv
    return x


# ---------------------------------------------------------------------------
# Public model functions
# ---------------------------------------------------------------------------

def _embed_inputs(params: Params, cfg: ArchConfig, batch: Dict) -> Tuple:
    """Token embedding + optional frontend embeddings prepended."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
    x = x * float(np.sqrt(cfg.d_model))
    n_front = 0
    if cfg.frontend == "vision" and "patches" in batch:
        front = (batch["patches"].astype(cdt)
                 @ params["frontend_proj"].astype(cdt))
        x = jnp.concatenate([front, x], axis=1)
        n_front = front.shape[1]
    return x, n_front


def _encode(params: Params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """Audio encoder (seamless): bidirectional stack over frame embeddings."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt) @ params["frontend_proj"].astype(cdt)
    positions = jnp.arange(x.shape[1])[None]
    windows = np.zeros(cfg.n_layers, np.int32)
    x = _run_stack(x, params["encoder"], cfg, windows, positions,
                   causal=False)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _lm_logits(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = x @ head
    return logical_constraint(logits, "batch", None, "vocab")


def loss_fn(params: Params, batch: Dict, *, cfg: ArchConfig) -> jax.Array:
    """One microbatch forward + CE loss. batch['tokens']: [B, S+1]."""
    tokens = batch["tokens"][:, :-1]
    labels = batch["tokens"][:, 1:]
    mb = dict(batch, tokens=tokens)
    x, n_front = _embed_inputs(params, cfg, mb)
    if cfg.enc_dec:
        enc_out = _encode(params, cfg, batch["frames"])
    else:
        enc_out = None
    positions = jnp.arange(x.shape[1])[None]
    windows = layer_windows(cfg)
    x = _run_stack(x, params["layers"], cfg, windows, positions,
                   causal=True, enc_out=enc_out)
    if n_front:
        x = x[:, n_front:]
    logits = _lm_logits(params, cfg, x)
    return cross_entropy_loss(logits, labels)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               enc_len: int = 0) -> Dict:
    cdt = jnp.dtype(cfg.compute_dtype)
    g, hd = cfg.n_kv_heads, cfg.hd
    cache = {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, g, hd), cdt),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, g, hd), cdt),
        "len": jnp.zeros((), jnp.int32),
    }
    if cfg.enc_dec and enc_len:
        cache["xk"] = jnp.zeros((cfg.n_layers, batch, enc_len, g, hd), cdt)
        cache["xv"] = jnp.zeros((cfg.n_layers, batch, enc_len, g, hd), cdt)
    return cache


def prefill(params: Params, batch: Dict, *, cfg: ArchConfig,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """Process the full prompt; returns (last-token logits [B, V], cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x, n_front = _embed_inputs(params, cfg, batch)
    stotal = x.shape[1]
    # frontend embeddings (VLM) occupy cache slots too
    max_len = max(max_len or stotal, stotal)
    positions = jnp.arange(stotal)[None]
    windows = layer_windows(cfg)
    enc_out = _encode(params, cfg, batch["frames"]) if cfg.enc_dec else None
    g, hd = cfg.n_kv_heads, cfg.hd
    r = cfg.n_heads // g

    cdt = jnp.dtype(cfg.compute_dtype)

    def body(h, per_layer):
        lp, window = per_layer
        lp = jax.tree.map(lambda w: w.astype(cdt), lp)
        hn = rms_norm(h, lp["norm1"], cfg.norm_eps)
        q, k, v = _qkv(hn, lp, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        o = attn.chunked_attention(
            q, k, v, window=window, causal=True,
            q_chunk=attn.pick_chunk(stotal, 2048),
            k_chunk=attn.pick_chunk(stotal, 1024))
        o = o.reshape(b, stotal, cfg.n_heads * hd)
        h = h + o @ lp["wo"]
        ys = {"k": k, "v": v}
        if enc_out is not None:
            se = enc_out.shape[1]
            ke = (enc_out @ lp["wk_x"]).reshape(b, se, g, hd)
            ve = (enc_out @ lp["wv_x"]).reshape(b, se, g, hd)
            hx = rms_norm(h, lp["norm_x"], cfg.norm_eps)
            qx = (hx @ lp["wq_x"]).reshape(b, stotal, g, r, hd)
            h = h + attn.cross_attention(qx, ke, ve).reshape(b, stotal, -1) \
                @ lp["wo_x"]
            ys.update({"xk": ke, "xv": ve})
        hn = rms_norm(h, lp["norm2"], cfg.norm_eps)
        h = h + (moe_ffn(hn, lp, cfg) if cfg.is_moe else _ffn(hn, lp, cfg))
        return h, ys

    if cfg.remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, caches = jax.lax.scan(body, x, (params["layers"],
                                       jnp.asarray(layer_windows(cfg))))
    logits = _lm_logits(params, cfg, x[:, -1:])[:, 0]

    pad = max_len - stotal
    cache = {
        "k": jnp.pad(caches["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(caches["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "len": jnp.int32(stotal),
    }
    if "xk" in caches:
        cache["xk"], cache["xv"] = caches["xk"], caches["xv"]
    return logits, cache


def decode_step(params: Params, cache: Dict, tokens: jax.Array,
                *, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    """One decode step. tokens: [B] int32. Returns (logits [B, V], cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b = tokens.shape[0]
    pos = cache["len"]
    x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cdt)
    x = x * float(np.sqrt(cfg.d_model))
    positions = jnp.full((1, 1), pos, jnp.int32)
    g, hd = cfg.n_kv_heads, cfg.hd
    r = cfg.n_heads // g
    windows = jnp.asarray(layer_windows(cfg))

    def body(h, per_layer):
        lp, window, kc, vc, xkv = per_layer
        lp = jax.tree.map(lambda w: w.astype(cdt), lp)
        hn = rms_norm(h, lp["norm1"], cfg.norm_eps)
        q, k, v = _qkv(hn, lp, cfg)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        o = attn.decode_attention(q, kc, vc, cache_len=pos + 1,
                                  window=window)
        h = h + o.reshape(b, 1, cfg.n_heads * hd) @ lp["wo"]
        if xkv is not None:
            hx = rms_norm(h, lp["norm_x"], cfg.norm_eps)
            qx = (hx @ lp["wq_x"]).reshape(b, 1, g, r, hd)
            h = h + attn.cross_attention(qx, *xkv).reshape(b, 1, -1) \
                @ lp["wo_x"]
        hn = rms_norm(h, lp["norm2"], cfg.norm_eps)
        h = h + (moe_ffn(hn, lp, cfg) if cfg.is_moe else _ffn(hn, lp, cfg))
        return h, (kc, vc)

    xkvs = ((cache["xk"], cache["xv"]) if "xk" in cache
            else None)

    def scan_body(h, xs):
        if xkvs is None:
            lp, window, kc, vc = xs
            return body(h, (lp, window, kc, vc, None))
        lp, window, kc, vc, xk, xv = xs
        return body(h, (lp, window, kc, vc, (xk, xv)))

    xs = ((params["layers"], windows, cache["k"], cache["v"])
          if xkvs is None else
          (params["layers"], windows, cache["k"], cache["v"], *xkvs))
    x, (new_k, new_v) = jax.lax.scan(scan_body, x, xs)
    logits = _lm_logits(params, cfg, x)[:, 0]
    new_cache = dict(cache, k=new_k, v=new_v, len=pos + 1)
    return logits, new_cache
