"""Mamba2 (SSD — state-space dual) block, chunk-parallel, MXU-friendly.

The chunked SSD algorithm computes the scalar-decay SSM

    h_t = exp(a_t) · h_{t-1} + B_t x_tᵀ ;   y_t = C_t · h_t

as (i) quadratic attention-like matmuls inside length-``l`` chunks and (ii) a
cheap inter-chunk scan over the [H, P, N] states — matmul-rich (MXU) with an
O(S/l) sequential tail.  This is the hardware-adaptation of the recurrence:
TPUs want big matmuls, not elementwise scans.

Also exposes ``ssd()`` for reuse: mLSTM (xlstm.py) is the same dual with
decay = log-sigmoid(forget gate) and input scale = exp(input gate).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, dense_init, rms_norm, split_keys

HEAD_DIM = 64  # mamba2 P (headdim)


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums.

    a: [..., l] → out[..., i, j] = Σ_{j < k ≤ i} a[k]  (−inf above diagonal).
    """
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd(x: jax.Array, a_log: jax.Array, b: jax.Array, c: jax.Array,
        chunk: int = 128,
        init_state: jax.Array | None = None
        ) -> Tuple[jax.Array, jax.Array]:
    """Chunked scalar-decay SSM.

    x: [B, S, H, P] (inputs, already dt-scaled), a_log: [B, S, H] (log decay,
    already dt-scaled, ≤ 0), b: [B, S, N] (input proj), c: [B, S, N] (output
    proj; groups=1 broadcast over heads).  Returns (y [B, S, H, P],
    final_state [B, H, P, N]).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a_log.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)

    # (i) intra-chunk (diagonal blocks): attention-like quadratic term.
    L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))        # [B,nc,H,l,l]
    y_diag = jnp.einsum("bzln,bzsn,bzhls,bzshp->bzlhp", cc, bc, L, xc)

    # chunk summaries: state contribution of each chunk (f32 recurrence).
    cum = jnp.cumsum(ac, axis=2)                           # [B,nc,l,H]
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)        # [B,nc,l,H]
    states = jnp.einsum("bzsn,bzsh,bzshp->bzhpn",
                        bc.astype(jnp.float32), decay_states,
                        xc.astype(jnp.float32))

    # (ii) inter-chunk recurrence over the nc chunk states.
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [B,nc,H]
    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                  # emit *previous*

    final, prev_states = jax.lax.scan(
        scan_fn, s0, (states.transpose(1, 0, 2, 3, 4),
                      chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # [B,nc,H,P,N]

    # state → output within each chunk.
    state_decay = jnp.exp(cum)                             # [B,nc,l,H]
    y_off = jnp.einsum("bzln,bzhpn,bzlh->bzlhp",
                       cc.astype(jnp.float32), prev_states, state_decay)
    y = (y_diag.astype(jnp.float32) + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final.astype(x.dtype)


def ssd_step(state: jax.Array, x: jax.Array, a_log: jax.Array, b: jax.Array,
             c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence. state: [B,H,P,N]; x: [B,H,P]; a_log: [B,H];
    b, c: [B,N]."""
    decay = jnp.exp(a_log)[:, :, None, None]
    state = state * decay + jnp.einsum("bhp,bn->bhpn", x, b)
    y = jnp.einsum("bhpn,bn->bhp", state, c)
    return y, state


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj → conv → SSD → gated norm → out_proj)
# ---------------------------------------------------------------------------

def mamba_param_shapes(cfg: ArchConfig) -> Dict[str, tuple]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = di // HEAD_DIM
    conv_ch = di + 2 * n
    return {
        "norm": (d,),
        "in_proj": (d, 2 * di + 2 * n + h),   # z, x, B, C, dt
        "conv_w": (cfg.ssm_conv, conv_ch),
        "a_log": (h,),
        "d_skip": (h,),
        "dt_bias": (h,),
        "ssm_norm": (di,),
        "out_proj": (di, d),
    }


def init_mamba_stack(key, cfg: ArchConfig, n_layers: int, dtype) -> Dict:
    shapes = mamba_param_shapes(cfg)
    keys = split_keys(key, list(shapes))
    out = {}
    for name, shape in shapes.items():
        full = (n_layers,) + shape
        if name in ("norm", "ssm_norm"):
            out[name] = jnp.zeros(full, dtype)
        elif name == "a_log":
            out[name] = jnp.ones(full, dtype)          # A = -exp(1) ≈ -e
        elif name in ("d_skip",):
            out[name] = jnp.ones(full, dtype)
        elif name == "dt_bias":
            out[name] = jnp.zeros(full, dtype)
        elif name == "conv_w":
            out[name] = dense_init(keys[name], full, dtype,
                                   fan_in=cfg.ssm_conv)
        else:
            out[name] = dense_init(keys[name], full, dtype,
                                   fan_in=shape[-2])
    return out


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(k))
    return out


def mamba_block(x: jax.Array, lp: Dict, cfg: ArchConfig,
                chunk: int = 128,
                state: Tuple | None = None,
                return_state: bool = False):
    """x: [B, S, D] → [B, S, D].  state = (conv_tail [B,K-1,C], ssd [B,H,P,N])
    for decode; pass S=1 with state for single-step."""
    bsz, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = di // HEAD_DIM

    hidden = rms_norm(x, lp["norm"], cfg.norm_eps)
    zxbcdt = hidden @ lp["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)

    if state is None:
        xbc_conv = jax.nn.silu(_causal_conv(xbc, lp["conv_w"]))
        new_conv_tail = xbc[:, -(cfg.ssm_conv - 1):]
    else:
        conv_tail = state[0]
        window = jnp.concatenate([conv_tail, xbc], axis=1)
        xbc_conv = jax.nn.silu(
            jnp.einsum("bkc,kc->bc", window, lp["conv_w"]))[:, None]
        new_conv_tail = window[:, 1:]

    xs, b, c = jnp.split(xbc_conv, [di, di + n], axis=-1)
    xs = xs.reshape(bsz, -1, h, HEAD_DIM)
    dt = jax.nn.softplus(dt + lp["dt_bias"])              # [B, S, H]
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))         # [H]
    a_log = (dt.astype(jnp.float32) * a)                  # [B, S, H] ≤ 0
    x_scaled = xs * dt[..., None].astype(xs.dtype)

    if state is None:
        y, final = ssd(x_scaled, a_log, b, c, chunk=chunk)
    else:
        y, final = ssd_step(state[1], x_scaled[:, 0], a_log[:, 0],
                            b[:, 0], c[:, 0])
        y = y[:, None]

    y = y + xs * lp["d_skip"][None, None, :, None]
    y = y.reshape(bsz, -1, di)
    y = rms_norm(y * jax.nn.silu(z), lp["ssm_norm"], cfg.norm_eps)
    out = x + y @ lp["out_proj"]
    if return_state or state is not None:
        return out, (new_conv_tail, final)
    return out
