"""Zamba2-style hybrid: Mamba2 backbone + a shared attention(+MLP) block.

zamba2-2.7b: 54 mamba2 layers; a single *parameter-shared* full-attention
block (MHA, 32 heads, kv=32) + MLP is applied after every ``attn_every``
mamba layers (9 invocations for attn_every=6).  Sharing is the memory trick
of the Zamba papers; each invocation still needs its own KV cache.

Simplifications vs. the released checkpoints (documented in DESIGN.md):
one shared block instead of two alternating; no per-invocation LoRA; no
concatenated embedding re-injection.

This hybrid is itself a TOTEM-style two-engine design: a cheap
high-throughput engine (SSM) handles the bulk, an expensive engine
(attention) handles what it is uniquely good at — the makespan model of the
paper applies directly (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import mamba2 as mb
from repro.models.common import (ArchConfig, cross_entropy_loss, dense_init,
                                 logical_constraint, rms_norm, rope,
                                 split_keys)

Params = Dict[str, Any]


def n_groups(cfg: ArchConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = split_keys(key, ["embed", "mamba", "attn", "final"])
    d, hd = cfg.d_model, cfg.hd
    h, g = cfg.n_heads, cfg.n_kv_heads
    akeys = split_keys(keys["attn"], ["wq", "wk", "wv", "wo", "w_gate",
                                      "w_up", "w_down"])
    shared = {
        "norm1": jnp.zeros((d,), dtype), "norm2": jnp.zeros((d,), dtype),
        "wq": dense_init(akeys["wq"], (d, h * hd), dtype),
        "wk": dense_init(akeys["wk"], (d, g * hd), dtype),
        "wv": dense_init(akeys["wv"], (d, g * hd), dtype),
        "wo": dense_init(akeys["wo"], (h * hd, d), dtype),
        "w_gate": dense_init(akeys["w_gate"], (d, cfg.d_ff), dtype),
        "w_up": dense_init(akeys["w_up"], (d, cfg.d_ff), dtype),
        "w_down": dense_init(akeys["w_down"], (cfg.d_ff, d), dtype),
    }
    return {
        "embed": dense_init(keys["embed"], (cfg.vocab, cfg.d_model), dtype,
                            fan_in=cfg.d_model),
        "mamba": mb.init_mamba_stack(keys["mamba"], cfg, cfg.n_layers,
                                     dtype),
        "shared_attn": shared,
        "final_norm": jnp.zeros((d,), dtype),
    }


def _shared_attn_block(x, sp, cfg: ArchConfig, positions,
                       cache: Optional[Tuple] = None,
                       cache_len=None):
    """The parameter-shared attention + MLP block (full causal MHA)."""
    b, s, d = x.shape
    g, hd = cfg.n_kv_heads, cfg.hd
    r = cfg.n_heads // g
    h0 = rms_norm(x, sp["norm1"], cfg.norm_eps)
    q = (h0 @ sp["wq"]).reshape(b, s, g, r, hd)
    k = (h0 @ sp["wk"]).reshape(b, s, g, hd)
    v = (h0 @ sp["wv"]).reshape(b, s, g, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cache is None:
        o = attn.chunked_attention(q, k, v,
                                   q_chunk=attn.pick_chunk(s, 2048),
                                   k_chunk=attn.pick_chunk(s, 1024))
        new_cache = (k, v)
    else:
        kc, vc = cache
        pos = cache_len
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        o = attn.decode_attention(q, kc, vc, cache_len=pos + 1)
        new_cache = (kc, vc)
    x = x + o.reshape(b, s, -1) @ sp["wo"]
    h1 = rms_norm(x, sp["norm2"], cfg.norm_eps)
    inter = jax.nn.silu(h1 @ sp["w_gate"]) * (h1 @ sp["w_up"])
    inter = logical_constraint(inter, "batch", None, "ffn")
    return x + inter @ sp["w_down"], new_cache


def _forward(params: Params, cfg: ArchConfig, tokens: jax.Array,
             cache: Optional[Dict] = None, ssd_chunk: int = 128):
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    ng = n_groups(cfg)
    ae = cfg.attn_every
    # regroup the mamba stack: [L, ...] -> [ng, ae, ...]
    grouped = jax.tree.map(
        lambda w: w.reshape((ng, ae) + w.shape[1:]), params["mamba"])
    shared = jax.tree.map(lambda w: w.astype(cdt), params["shared_attn"])
    positions = (jnp.arange(s)[None] if cache is None
                 else jnp.full((1, 1), cache["len"], jnp.int32))

    new_conv, new_ssd, new_k, new_v = [], [], [], []
    for gi in range(ng):
        gp = jax.tree.map(lambda w: w[gi], grouped)

        if cache is None:
            def body(h, lp):
                lp = jax.tree.map(lambda w: w.astype(cdt), lp)
                out = mb.mamba_block(h, lp, cfg, chunk=ssd_chunk)
                return out.astype(cdt), None

            def attn_only(h):
                return _shared_attn_block(h, shared, cfg, positions)[0]

            if cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
                # The shared block must be remat'd too: un-checkpointed, the
                # chunked-attention scan saves per-chunk probability tensors
                # for backward — measured +~38 GiB/chip on train_4k (§Perf).
                attn_only = jax.checkpoint(
                    attn_only,
                    policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = jax.lax.scan(body, x, gp)
            x = attn_only(x)
            x = logical_constraint(x, "batch", "seq", None)
        else:
            def body(h, per_layer):
                lp, conv_t, ssd_st = per_layer
                lp = jax.tree.map(lambda w: w.astype(cdt), lp)
                out, (c2, s2) = mb.mamba_block(h, lp, cfg,
                                               state=(conv_t, ssd_st))
                return out.astype(cdt), (c2, s2)

            lo = gi * ae
            conv_g = jax.lax.dynamic_slice_in_dim(cache["conv"], lo, ae, 0)
            ssd_g = jax.lax.dynamic_slice_in_dim(cache["ssd"], lo, ae, 0)
            x, (conv2, ssd2) = jax.lax.scan(body, x, (gp, conv_g, ssd_g))
            new_conv.append(conv2)
            new_ssd.append(ssd2)
            x, (kc, vc) = _shared_attn_block(
                x, shared, cfg, positions,
                cache=(cache["k"][gi], cache["v"][gi]),
                cache_len=cache["len"])
            new_k.append(kc)
            new_v.append(vc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T.astype(x.dtype)
    logits = logical_constraint(logits, "batch", None, "vocab")

    new_cache = None
    if cache is not None:
        new_cache = dict(
            cache,
            conv=jnp.concatenate(new_conv, axis=0),
            ssd=jnp.concatenate(new_ssd, axis=0),
            k=jnp.stack(new_k), v=jnp.stack(new_v),
            len=cache["len"] + 1)
    return logits, new_cache


def loss_fn(params: Params, batch: Dict, *, cfg: ArchConfig) -> jax.Array:
    tokens = batch["tokens"][:, :-1]
    labels = batch["tokens"][:, 1:]
    logits, _ = _forward(params, cfg, tokens)
    return cross_entropy_loss(logits, labels)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               enc_len: int = 0) -> Dict:
    del enc_len
    cdt = jnp.dtype(cfg.compute_dtype)
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h_ssd = di // mb.HEAD_DIM
    conv_ch = di + 2 * n
    ng = n_groups(cfg)
    g, hd = cfg.n_kv_heads, cfg.hd
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch),
                          cdt),
        "ssd": jnp.zeros((cfg.n_layers, batch, h_ssd, mb.HEAD_DIM, n), cdt),
        "k": jnp.zeros((ng, batch, max_len, g, hd), cdt),
        "v": jnp.zeros((ng, batch, max_len, g, hd), cdt),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params: Params, batch: Dict, *, cfg: ArchConfig,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """Parallel forward with state extraction (chunked SSD final states +
    attention KV), then assemble the decode cache."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    ng = n_groups(cfg)
    ae = cfg.attn_every
    grouped = jax.tree.map(
        lambda w: w.reshape((ng, ae) + w.shape[1:]), params["mamba"])
    shared = jax.tree.map(lambda w: w.astype(cdt), params["shared_attn"])
    positions = jnp.arange(s)[None]

    convs, ssds, ks, vs = [], [], [], []
    for gi in range(ng):
        gp = jax.tree.map(lambda w: w[gi], grouped)

        def body(h, lp):
            lp = jax.tree.map(lambda w: w.astype(cdt), lp)
            out, st = mb.mamba_block(h, lp, cfg, return_state=True)
            return out.astype(cdt), st

        x, (conv_t, ssd_st) = jax.lax.scan(body, x, gp)
        convs.append(conv_t)
        ssds.append(ssd_st)
        x, (k_new, v_new) = _shared_attn_block(x, shared, cfg, positions)
        pad = max_len - s
        ks.append(jnp.pad(k_new, ((0, 0), (0, pad), (0, 0), (0, 0))))
        vs.append(jnp.pad(v_new, ((0, 0), (0, pad), (0, 0), (0, 0))))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ params["embed"].T.astype(x.dtype))
    cache = {
        "conv": jnp.concatenate(convs, axis=0).astype(cdt),
        "ssd": jnp.concatenate(ssds, axis=0).astype(cdt),
        "k": jnp.stack(ks), "v": jnp.stack(vs),
        "len": jnp.int32(s),
    }
    return logits, cache


def decode_step(params: Params, cache: Dict, tokens: jax.Array,
                *, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    logits, cache = _forward(params, cfg, tokens[:, None], cache)
    return logits[:, 0], cache
