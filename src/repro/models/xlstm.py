"""xLSTM (sLSTM + mLSTM blocks) — the [ssm] architecture (xlstm-125m).

- **mLSTM** (parallelizable): matrix memory C_t = f_t·C_{t-1} + i_t·v_t k_tᵀ,
  read h_t = C_t q_t / max(|n_t·q_t|, 1).  This is the same scalar-decay dual
  as mamba2's SSD, so training reuses ``mamba2.ssd`` (chunked, MXU-friendly):
  decay = logσ(f-gate), input scale = exp(i-gate) (clipped), B=k, C=q, x=v.
  The normalizer n runs through the same SSD with x=1.
- **sLSTM** (every ``slstm_every``-th layer): scalar memory with exponential
  gating and the stabilizer state m — inherently sequential (the paper's
  point), implemented as ``lax.scan`` over time with recurrent gate inputs.

d_ff = 0 in the public config: blocks are pure mixers with an internal
projection factor of 2 (as in the xLSTM paper), no separate FFN.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import (ArchConfig, cross_entropy_loss, dense_init,
                                 logical_constraint, rms_norm, split_keys)
from repro.models.mamba2 import ssd, ssd_step

Params = Dict[str, Any]
GATE_CLIP = 8.0   # exp input-gate clip (stabilization, see module docstring)


def _proj_dim(cfg: ArchConfig) -> int:
    return 2 * cfg.d_model  # projection factor 2


def layer_param_shapes(cfg: ArchConfig) -> Dict[str, tuple]:
    d = cfg.d_model
    di = _proj_dim(cfg)
    h = cfg.n_heads
    dh = di // h
    return {
        "norm": (d,),
        "up_proj": (d, 2 * di),          # value stream + gate stream
        "wq": (d, di), "wk": (d, di),
        "w_igate": (d, h), "w_fgate": (d, h),
        "b_igate": (h,), "b_fgate": (h,),
        # sLSTM recurrent gate weights (block-diagonal per head)
        "r_igate": (h, dh), "r_fgate": (h, dh), "r_zgate": (h, dh),
        "w_ogate": (d, di),
        "mix_norm": (di,),
        "down_proj": (di, d),
    }


def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = split_keys(key, ["embed", "layers", "final_norm"])
    shapes = layer_param_shapes(cfg)
    lkeys = split_keys(keys["layers"], list(shapes))
    layers = {}
    for name, shape in shapes.items():
        full = (cfg.n_layers,) + shape
        if "norm" in name:
            layers[name] = jnp.zeros(full, dtype)
        elif name == "b_fgate":
            layers[name] = jnp.full(full, 3.0, dtype)   # forget-bias init
        elif name.startswith("b_"):
            layers[name] = jnp.zeros(full, dtype)
        else:
            fan = shape[-2] if len(shape) >= 2 else shape[-1]
            layers[name] = dense_init(lkeys[name], full, dtype, fan_in=fan)
    return {
        "embed": dense_init(keys["embed"], (cfg.vocab, cfg.d_model), dtype,
                            fan_in=cfg.d_model),
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def slstm_layers_mask(cfg: ArchConfig) -> np.ndarray:
    if not cfg.slstm_every:
        return np.zeros(cfg.n_layers, bool)
    idx = np.arange(cfg.n_layers)
    return (idx + 1) % cfg.slstm_every == 0


# ---------------------------------------------------------------------------
# Cells
# ---------------------------------------------------------------------------

def _mlstm_parallel(v, q, k, igate, fgate, cfg: ArchConfig,
                    init_state=None):
    """Chunk-parallel mLSTM via the SSD dual.

    v: [B,S,H,Dh]; q,k: [B,S,H,Dh]; i/f gates: [B,S,H].
    Returns (h [B,S,H,Dh], final_state dict).
    """
    b, s, h, dh = v.shape
    a_log = jax.nn.log_sigmoid(fgate.astype(jnp.float32))
    iscale = jnp.exp(jnp.clip(igate.astype(jnp.float32), -GATE_CLIP,
                              GATE_CLIP)).astype(v.dtype)
    x = v * iscale[..., None]
    ones = jnp.ones((b, s, h, 1), v.dtype) * iscale[..., None]
    # per-head B/C: flatten heads into the batch axis for grouped ssd
    def flat(t):  # [B,S,H,X] -> [B*H, S, X] is wrong for ssd API; use vmap
        return t

    # ssd expects b/c shared across heads; ours are per-head → vmap over H.
    def per_head(xh, ah, bh, ch, s0):
        return ssd(xh[:, :, None], ah[:, :, None], bh, ch, chunk=128,
                   init_state=s0)

    vm = jax.vmap(per_head, in_axes=(2, 2, 2, 2, 1), out_axes=(2, 1))
    s0_c = (jnp.zeros((b, h, 1, dh, dh), v.dtype) if init_state is None
            else init_state["C"][:, :, None])
    s0_n = (jnp.zeros((b, h, 1, 1, dh), v.dtype) if init_state is None
            else init_state["n"][:, :, None, None])
    num, st_c = vm(x, a_log, k, q, s0_c)
    den, st_n = vm(ones, a_log, k, q, s0_n)
    hval = num[..., 0, :] / jnp.maximum(jnp.abs(den[..., 0, :]), 1.0)
    state = {"C": st_c[:, :, 0], "n": st_n[:, :, 0, 0]}
    return hval.astype(v.dtype), state


def _mlstm_step(v, q, k, igate, fgate, state):
    """One-step mLSTM. v,q,k: [B,H,Dh]; gates: [B,H]."""
    a_log = jax.nn.log_sigmoid(fgate.astype(jnp.float32))
    iscale = jnp.exp(jnp.clip(igate.astype(jnp.float32), -GATE_CLIP,
                              GATE_CLIP))
    decay = jnp.exp(a_log)[..., None, None]
    C = state["C"] * decay + jnp.einsum(
        "bhd,bhe->bhde", (v * iscale[..., None]).astype(jnp.float32),
        k.astype(jnp.float32))
    n = state["n"] * jnp.exp(a_log)[..., None] + \
        iscale[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", C, q.astype(jnp.float32))
    den = jnp.einsum("bhe,bhe->bh", n, q.astype(jnp.float32))
    hval = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    return hval.astype(v.dtype), {"C": C.astype(state["C"].dtype),
                                  "n": n.astype(state["n"].dtype)}


def _slstm_scan(v, z_pre, igate, fgate, lp, init=None):
    """Sequential sLSTM with stabilizer. v unused (z is the input stream).

    z_pre, per-step gate pre-activations: [B, S, H] (+recurrent terms added
    inside).  Returns h: [B, S, H, Dh]."""
    b, s, h = igate.shape
    dh = z_pre.shape[-1] // h
    zs = z_pre.reshape(b, s, h, dh)

    def cell(carry, t):
        c, n, m, hprev = carry
        z_t, i_t, f_t = t
        # recurrent contributions (block-diagonal per head)
        i_t = i_t + jnp.einsum("bhd,hd->bh", hprev, lp["r_igate"])
        f_t = f_t + jnp.einsum("bhd,hd->bh", hprev, lp["r_fgate"])
        z_t = jnp.tanh(z_t + hprev * lp["r_zgate"][None])
        log_f = jax.nn.log_sigmoid(f_t.astype(jnp.float32))
        log_i = jnp.clip(i_t.astype(jnp.float32), -GATE_CLIP, GATE_CLIP)
        m_new = jnp.maximum(log_f + m, log_i)
        i_s = jnp.exp(log_i - m_new)[..., None]
        f_s = jnp.exp(log_f + m - m_new)[..., None]
        c = f_s * c + i_s * z_t
        n = f_s * n + i_s
        h_new = c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h_new), h_new

    if init is None:
        c0 = jnp.zeros((b, h, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
        h0 = jnp.zeros((b, h, dh), jnp.float32)
    else:
        c0, n0, m0, h0 = init
    (c, n, m, hl), hs = jax.lax.scan(
        cell, (c0, n0, m0, h0),
        (zs.transpose(1, 0, 2, 3).astype(jnp.float32),
         igate.transpose(1, 0, 2), fgate.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2, 3), (c, n, m, hl)


# ---------------------------------------------------------------------------
# Block + model
# ---------------------------------------------------------------------------

def _gates_and_streams(x, lp, cfg: ArchConfig):
    di = _proj_dim(cfg)
    h = cfg.n_heads
    dh = di // h
    b, s, _ = x.shape
    up = x @ lp["up_proj"]
    val, gate = jnp.split(up, 2, axis=-1)
    q = (x @ lp["wq"]).reshape(b, s, h, dh)
    k = (x @ lp["wk"]).reshape(b, s, h, dh) / float(np.sqrt(dh))
    ig = x @ lp["w_igate"] + lp["b_igate"]
    fg = x @ lp["w_fgate"] + lp["b_fgate"]
    return val.reshape(b, s, h, dh), gate, q, k, ig, fg


def xlstm_block(x, lp, cfg: ArchConfig, is_slstm: bool,
                state=None):
    """One xLSTM block; state-carrying when ``state`` is not None (decode)."""
    b, s, d = x.shape
    di = _proj_dim(cfg)
    hidden = rms_norm(x, lp["norm"], cfg.norm_eps)
    v, gate, q, k, ig, fg = _gates_and_streams(hidden, lp, cfg)

    if is_slstm:
        hval, new_state = _slstm_scan(v, v.reshape(b, s, di), ig, fg, lp,
                                      init=state)
    else:
        if state is None:
            hval, new_state = _mlstm_parallel(v, q, k, ig, fg, cfg)
        else:
            hval, new_state = _mlstm_step(v[:, 0], q[:, 0], k[:, 0],
                                          ig[:, 0], fg[:, 0], state)
            hval = hval[:, None]
    hval = hval.reshape(b, s, di).astype(x.dtype)
    o = jax.nn.sigmoid(hidden @ lp["w_ogate"])
    y = rms_norm(hval * o, lp["mix_norm"], cfg.norm_eps)
    return x + y @ lp["down_proj"], new_state


# ---------------------------------------------------------------------------
# Model-level API (loss / prefill / decode)
# ---------------------------------------------------------------------------

def _forward(params: Params, cfg: ArchConfig, tokens: jax.Array,
             cache: Optional[Dict] = None):
    """Full forward. cache=None → parallel over S; else single-step decode."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    slstm_mask = jnp.asarray(slstm_layers_mask(cfg))
    b = tokens.shape[0]
    di = _proj_dim(cfg)
    h = cfg.n_heads
    dh = di // h

    if cache is None:
        def body(hcar, per_layer):
            lp, is_s = per_layer
            lp = jax.tree.map(lambda w: w.astype(cdt), lp)

            def s_path(hc):
                out, _ = xlstm_block(hc, lp, cfg, is_slstm=True)
                return out

            def m_path(hc):
                out, _ = xlstm_block(hc, lp, cfg, is_slstm=False)
                return out

            hcar = jax.lax.cond(is_s, s_path, m_path, hcar)
            return hcar.astype(cdt), None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, (params["layers"], slstm_mask))
        new_cache = None
    else:
        def body(hcar, per_layer):
            lp, is_s, mC, mn, sc, sn, sm, sh = per_layer
            lp = jax.tree.map(lambda w: w.astype(cdt), lp)

            def s_path(args):
                hc, states = args
                out, (c2, n2, m2, h2) = xlstm_block(
                    hc, lp, cfg, is_slstm=True,
                    state=(states[2], states[3], states[4], states[5]))
                return out, (states[0], states[1], c2, n2, m2, h2)

            def m_path(args):
                hc, states = args
                out, st = xlstm_block(hc, lp, cfg, is_slstm=False,
                                      state={"C": states[0], "n": states[1]})
                return out, (st["C"], st["n"], states[2], states[3],
                             states[4], states[5])

            hcar, new_states = jax.lax.cond(
                is_s, s_path, m_path, (hcar, (mC, mn, sc, sn, sm, sh)))
            return hcar.astype(cdt), new_states

        xs = (params["layers"], slstm_mask, cache["mC"], cache["mn"],
              cache["sc"], cache["sn"], cache["sm"], cache["sh"])
        x, states = jax.lax.scan(body, x, xs)
        new_cache = dict(cache, mC=states[0], mn=states[1], sc=states[2],
                         sn=states[3], sm=states[4], sh=states[5],
                         len=cache["len"] + 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T.astype(x.dtype)
    logits = logical_constraint(logits, "batch", None, "vocab")
    return logits, new_cache


def loss_fn(params: Params, batch: Dict, *, cfg: ArchConfig) -> jax.Array:
    tokens = batch["tokens"][:, :-1]
    labels = batch["tokens"][:, 1:]
    logits, _ = _forward(params, cfg, tokens)
    return cross_entropy_loss(logits, labels)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               enc_len: int = 0) -> Dict:
    """Recurrent state (O(1) in sequence length — the sub-quadratic point)."""
    del max_len, enc_len
    di = _proj_dim(cfg)
    h = cfg.n_heads
    dh = di // h
    L = cfg.n_layers
    f32 = jnp.float32
    return {
        "mC": jnp.zeros((L, batch, h, dh, dh), f32),
        "mn": jnp.zeros((L, batch, h, dh), f32),
        "sc": jnp.zeros((L, batch, h, dh), f32),
        "sn": jnp.zeros((L, batch, h, dh), f32),
        "sm": jnp.full((L, batch, h), -1e30, f32),
        "sh": jnp.zeros((L, batch, h, dh), f32),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params: Params, batch: Dict, *, cfg: ArchConfig,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """Sequential state build-up via the decode path over the prompt.

    For the dry-run shapes the prefill of a recurrent model is the parallel
    forward + state extraction; for simplicity and because xlstm-125m decode
    dominates its assigned cells, we run the parallel forward for logits and
    a single-step replay for the state of the *last* token only (documented
    simplification: state reflects the last token; serving tests use tiny
    prompts where this is exercised step by step instead).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len or s)

    def step(cache, tok):
        logits, cache = _forward(params, cfg, tok[:, None], cache)
        return cache, logits

    cache, logits = jax.lax.scan(step, cache,
                                 tokens.transpose(1, 0))
    return logits[-1][:, 0], cache


def decode_step(params: Params, cache: Dict, tokens: jax.Array,
                *, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    logits, cache = _forward(params, cfg, tokens[:, None], cache)
    return logits[:, 0], cache
