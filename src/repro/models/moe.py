"""Mixture-of-Experts FFN: top-k routing, capacity-bucketed dispatch, EP.

The dispatch is scatter-based (not dense-einsum): tokens are written into an
[E, C, D] expert buffer at their position-in-expert, expert FFNs run as
batched einsums (MXU-friendly), and results gather back with gate weighting.
Experts shard over the ``model`` mesh axis (expert parallelism); the buffer's
capacity axis shards over ``data``, so the dispatch scatter lowers to an
all-to-all on the expert axis — the direct analogue of TOTEM's outbox/inbox
exchange, with expert load skew playing the role of vertex-degree skew
(DESIGN.md §4).

Tokens over capacity are dropped (standard capacity-factor semantics); the
auxiliary load-balance loss (Shazeer et al.) is returned via a side channel.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, logical_constraint


def moe_layer_params(cfg: ArchConfig) -> Dict[str, tuple]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    return {
        "moe_wg": (d, e),
        "moe_w1": (e, d, 2 * f),   # fused gate+up
        "moe_w2": (e, f, d),
    }


def capacity(tokens: int, cfg: ArchConfig) -> int:
    c = int(tokens * cfg.moe_top_k / cfg.moe_experts
            * cfg.moe_capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(x: jax.Array, lp: Dict[str, Any], cfg: ArchConfig) -> jax.Array:
    """x: [B, S, D] → [B, S, D]."""
    b, s, d = x.shape
    e, k, f = cfg.moe_experts, cfg.moe_top_k, cfg.d_ff
    t = b * s
    xt = x.reshape(t, d)

    # --- routing ------------------------------------------------------------
    logits = xt.astype(jnp.float32) @ lp["moe_wg"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gates, expert_idx = jax.lax.top_k(probs, k)                # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- position-in-expert (capacity bucketing) ----------------------------
    # moe_local (§Perf, beyond-paper): capacity is allocated PER DATA SHARD,
    # so every token's slot lies in its own shard's slice of the buffer and
    # the dispatch scatter compiles to a shard-local write — the TOTEM move
    # of reshaping the workload so boundary communication disappears (§3.4),
    # instead of XLA's zero-buffer + all-reduce scatter merge.
    from repro.models.common import opt_enabled
    from repro.launch.sharding import data_shard_count
    d_shards = data_shard_count() if opt_enabled("moe_local") else 1
    if t % d_shards:
        d_shards = 1
    t_loc = t // d_shards
    c_loc = capacity(t_loc, cfg)
    c = c_loc * d_shards

    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)    # [T, k, E]
    # priority: earlier tokens and higher-rank choices claim slots first
    flat = onehot.reshape(d_shards, t_loc * k, e)
    pos_flat = jnp.cumsum(flat, axis=1) - flat                 # per-shard
    pos = jnp.take_along_axis(
        pos_flat.reshape(t, k, e), expert_idx[..., None], axis=-1)[..., 0]
    keep = pos < c_loc                                         # [T, k]
    slot = jnp.where(keep, pos, c_loc)                         # overflow slot

    # --- dispatch: scatter tokens into [Z, E, c_loc+1, D] -------------------
    # The data-shard axis Z is an explicit *batch dimension* of the scatter
    # (vmap), so SPMD partitions it with zero communication — without it,
    # XLA merges shard contributions with a full-buffer all-reduce
    # (measured: 79 TB/step on qwen3 train_4k, §Perf).
    eix = expert_idx.reshape(d_shards, t_loc * k)
    six = slot.reshape(d_shards, t_loc * k)
    upd = jnp.repeat(xt, k, axis=0).reshape(d_shards, t_loc * k, d)

    def shard_scatter(e_i, s_i, u):
        return jnp.zeros((e, c_loc + 1, d), xt.dtype).at[e_i, s_i].add(u)

    buf = jax.vmap(shard_scatter)(eix, six, upd)   # [Z, E, c_loc+1, D]
    buf = buf[:, :, :c_loc]
    buf = logical_constraint(buf, "expert_cap", "experts", None, None)

    # --- expert computation (batched einsum over Z, E) -----------------------
    # NB: not "ffn" on the F axis — experts already occupy the model axis
    # and one PartitionSpec may not name a mesh axis twice.
    h = jnp.einsum("zecd,edf->zecf", buf, lp["moe_w1"].astype(buf.dtype))
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    h = logical_constraint(h, "expert_cap", "experts", None, None)
    y = jnp.einsum("zecf,efd->zecd", h, lp["moe_w2"].astype(h.dtype))
    y = logical_constraint(y, "expert_cap", "experts", None, None)

    # --- combine: batched gather back + gate weighting ----------------------
    y = jnp.pad(y, ((0, 0), (0, 0), (0, 1), (0, 0)))           # overflow = 0

    def shard_gather(yz, e_i, s_i):
        return yz[e_i, s_i]

    out_tk = jax.vmap(shard_gather)(y, eix, six).reshape(t, k, d)
    out = jnp.sum(out_tk * (gates * keep).astype(out_tk.dtype)[..., None],
                  axis=1)
    return out.reshape(b, s, d)


def load_balance_loss(logits: jax.Array, expert_idx: jax.Array,
                      num_experts: int) -> jax.Array:
    """Shazeer aux loss: E · Σ_e fraction_e · prob_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], num_experts), axis=0)
    return num_experts * jnp.sum(frac * probs.mean(0))


def expert_load_stats(logits: jax.Array, cfg: ArchConfig) -> Dict[str, Any]:
    """Expert token-load histogram — the 'degree distribution' of the MoE
    workload, used by the heterogeneity-aware placement hillclimb."""
    _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe_top_k)
    counts = jnp.sum(jax.nn.one_hot(idx, cfg.moe_experts), axis=(0, 1))
    return {"counts": counts, "max_over_mean":
            counts.max() / jnp.maximum(counts.mean(), 1e-9)}
