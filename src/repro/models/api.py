"""Model API: family dispatch, input specs, train/serve step builders.

Every architecture exposes the same five entry points regardless of family
(transformer / moe / ssm / hybrid / enc-dec / vlm):

  init(key) → params
  loss(params, microbatch) → scalar
  prefill(params, batch) → (last logits, cache)
  decode_step(params, cache, tokens) → (logits, cache)
  init_cache(batch, max_len) → cache

`input_specs` produces ShapeDtypeStruct stand-ins for the dry-run (no
allocation), including cache inputs for decode shapes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig
from repro.optim.adamw import AdamW, AdamWState, apply_updates


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, Dict], jax.Array]
    prefill: Callable[[Any, Dict], Tuple[jax.Array, Dict]]
    decode_step: Callable[[Any, Dict, jax.Array], Tuple[jax.Array, Dict]]
    init_cache: Callable[[int, int], Dict]

    def params_shape(self):
        return jax.eval_shape(self.init, jax.random.key(0))


def build(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        from repro.models import transformer as T
        return Model(
            cfg=cfg,
            init=functools.partial(T.init_params, cfg=cfg),
            loss=functools.partial(T.loss_fn, cfg=cfg),
            prefill=functools.partial(T.prefill, cfg=cfg),
            decode_step=functools.partial(T.decode_step, cfg=cfg),
            init_cache=functools.partial(T.init_cache, cfg),
        )
    if cfg.family == "ssm":
        from repro.models import xlstm as X
        return Model(
            cfg=cfg,
            init=functools.partial(X.init_params, cfg=cfg),
            loss=functools.partial(X.loss_fn, cfg=cfg),
            prefill=functools.partial(X.prefill, cfg=cfg),
            decode_step=functools.partial(X.decode_step, cfg=cfg),
            init_cache=functools.partial(X.init_cache, cfg),
        )
    if cfg.family == "hybrid":
        from repro.models import zamba as Z
        return Model(
            cfg=cfg,
            init=functools.partial(Z.init_params, cfg=cfg),
            loss=functools.partial(Z.loss_fn, cfg=cfg),
            prefill=functools.partial(Z.prefill, cfg=cfg),
            decode_step=functools.partial(Z.decode_step, cfg=cfg),
            init_cache=functools.partial(Z.init_cache, cfg),
        )
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins; also used to synthesize smoke batches)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    b, s = shape.batch, shape.seq
    if shape.kind == "train":
        batch = {"tokens": sds((b, s + 1), i32)}
        if cfg.frontend == "vision":
            batch["patches"] = sds((b, cfg.frontend_len, cfg.d_model),
                                   jnp.float32)
        if cfg.enc_dec:
            batch["frames"] = sds((b, min(s, 4096), cfg.d_model),
                                  jnp.float32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s), i32)}
        if cfg.frontend == "vision":
            batch["patches"] = sds((b, cfg.frontend_len, cfg.d_model),
                                   jnp.float32)
        if cfg.enc_dec:
            batch["frames"] = sds((b, 4096, cfg.d_model), jnp.float32)
        return batch
    # decode: one new token against a seq-long cache
    model = build(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return {"tokens": sds((b,), i32), "cache": cache}


def synth_batch(cfg: ArchConfig, shape: ShapeSpec, seed: int = 0):
    """Materialize a random batch matching input_specs (smoke tests)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)

    def make(spec):
        if jnp.issubdtype(spec.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, cfg.vocab, spec.shape),
                               spec.dtype)
        return jnp.asarray(rng.normal(size=spec.shape) * 0.02, spec.dtype)

    return jax.tree.map(make, specs)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(model: Model, optimizer: Optional[AdamW] = None,
                    microbatches: Optional[int] = None):
    """(params, opt_state, batch) → (params, opt_state, metrics).

    Gradient accumulation over microbatches via lax.scan; XLA overlaps the
    per-microbatch backward with the (reduce-scattered) gradient psum of the
    previous one — the compute/comm overlap the paper gets from double
    buffering (§4.3.4-iv).
    """
    optimizer = optimizer or AdamW()
    n_mb = microbatches or model.cfg.microbatches

    def train_step(params, opt_state: AdamWState, batch):
        def to_mb(x):
            return x.reshape(n_mb, x.shape[0] // n_mb, *x.shape[1:])

        from repro.models.common import opt_enabled
        acc_dtype = (jnp.bfloat16 if opt_enabled("grad_bf16")
                     else jnp.float32)
        mbs = jax.tree.map(to_mb, batch)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dtype), params)

        def mb_step(acc, mb):
            loss, grads = jax.value_and_grad(model.loss)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(acc_dtype),
                               acc, grads)
            return acc, loss

        grads, losses = jax.lax.scan(mb_step, zero_grads, mbs)
        grads = jax.tree.map(lambda g: g / n_mb, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": losses.mean(),
                   "grad_norm_sq": sum(jnp.sum(jnp.square(g))
                                       for g in jax.tree.leaves(grads))}
        return params, opt_state, metrics

    return train_step


def make_serve_step(model: Model, kind: str):
    if kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)
        return prefill_step

    def decode(params, batch):
        return model.decode_step(params, batch["cache"], batch["tokens"])
    return decode
