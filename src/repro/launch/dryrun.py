import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (spec deliverable e).

Lowers + compiles every (architecture × input shape) cell for the single-pod
(16×16) and multi-pod (2×16×16) production meshes on 512 placeholder host
devices, records ``memory_analysis()`` / ``cost_analysis()`` / HLO-parsed
collective bytes, and writes one JSON per cell.

Usage:
  python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all [--mesh both] [--out DIR]

``--all`` runs each cell in a fresh subprocess (isolation: one failing cell
cannot kill the sweep) and skips cells whose JSON already exists.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api
from repro.models.common import ArchConfig
from repro.launch import mesh as M
from repro.launch import sharding as shd
from repro.optim.adamw import AdamW, AdamWState

sys.path.insert(0, str(Path(__file__).resolve().parents[3]))
from benchmarks import calculator, hlo_analysis  # noqa: E402

SHAPES = list(api.SHAPES)
MESHES = {"single": False, "multi": True}


def should_skip(cfg: ArchConfig, shape_name: str) -> str:
    """Spec-mandated skips, recorded (not silently dropped)."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("skipped: long_500k needs sub-quadratic attention; "
                f"{cfg.name} is pure full-attention (DESIGN.md §4)")
    return ""


def _depth_multipliers(cfg: ArchConfig, shape: api.ShapeSpec):
    """Execution-count multiplier per while-nesting depth (hlo_analysis)."""
    s = shape.seq
    nq = max(s // 2048, 1)
    nk = max(s // 1024, 1)
    if shape.kind == "train":
        inner = cfg.attn_every if cfg.family == "hybrid" else cfg.n_layers
        return [1, cfg.microbatches, cfg.microbatches * inner,
                cfg.microbatches * inner * nq,
                cfg.microbatches * inner * nq * nk]
    if shape.kind == "prefill":
        if cfg.family == "ssm":       # xlstm prefill scans over tokens
            return [1, s, s * cfg.n_layers]
        inner = cfg.attn_every if cfg.family == "hybrid" else cfg.n_layers
        return [1, inner, inner * nq, inner * nq * nk]
    # decode
    inner = cfg.attn_every if cfg.family == "hybrid" else cfg.n_layers
    return [1, inner]


def run_cell(arch_id: str, shape_name: str, mesh_name: str) -> dict:
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "ok": False, "opts": os.environ.get("REPRO_OPTS", "")}
    t0 = time.time()

    if arch_id == "totem-rmat":
        return run_graph_cell(shape_name, mesh_name, rec)

    cfg = configs.get(arch_id)
    if ("serve_bf16" in os.environ.get("REPRO_OPTS", "")
            and api.SHAPES.get(shape_name)
            and api.SHAPES[shape_name].kind != "train"):
        # §Perf: serving stores bf16 weights (standard practice) — halves
        # both the resident parameter bytes and the f32→bf16 convert temps.
        import dataclasses as _dc
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
    skip = should_skip(cfg, shape_name)
    if skip:
        rec.update(ok=True, skipped=skip)
        return rec

    shape = api.SHAPES[shape_name]
    mesh = M.make_production_mesh(multi_pod=MESHES[mesh_name])
    model = api.build(cfg)
    params_shape = model.params_shape()
    pspecs = shd.param_specs(params_shape, mesh)
    batch_shape = api.input_specs(cfg, shape)
    num_chips = 512 if MESHES[mesh_name] else 256
    # the single-pod mesh uses only half the placeholder devices
    rec["chips"] = num_chips

    if shape.kind == "train":
        opt = AdamW()
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = AdamWState(step=shd.P(),
                            mu=shd.param_specs(opt_shape.mu, mesh),
                            nu=shd.param_specs(opt_shape.nu, mesh))
        bspecs = shd.batch_specs(batch_shape, mesh)
        step = api.make_train_step(model, opt)

        def wrapped(params, opt_state, batch):
            with shd.activation_rules(mesh, seq_sharded="seq_shard" in os.environ.get("REPRO_OPTS", "")):
                return step(params, opt_state, batch)

        donate = ((0, 1) if "donate" in
                  os.environ.get("REPRO_OPTS", "") else ())
        jitted = jax.jit(
            wrapped,
            in_shardings=(shd.named(pspecs, mesh),
                          shd.named(ospecs, mesh),
                          shd.named(bspecs, mesh)),
            out_shardings=(shd.named(pspecs, mesh),
                           shd.named(ospecs, mesh), None),
            donate_argnums=donate)
        args = (params_shape, opt_shape, batch_shape)
    elif shape.kind == "prefill":
        bspecs = shd.batch_specs(batch_shape, mesh)

        def wrapped(params, batch):
            with shd.activation_rules(mesh, seq_sharded="seq_shard" in os.environ.get("REPRO_OPTS", "")):
                return model.prefill(params, batch)

        jitted = jax.jit(wrapped,
                         in_shardings=(shd.named(pspecs, mesh),
                                       shd.named(bspecs, mesh)))
        args = (params_shape, batch_shape)
    else:  # decode
        cache_shape = batch_shape["cache"]
        cspecs = _cache_specs(cache_shape, mesh)
        tok_spec = shd.batch_specs({"tokens": batch_shape["tokens"]},
                                   mesh)["tokens"]

        def wrapped(params, cache, tokens):
            with shd.activation_rules(mesh, seq_sharded="seq_shard" in os.environ.get("REPRO_OPTS", "")):
                return model.decode_step(params, cache, tokens)

        jitted = jax.jit(
            wrapped,
            in_shardings=(shd.named(pspecs, mesh),
                          shd.named(cspecs, mesh),
                          jax.sharding.NamedSharding(mesh, tok_spec)),
            donate_argnums=(1,))
        args = (params_shape, cache_shape, batch_shape["tokens"])

    lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory_analysis"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_estimate_gib": round(
            (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 2**30, 3),
    }
    ca = compiled.cost_analysis() or {}
    rec["cost_analysis_raw"] = {
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
    }
    mults = _depth_multipliers(configs.get(arch_id), api.SHAPES[shape_name])
    cb = hlo_analysis.collective_bytes(compiled.as_text(), mults)
    rec["collective_bytes"] = {k: v for k, v in cb.items()
                               if k != "by_depth"}
    rec["collective_by_depth"] = cb["by_depth"]
    rec["depth_multipliers"] = mults

    roof = calculator.analyze(configs.get(arch_id), api.SHAPES[shape_name],
                              num_chips,
                              ici_bytes_measured=cb["total"] / num_chips
                              if cb["total"] else None)
    rec["roofline"] = roof.as_dict()
    rec["ok"] = True
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def _cache_specs(cache_shape, mesh):
    """Decode caches: batch dim sharded over data(+pod), head/expert dims on
    model where divisible."""
    fsdp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    seq_shard = "cache_seq_shard" in os.environ.get("REPRO_OPTS", "")

    def leaf(x):
        if len(x.shape) == 0:
            return shd.P()
        spec = [None] * len(x.shape)
        # leading L/group axis unsharded; batch axis = index 1 where present
        bdim = 1 if len(x.shape) >= 2 else 0
        if x.shape[bdim] % (int(jnp.prod(jnp.asarray(
                [mesh.shape[a] for a in fsdp])))) == 0:
            spec[bdim] = fsdp
        # cache_seq_shard (§Perf): split-KV decode — shard the sequence dim
        # of [L, B, S, G, hd] caches over 'model' (the attention contraction
        # partitions cleanly; kv-head counts rarely divide the axis).
        if (seq_shard and len(x.shape) >= 5
                and x.shape[2] % mesh.shape["model"] == 0):
            spec[2] = "model"
        # default: kv-head axis over model when divisible (axis -2 for k/v)
        elif len(x.shape) >= 4 and x.shape[-2] % mesh.shape["model"] == 0:
            spec[-2] = "model"
        elif len(x.shape) >= 3 and x.shape[-1] % mesh.shape["model"] == 0:
            spec[-1] = "model"
        return shd.P(*spec)

    return jax.tree.map(leaf, cache_shape)


# ---------------------------------------------------------------------------
# The paper's own workload: BSP PageRank superstep on 512 partitions
# ---------------------------------------------------------------------------

def run_graph_cell(shape_name: str, mesh_name: str, rec: dict) -> dict:
    """Lower the TOTEM BSP superstep for RMAT28-like partition shapes."""
    import numpy as np
    from repro.core.bsp import _superstep, _Dims, VertexProgram, SUM

    t0 = time.time()
    multi = MESHES[mesh_name]
    n_dev = 512 if multi else 256
    mesh = jax.make_mesh((n_dev,), ("parts",))
    scale, ef = 28, 16
    v_total, e_total = 1 << scale, (1 << scale) * ef
    v_max = -(-v_total // n_dev)
    e_max = int(e_total / n_dev * 1.5)          # skew headroom
    o_max = min(v_max, e_max) // 4
    dims = _Dims(n_dev, v_max, e_max, o_max)

    msg_dtype = (jnp.bfloat16 if "graph_bf16_msgs" in
                 os.environ.get("REPRO_OPTS", "") else jnp.float32)

    def edge_fn(state, src, weight, step):
        from repro.core.bsp import gather_src
        return gather_src(state["rank"], src).astype(msg_dtype)

    def apply_fn(state, acc, step):
        acc = acc.astype(jnp.float32)
        return {"rank": 0.15 / v_total + 0.85 * acc}, jnp.bool_(True)

    program = VertexProgram(combine=SUM, edge_fn=edge_fn, apply_fn=apply_fn,
                            max_steps=20)

    def local_fn(state, edges):
        def exchange(outbox):
            pl = outbox.shape[0]
            ob = outbox.reshape(pl, n_dev, pl, outbox.shape[-1])
            recv = jax.lax.all_to_all(ob, "parts", split_axis=1,
                                      concat_axis=0, tiled=False)
            recv = recv.transpose(2, 0, 1, 3)
            return recv.reshape(pl, n_dev * pl, outbox.shape[-1])

        def fin(x):
            return jax.lax.psum(jnp.int32(0), "parts") == 0

        state, _ = _superstep(dims, program, edges, exchange, fin, None,
                              state, jnp.int32(0))
        return state

    P = jax.sharding.PartitionSpec
    sds = jax.ShapeDtypeStruct
    state = {"rank": sds((n_dev, v_max), jnp.float32)}
    edges = {"src": sds((n_dev, e_max), jnp.int32),
             "dst_ext": sds((n_dev, e_max), jnp.int32),
             "inbox_dst": sds((n_dev, n_dev, o_max), jnp.int32)}
    from repro.core.compat import shard_map
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(jax.tree.map(lambda _: P("parts"), state),
                             jax.tree.map(lambda _: P("parts"), edges)),
                   out_specs=jax.tree.map(lambda _: P("parts"), state),
                   check_vma=False)
    jitted = jax.jit(fn)
    lowered = jitted.lower(state, edges)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    ma = compiled.memory_analysis()
    rec["memory_analysis"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "peak_estimate_gib": round(
            (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 2**30, 3),
    }
    ca = compiled.cost_analysis() or {}
    rec["cost_analysis_raw"] = {"flops": ca.get("flops", 0.0),
                                "bytes_accessed": ca.get("bytes accessed",
                                                         0.0)}
    cb = hlo_analysis.collective_bytes(compiled.as_text(), [1])
    rec["collective_bytes"] = {k: v for k, v in cb.items()
                               if k != "by_depth"}
    # analytic: one superstep ≈ memory-bound edge traffic
    hbm = e_max * 8 + v_max * 4 * 3 + n_dev * o_max * 4 * 2
    rec["roofline"] = {
        "flops": 2.0 * e_max, "hbm_bytes": float(hbm),
        "ici_bytes": cb["total"] / n_dev,
        "model_flops": 2.0 * e_total,
        "compute_s": 2.0 * e_max / calculator.PEAK_FLOPS,
        "memory_s": hbm / calculator.HBM_BW,
        "collective_s": (cb["total"] / n_dev) / calculator.ICI_BW,
        "dominant": "memory",
        "useful_ratio": 1.0,
    }
    rec["ok"] = True
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def all_cells():
    cells = [(a, s) for a in configs.all_ids() for s in SHAPES]
    cells.append(("totem-rmat", "pagerank_superstep"))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    if args.all:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = 0
        for arch, shape in all_cells():
            for mesh_name in meshes:
                path = out / f"{arch}__{shape}__{mesh_name}.json"
                if path.exists() and json.loads(path.read_text()).get("ok"):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", mesh_name, "--out", str(out)]
                print(f"[dryrun] {arch} × {shape} × {mesh_name} ...",
                      flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    failures += 1
                    path.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mesh_name,
                         "ok": False, "error": r.stderr[-4000:]}, indent=1))
                    print(f"  FAILED: {r.stderr.splitlines()[-1][:200]}"
                          if r.stderr else "  FAILED", flush=True)
                else:
                    print("  ok", flush=True)
        return 1 if failures else 0

    from repro.runtime.failures import RETRYABLE_EXCEPTIONS

    rec = {}
    try:
        rec = run_cell(args.arch, args.shape, args.mesh)
    except (ValueError, TypeError, NotImplementedError,
            RuntimeError) + RETRYABLE_EXCEPTIONS:
        # Expected dry-run outcomes (shape/config rejections, XLA compile
        # and runtime errors, worker faults) become a failed cell record;
        # anything else — a programming bug — propagates with a traceback.
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "ok": False, "error": traceback.format_exc()[-4000:]}
    path = out / f"{args.arch}__{args.shape}__{args.mesh}.json"
    path.write_text(json.dumps(rec, indent=1, default=float))
    if rec.get("ok"):
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "ok") if k in rec}))
        if "memory_analysis" in rec:
            print("memory:", rec["memory_analysis"])
            print("cost:", rec["cost_analysis_raw"])
        return 0
    print(rec.get("error", "")[-2000:], file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
