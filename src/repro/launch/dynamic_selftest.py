"""Dynamic-graph parity self-test: the incremental contract across the full
backend × strategy matrix at a forced device count.

For every backend {reference, fused, hybrid} × strategy {rand, high, low}:
apply a deterministic mutation stream (inserts + deletes,
``data.graphs.edge_stream``) to a resident :class:`DynamicGraph`, then
assert that running on the mutated-in-place layout equals a from-scratch
partition + run of the canonically mutated graph — bitwise for the min /
min-plus algorithms (BFS, SSSP), f32-allclose for the sum path (PageRank,
whose delta tail / dense-block writes legitimately reassociate).  An
insert-only window then checks monotone warm-start parity, and the jit
cache is asserted not to grow across mutation batches (the zero-retrace
contract).  With >1 device the same matrix runs through
``DistributedBSPEngine`` (the hybrid backend consumes mutations via
compaction there — docs/dynamic.md).  Invoked in a subprocess so the
forced device count never leaks:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      python -m repro.launch.dynamic_selftest [--scale 8] [--parts 4]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--edge-factor", type=int, default=4)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--seed", type=int, default=13)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=24)
    args = ap.parse_args(argv)

    import jax
    from repro.core import bsp
    from repro.core import graph as G
    from repro.core import partition as PT
    from repro.core.bsp import BSPEngine, DistributedBSPEngine
    from repro.core.dynamic import DynamicGraph
    from repro.core.graph import apply_mutation_batches
    from repro.data.graphs import edge_stream
    from repro.algorithms.bfs import bfs_batched, bfs_incremental
    from repro.algorithms.sssp import sssp_batched, sssp_incremental
    from repro.algorithms.pagerank import pagerank

    n_dev = len(jax.devices())
    assert args.parts % n_dev == 0, (args.parts, n_dev)
    mesh = jax.make_mesh((n_dev,), ("parts",))
    distributed = n_dev > 1

    g = G.rmat(args.scale, args.edge_factor,
               seed=args.seed).with_uniform_weights(seed=1)
    stream = edge_stream(g, args.batches, args.batch_size, churn=0.7,
                         seed=args.seed)
    g_mut = apply_mutation_batches(g, stream)
    sources = [0, 3, 17, 91]

    backends = {"reference": dict(), "fused": dict(fused=True, block_e=256),
                "hybrid": dict(backend="hybrid")}
    for strategy in PT.STRATEGIES:
        pg_mut = PT.partition(g_mut, args.parts, strategy)
        want_bfs, _ = bfs_batched(BSPEngine(pg_mut), sources)
        want_sssp, _ = sssp_batched(BSPEngine(pg_mut), sources)
        want_pr = pagerank(BSPEngine(pg_mut), num_iterations=8)
        for name, kw in backends.items():
            dg = DynamicGraph(g, args.parts, strategy,
                              mutation_capacity=4 * args.batch_size)
            if distributed:
                eng = DistributedBSPEngine(dg, mesh, **kw)
            else:
                eng = BSPEngine(dg, **kw)
            # compile the retrace-gated programs before the stream starts
            # (pagerank builds a fresh program object per call — a per-call
            # retrace by construction, identical on the static engine, so
            # it sits outside the guard)
            bfs_batched(eng, sources)
            sssp_batched(eng, sources)
            caches = [bsp._run_dyn_jit, bsp._run_dyn_hybrid_jit]
            entries0 = sum(f._cache_size() for f in caches)
            for mb in stream:
                dg.apply_mutations(mb)
            # mutate-then-rerun == from-scratch rebuild of the mutated graph
            got_bfs, _ = bfs_batched(eng, sources)
            np.testing.assert_array_equal(got_bfs, want_bfs)      # min
            got_sssp, _ = sssp_batched(eng, sources)
            np.testing.assert_array_equal(got_sssp, want_sssp)    # min-plus
            if not distributed and dg.compactions == 0:
                # zero-retrace contract: same-shape batches reuse the
                # compiled loops (distributed shard_map closures are
                # per-call; the single-device runner is the gated path)
                grown = sum(f._cache_size() for f in caches) - entries0
                assert grown == 0, (name, strategy, grown)
            got_pr = pagerank(eng, num_iterations=8)
            np.testing.assert_allclose(got_pr, want_pr, rtol=1e-5,
                                       atol=1e-8)                 # f32 sum

            # monotone warm start from the current fixpoint
            mark = dg.mark()
            ins = edge_stream(dg.mutated_csr(), 1, args.batch_size,
                              churn=1.0, seed=args.seed + 7)[0]
            dg.apply_mutations(ins)
            dirty, monotone = dg.dirty_since(mark)
            assert monotone
            warm_bfs, wsteps = bfs_incremental(eng, got_bfs, dirty)
            cold_bfs, csteps = bfs_batched(eng, sources)
            np.testing.assert_array_equal(warm_bfs, cold_bfs)     # bitwise
            assert int(wsteps.max()) <= int(csteps.max())
            warm_sssp, _ = sssp_incremental(eng, got_sssp, dirty)
            cold_sssp, _ = sssp_batched(eng, sources)
            np.testing.assert_array_equal(warm_sssp, cold_sssp)   # bitwise

            # compaction round-trip: fold everything, rerun, same answer
            dg.compact()
            post_bfs, _ = bfs_batched(eng, sources)
            np.testing.assert_array_equal(post_bfs, cold_bfs)
        print(f"{strategy:>4}: bfs/sssp/pagerank mutate-rerun parity + "
              f"warm-start + compaction over {n_dev} device(s)", flush=True)

    print("DYNAMIC SELFTEST OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
