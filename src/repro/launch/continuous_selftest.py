"""Continuous-batching parity self-test on ``DistributedBSPEngine``.

One resident session (per backend: fused shard_map and hybrid) serves a
mixed-convergence stream of 4x its slot count: converged slots are
compacted out at chunk boundaries (finished votes psum'd across shards)
and refilled from the queue.  Every completed query must be **bitwise**
equal to the single-device drain-batch reference, with zero retraces
after warmup and every slot refilled at least once.  Invoked in a
subprocess so the forced device count never leaks into the caller's jax
runtime:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      python -m repro.launch.continuous_selftest [--scale 8] [--parts 4]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--edge-factor", type=int, default=4)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--seed", type=int, default=13)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--alg", default="bfs", choices=("bfs", "sssp"))
    args = ap.parse_args(argv)

    import jax
    from repro.core import graph as G
    from repro.core import partition as PT
    from repro.core.bsp import BSPEngine, DistributedBSPEngine
    from repro.runtime import ServeSession, drain_reference

    n_dev = len(jax.devices())
    assert args.parts % n_dev == 0, (args.parts, n_dev)
    mesh = jax.make_mesh((n_dev,), ("parts",))
    g = G.rmat(args.scale, args.edge_factor,
               seed=args.seed).with_uniform_weights(seed=1)
    pg = PT.partition(g, args.parts, PT.HIGH)
    ref_engine = BSPEngine(pg)

    rng = np.random.default_rng(args.seed)
    deg = g.out_degrees()
    # mixed convergence by construction: hub + fringe + random sources
    # converge at very different supersteps, so slots free asymmetrically
    stream = np.concatenate([
        [int(np.argmax(deg)), int(np.argmin(deg))],
        rng.integers(0, g.num_vertices, size=4 * args.batch - 2)])
    want = drain_reference(ref_engine, args.alg, stream, args.batch)

    engines = [("dist_fused", DistributedBSPEngine(pg, mesh, fused=True)),
               ("dist_hybrid", DistributedBSPEngine(pg, mesh,
                                                    backend="hybrid"))]
    for name, eng in engines:
        session = ServeSession(eng, args.alg, slots=args.batch, chunk=2)
        qids = session.submit(stream)
        rep = session.drain()
        results = {r["query"]: r["result"] for r in session.poll()}
        assert len(results) == len(stream), (len(results), len(stream))
        for qid, row in zip(qids, want):
            np.testing.assert_array_equal(results[qid], row)  # bitwise
        assert rep["refills"] >= 3 * args.batch - args.batch, rep
        assert rep["min_slot_refills"] >= 1, rep
        assert rep["retraces"] == 0, rep
        print(f"{name}: {len(stream)} queries through {args.batch} "
              f"resident slots over {n_dev} device(s) — "
              f"refills={rep['refills']} "
              f"(min/slot={rep['min_slot_refills']}), "
              f"retraces={rep['retraces']}, bitwise parity", flush=True)

    print("CONTINUOUS SELFTEST OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
