"""Direction-optimized traversal parity self-test: every min-combine
traversal (BFS, SSSP, CC) through forced ``direction="push"``, forced
``"pull"``, and per-shard ``"auto"`` on every backend, against the
single-device push reference — bitwise.  Invoked in a subprocess so the
forced device count never leaks into the caller's jax runtime:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      python -m repro.launch.direction_selftest [--scale 8] [--parts 4]

Direction is a pure performance choice for min combines (push and pull
reduce the same value multiset per destination — docs/traversal.md), so
every cell of the matrix must agree exactly, and the auto runs must
additionally report live ``last_direction_stats`` (edges examined > 0 on
every query, zero switches under forced directions).
"""
from __future__ import annotations

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--edge-factor", type=int, default=4)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--seed", type=int, default=13)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.algorithms.bfs import bfs_batched
    from repro.algorithms.cc import connected_components, symmetrize
    from repro.algorithms.sssp import sssp_batched
    from repro.core import graph as G
    from repro.core import partition as PT
    from repro.core.bsp import BSPEngine, DistributedBSPEngine

    n_dev = len(jax.devices())
    assert args.parts % n_dev == 0, (args.parts, n_dev)
    mesh = jax.make_mesh((n_dev,), ("parts",))
    g = G.rmat(args.scale, args.edge_factor,
               seed=args.seed).with_uniform_weights(seed=1)
    gs = symmetrize(G.rmat(args.scale, args.edge_factor, seed=args.seed))
    pg = PT.partition(g, args.parts, PT.HIGH)
    pgs = PT.partition(gs, args.parts, PT.HIGH)
    sources = [0, 3, 11]

    backends = {"reference": dict(), "fused": dict(fused=True, block_e=256),
                "hybrid": dict(backend="hybrid")}

    # single-device push baselines (the repo's long-standing oracle chain
    # ends at the numpy references; parity suites pin that elsewhere)
    base = BSPEngine(pg, direction="push")
    want_bfs, _ = bfs_batched(base, sources)
    want_sssp, _ = sssp_batched(base, sources)
    want_cc, _ = connected_components(BSPEngine(pgs, direction="push"))

    for bname, kw in backends.items():
        for direction in ("push", "pull", "auto"):
            eng = DistributedBSPEngine(pg, mesh, direction=direction, **kw)
            got_bfs, _ = bfs_batched(eng, sources)
            np.testing.assert_array_equal(want_bfs, got_bfs,
                                          err_msg=f"bfs {bname} {direction}")
            st = eng.last_direction_stats
            assert st is not None and (st["edges_examined"] > 0).all(), \
                (bname, direction, st)
            if direction != "auto":
                assert (st["switches"] == 0).all(), (bname, direction, st)

            got_sssp, _ = sssp_batched(eng, sources)
            np.testing.assert_array_equal(
                want_sssp, got_sssp, err_msg=f"sssp {bname} {direction}")

            ec = DistributedBSPEngine(pgs, mesh, direction=direction, **kw)
            got_cc, _ = connected_components(ec)
            np.testing.assert_array_equal(want_cc, got_cc,
                                          err_msg=f"cc {bname} {direction}")
        print(f"{bname:>9}: bfs/sssp/cc push==pull==auto over "
              f"{n_dev} device(s)", flush=True)

    print(f"DIRECTION SELFTEST OK ({n_dev} devices)")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
