"""Distributed-hybrid parity self-test: every algorithm through
``DistributedBSPEngine(backend="hybrid")`` against the single-device
reference engine, across partitioning strategies.  Invoked in a subprocess
so the forced device count never leaks into the caller's jax runtime:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      python -m repro.launch.hybrid_selftest [--scale 9] [--parts 4]

Min combines (BFS, SSSP, CC) are compared exactly; sum combines (PageRank,
BC) to f32 tolerance (the dense/ELL split and the outbox aggregation
reassociate the sums).  With a single device the suite also covers the
``P=1`` single-partition case — an entirely empty outbox (no boundary
edges, no exchange), the degenerate end of the compact-exchange contract.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=9)
    ap.add_argument("--edge-factor", type=int, default=4)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--seed", type=int, default=13)
    args = ap.parse_args(argv)

    import jax
    from repro.core import graph as G
    from repro.core import partition as PT
    from repro.core.bsp import BSPEngine, DistributedBSPEngine
    from repro.algorithms.bfs import bfs
    from repro.algorithms.sssp import sssp
    from repro.algorithms.pagerank import pagerank, pagerank_distributed
    from repro.algorithms.cc import connected_components, symmetrize
    from repro.algorithms.bc import betweenness_centrality

    n_dev = len(jax.devices())
    assert args.parts % n_dev == 0, (args.parts, n_dev)
    mesh = jax.make_mesh((n_dev,), ("parts",))
    g = G.rmat(args.scale, args.edge_factor,
               seed=args.seed).with_uniform_weights(seed=1)
    gs = symmetrize(G.rmat(args.scale, args.edge_factor, seed=args.seed))

    for strategy in PT.STRATEGIES:
        pg = PT.partition(g, args.parts, strategy, include_reverse=True)
        ref = BSPEngine(pg)
        hyb = DistributedBSPEngine(pg, mesh, backend="hybrid")
        plan = hyb.hybrid_plan()
        ks = [rec["k_dense"] for rec in plan["per_shard"]]

        lr, sr = bfs(ref, 0)
        lh, sh = bfs(hyb, 0)
        np.testing.assert_array_equal(lr, lh)      # min combine: exact
        assert sr == sh, (sr, sh)

        dr, _ = sssp(ref, 0)
        dh, _ = sssp(hyb, 0)
        np.testing.assert_array_equal(dr, dh)      # min combine: exact

        pr = pagerank(ref, num_iterations=10)
        ph = pagerank_distributed(hyb, num_iterations=10)
        np.testing.assert_allclose(pr, ph, rtol=1e-5, atol=1e-8)

        br, s1 = betweenness_centrality(ref, 0)
        bh, s2 = betweenness_centrality(hyb, 0)
        assert s1 == s2, (s1, s2)
        np.testing.assert_allclose(br, bh, rtol=1e-4, atol=1e-4)

        pgs = PT.partition(gs, args.parts, strategy)
        cr, _ = connected_components(BSPEngine(pgs))
        ch, _ = connected_components(
            DistributedBSPEngine(pgs, mesh, backend="hybrid"))
        np.testing.assert_array_equal(cr, ch)      # min combine: exact

        print(f"{strategy:>4}: bfs/sssp/pagerank/bc/cc parity over "
              f"{n_dev} device(s), per-shard k={ks}", flush=True)

    if n_dev == 1:
        # P=1: no peers, no boundary edges, empty outbox — the engine must
        # statically skip the exchange and still match the reference.
        pg1 = PT.partition(g, 1, PT.RAND)
        assert float(pg1.beta_with_reduction) == 0.0
        lr, _ = bfs(BSPEngine(pg1), 0)
        lh, _ = bfs(DistributedBSPEngine(pg1, mesh, backend="hybrid"), 0)
        np.testing.assert_array_equal(lr, lh)
        dr, _ = sssp(BSPEngine(pg1), 0)
        dh, _ = sssp(DistributedBSPEngine(pg1, mesh, backend="hybrid"), 0)
        np.testing.assert_array_equal(dr, dh)
        print("P=1 empty-outbox edge case: parity", flush=True)

    print("HYBRID SELFTEST OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
