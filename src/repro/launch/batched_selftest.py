"""Batched-query parity self-test: multi-source bfs/sssp/bc and
personalized PageRank through ``DistributedBSPEngine`` (fused and hybrid
backends) against the single-device sequential reference, plus the Q=1
no-regression and mixed-convergence cases.  Invoked in a subprocess so the
forced device count never leaks into the caller's jax runtime:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      python -m repro.launch.batched_selftest [--scale 8] [--parts 4]

Min combines (BFS, SSSP) are compared exactly; sum combines (BC, PPR) to
f32 tolerance (the shard split and outbox aggregation reassociate sums).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--edge-factor", type=int, default=4)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--seed", type=int, default=13)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args(argv)

    import jax
    from repro.core import graph as G
    from repro.core import partition as PT
    from repro.core.bsp import BSPEngine, DistributedBSPEngine
    from repro.algorithms import (betweenness_centrality,
                                  betweenness_centrality_batched, bfs,
                                  bfs_batched, personalized_pagerank,
                                  personalized_pagerank_reference, sssp,
                                  sssp_batched)

    n_dev = len(jax.devices())
    assert args.parts % n_dev == 0, (args.parts, n_dev)
    mesh = jax.make_mesh((n_dev,), ("parts",))
    g = G.rmat(args.scale, args.edge_factor,
               seed=args.seed).with_uniform_weights(seed=1)
    pg = PT.partition(g, args.parts, PT.HIGH, include_reverse=True)
    ref = BSPEngine(pg)

    rng = np.random.default_rng(args.seed)
    # Mixed convergence by construction: the max-degree hub and a random
    # low-degree tail vertex have very different eccentricities, so some
    # queries vote finish supersteps before others.
    deg = g.out_degrees()
    sources = np.unique(np.concatenate([
        [int(np.argmax(deg)), int(np.argmin(deg))],
        rng.integers(0, g.num_vertices, size=args.batch)]))[:args.batch]

    reset = rng.random((args.batch, g.num_vertices)).astype(np.float32)
    reset /= reset.sum(axis=1, keepdims=True)
    ppr_want = personalized_pagerank_reference(g, reset, num_iterations=8)

    engines = [("dist_fused", DistributedBSPEngine(pg, mesh, fused=True)),
               ("dist_hybrid", DistributedBSPEngine(pg, mesh,
                                                    backend="hybrid"))]
    for name, eng in engines:
        lv, steps = bfs_batched(eng, sources)
        dv, _ = sssp_batched(eng, sources)
        bcv, _ = betweenness_centrality_batched(eng, sources)
        for i, s in enumerate(sources):
            want_l, want_steps = bfs(ref, int(s))
            np.testing.assert_array_equal(lv[i], want_l)   # min: exact
            assert int(steps[i]) == want_steps, (s, int(steps[i]),
                                                 want_steps)
            want_d, _ = sssp(ref, int(s))
            np.testing.assert_array_equal(dv[i], want_d)   # min: exact
            want_b, _ = betweenness_centrality(ref, int(s))
            np.testing.assert_allclose(bcv[i], want_b, rtol=1e-4, atol=1e-4)
        assert len(set(int(x) for x in steps)) > 1, (
            "sources were expected to converge at different supersteps "
            f"(got {steps}) — mixed convergence not exercised")

        ppr = personalized_pagerank(eng, reset, num_iterations=8)
        np.testing.assert_allclose(ppr, ppr_want, rtol=1e-4, atol=1e-7)

        # Q=1 no-regression: the batched path with one query == run().
        lv1, st1 = bfs_batched(eng, [int(sources[0])])
        np.testing.assert_array_equal(lv1[0], lv[0])
        assert int(st1[0]) == int(steps[0])

        print(f"{name}: batched bfs/sssp/bc/ppr parity over {n_dev} "
              f"device(s), Q={len(sources)}, steps={list(map(int, steps))}",
              flush=True)

    print("BATCHED SELFTEST OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
