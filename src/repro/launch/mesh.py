"""Production mesh construction (spec §Multi-pod dry-run).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """A small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The (super-)data-parallel axes of a mesh: ('pod','data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
