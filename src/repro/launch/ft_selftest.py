"""Elastic checkpoint/restart selftest (subprocess-driven).

Proves the tentpole elasticity claim: a chunked-run snapshot taken on N
devices resumes **bitwise identically** on a different device count,
because ``CheckpointManager`` stores leaves unsharded and
``restore_resharded`` re-places them under any mesh.

Two modes, orchestrated by ``tests/test_resilience.py`` over a shared
checkpoint directory with forced host device counts
(``--xla_force_host_platform_device_count``):

- ``--mode snapshot``: build the distributed engine on all visible
  devices, advance a BFS query batch by one checkpoint chunk, persist the
  full carry via ``save_tree``, then run to the fixpoint and record the
  reference result.
- ``--mode resume``: on a *different* device count, ``restore_resharded``
  the carry, resume the chunked run, and assert the fixpoint (levels and
  per-query superstep counts) equals the reference bitwise.
"""
from __future__ import annotations

import argparse
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.algorithms.bfs import BFS_PROGRAM, gather_batch, \
    multi_source_state
from repro.checkpoint.manager import CheckpointManager, restore_resharded
from repro.core import graph as G
from repro.core import partition as PT
from repro.core.bsp import DistributedBSPEngine


def build(args):
    g = G.rmat(args.scale, 8, seed=args.seed)
    pg = PT.partition(g, args.parts, "high")
    mesh = jax.make_mesh((len(jax.devices()),), ("parts",))
    eng = DistributedBSPEngine(pg, mesh)
    rng = np.random.default_rng(args.seed + 1)
    sources = rng.integers(0, g.num_vertices, size=(args.queries, 1))
    return pg, mesh, eng, sources


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["snapshot", "resume"], required=True)
    ap.add_argument("--ckpt", required=True)
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    pg, mesh, eng, sources = build(args)
    mgr = CheckpointManager(args.ckpt, keep=3)
    ref_path = Path(args.ckpt) / "final_reference.npz"
    ndev = len(jax.devices())

    if args.mode == "snapshot":
        state0 = {"level": jnp.asarray(multi_source_state(pg, sources))}
        st, sq, info = eng.execute(
            BFS_PROGRAM, state0, chunk=args.chunk, max_chunks=1)
        step = info["final_step"]
        mgr.save_tree(step, {"state": st, "fin": info["finished"],
                             "steps_q": sq},
                      extra={"step": step, "devices": ndev}, blocking=True)
        final, fsq, _ = eng.execute(
            BFS_PROGRAM, st, chunk=args.chunk, start_step=step,
            fin=info["finished"], steps_q=sq)
        np.savez(ref_path, level=gather_batch(pg, final["level"]),
                 steps=np.asarray(fsq))
        print(f"FT SNAPSHOT OK devices={ndev} step={step}")
        return 0

    like = {"state": {"level": np.zeros(
                (args.queries, args.parts, pg.v_max), np.float32)},
            "fin": np.zeros(args.queries, bool),
            "steps_q": np.zeros(args.queries, np.int32)}
    spec = {"state": {"level": P(None, "parts")}, "fin": P(),
            "steps_q": P()}
    step, tree = restore_resharded(mgr, like, mesh, spec)
    assert step == mgr.manifest_extra(step)["step"]
    final, sq, _ = eng.execute(
        BFS_PROGRAM, tree["state"], chunk=args.chunk,
        start_step=step, fin=tree["fin"], steps_q=tree["steps_q"])
    ref = np.load(ref_path)
    got = gather_batch(pg, final["level"])
    assert np.array_equal(got, ref["level"]), \
        "resumed fixpoint differs from the snapshot-device reference"
    assert np.array_equal(np.asarray(sq), ref["steps"]), \
        "per-query superstep counts differ after resharded resume"
    src_dev = mgr.manifest_extra(step)["devices"]
    print(f"FT RESUME OK devices={src_dev}->{ndev} step={step}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
