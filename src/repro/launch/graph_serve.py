"""Batched graph query-serving driver — the throughput face of the engine.

The paper evaluates BFS/SSSP/BC "for a single source" (Table 4); a serving
deployment instead amortizes **one resident partitioned graph** across many
concurrent queries.  This driver is that regime end to end:

  1. load a synthetic workload (RMAT / uniform, the paper's Table 2
     generators) and partition it once;
  2. build one engine (reference / fused / hybrid backend) — the graph
     topology, block metadata, and degree splits stay device-resident for
     the whole run;
  3. drain a synthetic query stream in fixed-size batches of Q sources:
     every batch runs through **one** compiled ``lax.while_loop``
     (``BSPEngine.run_batched``), so per-query cost amortizes the dispatch,
     kernel-launch, and graph-residency overheads Q ways;
  4. report queries/sec, per-query latency percentiles (a query's latency
     is its batch's wall time — batch-synchronous serving), the amortized
     per-query time, and the engine's compile-cache growth across batches
     (0 retraces after warmup is the serving contract).

  PYTHONPATH=src python -m repro.launch.graph_serve \
      [--scale 12] [--parts 4] [--alg bfs] [--batch 32] \
      [--num-queries 256] [--backend fused] [--out serve_report.json]

``--smoke`` shrinks everything for CI.  The first batch per algorithm pays
compilation and is reported separately (``cold_ms``); steady-state numbers
exclude it.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _percentile(vals, p: float) -> float:
    if not len(vals):
        return float("nan")
    return float(np.percentile(vals, p, method="nearest"))


def run_query_batch(engine, alg: str, sources: np.ndarray) -> np.ndarray:
    """Dispatch one batch of queries; returns the [Q, n] result block."""
    from repro.algorithms import (betweenness_centrality_batched,
                                  bfs_batched, personalized_pagerank,
                                  sssp_batched)

    if alg == "bfs":
        return bfs_batched(engine, sources)[0]
    if alg == "sssp":
        return sssp_batched(engine, sources)[0]
    if alg == "bc":
        return betweenness_centrality_batched(engine, sources)[0]
    if alg == "ppr":
        return personalized_pagerank(engine, sources, num_iterations=10)
    raise ValueError(f"unknown algorithm {alg!r}")


def serve(engine, alg: str, sources: np.ndarray, batch: int,
          check_fn=None) -> dict:
    """Drain ``sources`` in batches of ``batch``; returns the metrics dict.

    ``check_fn(sources, results)`` optionally validates a batch (the
    selftest hook).  The query stream is padded to a whole number of
    batches with repeats of its head so every batch compiles to the same Q.
    """
    num = len(sources)
    pad = (-num) % batch
    # np.resize repeats the stream cyclically, so padding works even when
    # pad > num (a stream shorter than one batch).
    stream = np.resize(sources, num + pad)
    batches = stream.reshape(-1, batch)

    cache_fn = type(engine).run_batched
    entries0 = None
    lat_ms, cold_ms = [], None
    served = 0
    t_all = time.perf_counter()
    for i, srcs in enumerate(batches):
        t0 = time.perf_counter()
        out = run_query_batch(engine, alg, srcs)
        dt = (time.perf_counter() - t0) * 1e3
        if i == 0:
            cold_ms = dt               # includes compilation
            try:
                entries0 = cache_fn._cache_size()
            except AttributeError:     # non-jitted run_batched (distributed)
                entries0 = None
        else:
            lat_ms.append(dt)
        served += batch
        if check_fn is not None:
            check_fn(srcs, out)
    wall_s = time.perf_counter() - t_all

    retraces = 0
    if entries0 is not None:
        retraces = cache_fn._cache_size() - entries0

    warm_s = sum(lat_ms) / 1e3
    warm_queries = max(served - batch, 0)
    report = dict(
        algorithm=alg, batch=batch, num_queries=num,
        batches=len(batches), cold_ms=cold_ms,
        queries_per_sec=(warm_queries / warm_s) if warm_s > 0 else None,
        ms_per_query=(warm_s * 1e3 / warm_queries) if warm_queries else None,
        batch_p50_ms=_percentile(lat_ms, 50),
        batch_p90_ms=_percentile(lat_ms, 90),
        batch_p99_ms=_percentile(lat_ms, 99),
        wall_s=wall_s,
        # compiled-loop reuse across batches: 0 == no per-batch retrace
        retraces=retraces,
        backend=getattr(engine, "backend", None),
        engine=type(engine).__name__,
    )
    return report


def build_engine(args):
    from repro.core import graph as G
    from repro.core import partition as PT
    from repro.core.bsp import BSPEngine

    gen = G.rmat if args.graph == "rmat" else G.uniform
    g = gen(args.scale, args.edge_factor, seed=args.seed)
    if args.alg == "sssp":
        g = g.with_uniform_weights(seed=args.seed + 1)
    pg = PT.partition(g, args.parts, args.strategy,
                      include_reverse=(args.alg == "bc"))
    kw = {}
    if args.backend == "fused":
        kw = dict(fused=True, block_e=args.block_e)
    elif args.backend == "hybrid":
        kw = dict(backend="hybrid")
    return g, pg, BSPEngine(pg, **kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--graph", choices=("rmat", "uniform"), default="rmat")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--strategy", default="high",
                    choices=("rand", "high", "low"))
    ap.add_argument("--backend", default="fused",
                    choices=("reference", "fused", "hybrid"))
    ap.add_argument("--block-e", type=int, default=256)
    ap.add_argument("--alg", default="bfs",
                    choices=("bfs", "sssp", "bc", "ppr"))
    ap.add_argument("--batch", type=int, default=32,
                    help="queries per batch (the Q axis)")
    ap.add_argument("--num-queries", type=int, default=256,
                    help="synthetic query stream length")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default=None, help="write the report JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (scale 8, 3 batches of 4)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.scale = min(args.scale, 8)
        args.batch = min(args.batch, 4)
        args.num_queries = min(args.num_queries, 3 * args.batch)

    g, pg, engine = build_engine(args)
    print(f"resident graph: |V|={g.num_vertices:,} |E|={g.num_edges:,} "
          f"parts={args.parts} strategy={args.strategy} "
          f"backend={args.backend}", flush=True)

    rng = np.random.default_rng(args.seed)
    sources = rng.integers(0, g.num_vertices, size=args.num_queries)
    report = serve(engine, args.alg, sources, args.batch)

    if report["ms_per_query"] is None:
        # Single-batch stream: everything landed in the cold batch.
        print(f"{args.alg}: {report['num_queries']} queries in one cold "
              f"batch of {args.batch} -> {report['cold_ms']:.0f} ms incl. "
              f"compilation (add batches for steady-state numbers)",
              flush=True)
    else:
        print(f"{args.alg}: {report['num_queries']} queries in batches of "
              f"{args.batch} -> {report['queries_per_sec']:.1f} q/s, "
              f"{report['ms_per_query']:.2f} ms/query amortized "
              f"(cold first batch {report['cold_ms']:.0f} ms; warm batch "
              f"p50={report['batch_p50_ms']:.1f} "
              f"p90={report['batch_p90_ms']:.1f} "
              f"p99={report['batch_p99_ms']:.1f} ms; "
              f"retraces={report['retraces']})", flush=True)
    if report["retraces"]:
        print(f"WARNING: {report['retraces']} compile-cache entries added "
              f"after warmup — batches are retracing", file=sys.stderr)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(dict(vars(args), **report), f, indent=2)
        print(f"wrote {args.out}")
    print("GRAPH SERVE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
