"""Batched graph query-serving driver — the throughput face of the engine.

The paper evaluates BFS/SSSP/BC "for a single source" (Table 4); a serving
deployment instead amortizes **one resident partitioned graph** across many
concurrent queries.  This driver is that regime end to end:

  1. load a synthetic workload (RMAT / uniform, the paper's Table 2
     generators) and partition it once;
  2. build one engine (reference / fused / hybrid backend) — the graph
     topology, block metadata, and degree splits stay device-resident for
     the whole run;
  3. drain a synthetic query stream in fixed-size batches of Q sources:
     every batch runs through **one** compiled ``lax.while_loop``
     (``BSPEngine.run_batched``), so per-query cost amortizes the dispatch,
     kernel-launch, and graph-residency overheads Q ways;
  4. report queries/sec, per-query latency percentiles (a query's latency
     is its batch's wall time — batch-synchronous serving), the amortized
     per-query time, and the engine's compile-cache growth across batches
     (0 retraces after warmup is the serving contract).

  PYTHONPATH=src python -m repro.launch.graph_serve \
      [--scale 12] [--parts 4] [--alg bfs] [--batch 32] \
      [--num-queries 256] [--backend fused] [--out serve_report.json]

``--smoke`` shrinks everything for CI.  The first batch per algorithm pays
compilation and is reported separately (``cold_ms``); steady-state numbers
exclude it.

Serving modes compose through a validated :class:`ServeConfig` (built from
the CLI flags; incompatible combinations fail fast with the flag to add).
``--continuous`` swaps drain-batch scheduling for a resident
:class:`~repro.runtime.session.ServeSession`: converged query slots are
compacted out and refilled from the admission queue at chunk boundaries
inside one compiled loop (zero retraces), and the report compares
``continuous_qps``/p99 against the drain-batch baseline on the same
stream.  ``--mutate`` and ``--depth-buckets`` compose with it; see
``docs/serving.md`` for the slot lifecycle and the API migration table.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Optional

import numpy as np


def _percentile(vals, p: float) -> float:
    if not len(vals):
        return float("nan")
    return float(np.percentile(vals, p, method="nearest"))


@dataclasses.dataclass
class ServeConfig:
    """The validated serving-mode surface — one place for the flags that
    used to sprawl across ``main()``'s dispatch order.

    The old dispatch silently ignored combinations (``--chaos`` dropped
    ``--depth-buckets``; ``--mutate`` dropped ``--deadline-ms``/
    ``--queue-capacity``); :meth:`validate` makes every incompatible pair
    an actionable error instead, and names the spelling that *does*
    compose (usually ``--continuous``, whose :class:`ServeSession` takes
    the other knobs as options).
    """
    alg: str = "bfs"
    batch: int = 32
    mutate: bool = False
    chaos: bool = False
    corrupt: bool = False
    continuous: bool = False
    depth_buckets: int = 0
    deadline_ms: Optional[float] = None
    queue_capacity: Optional[int] = None
    chunk: int = 2

    @classmethod
    def from_args(cls, args) -> "ServeConfig":
        return cls(alg=args.alg, batch=args.batch, mutate=args.mutate,
                   chaos=args.chaos, corrupt=args.corrupt,
                   continuous=args.continuous,
                   depth_buckets=args.depth_buckets,
                   deadline_ms=args.deadline_ms,
                   queue_capacity=args.queue_capacity,
                   chunk=args.checkpoint_every).validate()

    def validate(self) -> "ServeConfig":
        def bad(combo: str, why: str, instead: str):
            raise ValueError(f"incompatible serving flags: {combo} — {why}. "
                             f"{instead}")

        if self.chaos:
            for name, on in (("--mutate", self.mutate),
                             ("--continuous", self.continuous),
                             ("--depth-buckets", bool(self.depth_buckets)),
                             ("--deadline-ms", self.deadline_ms is not None),
                             ("--queue-capacity",
                              self.queue_capacity is not None)):
                if on:
                    bad(f"--chaos + {name}",
                        "the chaos drill is a self-contained mutating "
                        "session with its own injection schedule",
                        "Run --chaos alone; fault tolerance for continuous "
                        "sessions is serve_with_restarts (see "
                        "tests/test_continuous.py).")
        if self.corrupt:
            if self.chaos:
                bad("--chaos + --corrupt",
                    "the drills have disjoint injection schedules (worker "
                    "faults vs silent bit-flips)",
                    "Run them as two invocations — CI does.")
            for name, on in (("--mutate", self.mutate),
                             ("--continuous", self.continuous),
                             ("--depth-buckets", bool(self.depth_buckets)),
                             ("--deadline-ms", self.deadline_ms is not None),
                             ("--queue-capacity",
                              self.queue_capacity is not None)):
                if on:
                    bad(f"--corrupt + {name}",
                        "the corruption drill runs its own sessions across "
                        "all three backends with a fixed injection schedule",
                        "Run --corrupt alone; certification in production "
                        "sessions is ServeSession(certifier=..., "
                        "monitor=...) — see docs/robustness.md.")
            if self.alg not in ("bfs", "sssp"):
                raise ValueError(
                    f"--corrupt drills the continuous-session certification "
                    f"path, which serves step-translatable programs only "
                    f"(bfs, sssp), not {self.alg!r}.")
        if self.continuous and self.alg not in ("bfs", "sssp"):
            raise ValueError(
                f"--continuous serves step-translatable programs only "
                f"(bfs, sssp), not {self.alg!r}: slot refill re-seeds a "
                f"query mid-loop in the global step frame "
                f"(algorithms/continuous.py).  Drop --continuous to "
                f"drain-batch {self.alg!r}.")
        if not self.continuous:
            if self.mutate:
                for name, on in (("--depth-buckets",
                                  bool(self.depth_buckets)),
                                 ("--deadline-ms",
                                  self.deadline_ms is not None),
                                 ("--queue-capacity",
                                  self.queue_capacity is not None)):
                    if on:
                        bad(f"--mutate + {name}",
                            "the drain-batch mutating driver has no "
                            "admission queue or scheduler",
                            "Add --continuous: ServeSession composes "
                            "mutations with deadlines, admission and the "
                            "depth scheduler in one resident engine.")
            elif self.depth_buckets:
                for name, on in (("--deadline-ms",
                                  self.deadline_ms is not None),
                                 ("--queue-capacity",
                                  self.queue_capacity is not None)):
                    if on:
                        bad(f"--depth-buckets + {name}",
                            "the bucketed A/B driver re-runs the stream "
                            "twice and reports buckets, not SLA",
                            "Add --continuous to schedule depth-first "
                            "under a deadline, or drop --depth-buckets.")
        return self

    @property
    def mode(self) -> str:
        if self.chaos:
            return "chaos"
        if self.corrupt:
            return "corrupt"
        if self.continuous:
            return "continuous"
        if self.mutate:
            return "mutate"
        if self.depth_buckets:
            return "depth"
        return "drain"


def run_query_batch(engine, alg: str, sources: np.ndarray) -> np.ndarray:
    """Dispatch one batch of queries; returns the [Q, n] result block."""
    from repro.algorithms import (betweenness_centrality_batched,
                                  bfs_batched, personalized_pagerank,
                                  sssp_batched)

    if alg == "bfs":
        return bfs_batched(engine, sources)[0]
    if alg == "sssp":
        return sssp_batched(engine, sources)[0]
    if alg == "bc":
        return betweenness_centrality_batched(engine, sources)[0]
    if alg == "ppr":
        return personalized_pagerank(engine, sources, num_iterations=10)
    raise ValueError(f"unknown algorithm {alg!r}")


def serve(engine, alg: str, sources: np.ndarray, batch: int,
          check_fn=None, deadline_ms=None, queue_capacity=None) -> dict:
    """Drain ``sources`` in batches of ``batch``; returns the metrics dict.

    ``check_fn(sources, results)`` optionally validates a batch (the
    selftest hook).  The query stream is padded to a whole number of
    batches with repeats of its head so every batch compiles to the same Q.

    ``queue_capacity`` bounds admission: sources beyond it are rejected
    with a reason (``report["admission"]``) instead of growing tail
    latency.  ``deadline_ms`` is a per-query SLA: a query's latency is its
    queue wait plus its batch's wall time (batch-synchronous serving);
    queries over deadline are counted in ``report["sla"]``.
    """
    admission = None
    if queue_capacity is not None:
        from repro.runtime import AdmissionController
        ctl = AdmissionController(queue_capacity)
        for s in np.asarray(sources).reshape(-1):
            ctl.offer(int(s), deadline_ms)
        sources = np.asarray(ctl.take(len(ctl)))
        admission = dict(capacity=queue_capacity, admitted=ctl.admitted,
                         rejected=len(ctl.rejected),
                         reject_reasons=sorted(
                             {r["reason"] for r in ctl.rejected}))
    num = len(sources)
    pad = (-num) % batch
    # np.resize repeats the stream cyclically, so padding works even when
    # pad > num (a stream shorter than one batch).
    stream = np.resize(sources, num + pad)
    batches = stream.reshape(-1, batch)

    tiered = getattr(engine, "tier_plan", None) is not None
    cache_fn = type(engine)._run_batched
    entries0 = None
    lat_ms, cold_ms = [], None
    batch_done_ms = []                  # cumulative wall at batch completion
    served = 0
    t_all = time.perf_counter()
    for i, srcs in enumerate(batches):
        t0 = time.perf_counter()
        out = run_query_batch(engine, alg, srcs)
        dt = (time.perf_counter() - t0) * 1e3
        batch_done_ms.append((time.perf_counter() - t_all) * 1e3)
        if i == 0:
            cold_ms = dt               # includes compilation
            if tiered:                 # streamed path: its own jit set
                entries0 = engine.tiered_cache_entries()
            else:
                try:
                    entries0 = cache_fn._cache_size()
                except AttributeError:  # non-jitted run_batched (distributed)
                    entries0 = None
        else:
            lat_ms.append(dt)
        served += batch
        if check_fn is not None:
            check_fn(srcs, out)
    wall_s = time.perf_counter() - t_all

    retraces = 0
    if entries0 is not None:
        cur = (engine.tiered_cache_entries() if tiered
               else cache_fn._cache_size())
        retraces = cur - entries0

    warm_s = sum(lat_ms) / 1e3
    warm_queries = max(served - batch, 0)
    report = dict(
        algorithm=alg, batch=batch, num_queries=num,
        batches=len(batches), cold_ms=cold_ms,
        queries_per_sec=(warm_queries / warm_s) if warm_s > 0 else None,
        ms_per_query=(warm_s * 1e3 / warm_queries) if warm_queries else None,
        batch_p50_ms=_percentile(lat_ms, 50),
        batch_p90_ms=_percentile(lat_ms, 90),
        batch_p99_ms=_percentile(lat_ms, 99),
        wall_s=wall_s,
        # compiled-loop reuse across batches: 0 == no per-batch retrace
        retraces=retraces,
        backend=getattr(engine, "backend", None),
        engine=type(engine).__name__,
    )
    if admission is not None:
        report["admission"] = admission
    if deadline_ms is not None:
        # query i rides batch i // batch; its latency is that batch's
        # completion time (queue wait included)
        lat_q = np.asarray(batch_done_ms)[
            np.arange(num) // batch] if num else np.zeros(0)
        misses = int((lat_q > deadline_ms).sum())
        report["sla"] = dict(deadline_ms=deadline_ms, misses=misses,
                             met=num - misses)
    return report


def build_engine(args, dynamic: bool = False):
    from repro.core import graph as G
    from repro.core import partition as PT
    from repro.core.bsp import BSPEngine
    from repro.core.dynamic import DynamicGraph

    gen = G.rmat if args.graph == "rmat" else G.uniform
    g = gen(args.scale, args.edge_factor, seed=args.seed)
    if args.alg == "sssp":
        g = g.with_uniform_weights(seed=args.seed + 1)
    kw = {}
    if args.backend == "fused":
        kw = dict(fused=True, block_e=args.block_e)
    elif args.backend == "hybrid":
        kw = dict(backend="hybrid")
    if getattr(args, "hbm_budget", None) is not None:
        kw["tiered"] = args.hbm_budget
        kw["win_blocks"] = args.win_blocks
        kw.setdefault("block_e", args.block_e)
    if dynamic:
        dg = DynamicGraph(g, args.parts, args.strategy,
                          include_reverse=(args.alg == "bc"),
                          mutation_capacity=args.mutation_batch)
        return g, dg, BSPEngine(dg, **kw)
    pg = PT.partition(g, args.parts, args.strategy,
                      include_reverse=(args.alg == "bc"))
    return g, pg, BSPEngine(pg, **kw)


def estimate_depth_order(g, sources: np.ndarray) -> np.ndarray:
    """Order ``sources`` by estimated traversal depth, shallow first.

    A batch runs ``max_q(steps_q)`` supersteps, so one deep query taxes
    every shallow query sharing its batch.  The proxy: BFS from a hub
    reaches the massive component in few levels, BFS from a fringe vertex
    walks long chains first — out-degree (cheap, already resident) orders
    hubs before fringe.  Returns indices into ``sources``.
    """
    deg = g.out_degrees()[np.asarray(sources)]
    return np.argsort(-deg, kind="stable")


def serve_depth_bucketed(engine, g, alg: str, sources: np.ndarray,
                         batch: int, num_buckets: int = 4) -> dict:
    """Depth-bucketing scheduler: drain the stream in estimated-depth order
    so shallow queries never ride a deep query's superstep count.

    Runs the same stream twice — arrival order (baseline: batches mix
    depths) and depth-bucketed — and reports per-bucket p50/p99 per-query
    latency for both (a query's latency is its batch's wall time).  The
    shallow buckets' p99 is the win; the deep buckets pay what they always
    paid.
    """
    order = estimate_depth_order(g, sources)
    num = len(sources)
    num_buckets = max(1, min(num_buckets, num))  # every bucket non-empty
    bucket_of = np.empty(num, dtype=np.int64)   # by stream position
    for b in range(num_buckets):
        lo = b * num // num_buckets
        hi = (b + 1) * num // num_buckets
        bucket_of[order[lo:hi]] = b

    run_query_batch(engine, alg, np.asarray(sources[:batch]))  # warm compile

    def drain(stream_idx):
        lat = np.empty(num, dtype=np.float64)
        for i in range(0, num, batch):
            idx = stream_idx[i: i + batch]
            srcs = np.asarray(sources)[idx]
            if len(srcs) < batch:                 # pad the tail batch
                srcs = np.resize(srcs, batch)
            t0 = time.perf_counter()
            run_query_batch(engine, alg, srcs)
            lat[idx] = (time.perf_counter() - t0) * 1e3
        return lat

    lat_base = drain(np.arange(num))              # arrival order (mixed)
    lat_buck = drain(order)                       # depth-homogeneous batches
    buckets = []
    for b in range(num_buckets):
        m = bucket_of == b
        buckets.append(dict(
            bucket=b, queries=int(m.sum()),
            min_degree=int(g.out_degrees()[sources[m]].min()),
            baseline_p50_ms=_percentile(lat_base[m], 50),
            baseline_p99_ms=_percentile(lat_base[m], 99),
            bucketed_p50_ms=_percentile(lat_buck[m], 50),
            bucketed_p99_ms=_percentile(lat_buck[m], 99)))
    return dict(num_buckets=num_buckets, batch=batch,
                baseline_p99_ms=_percentile(lat_base, 99),
                bucketed_p99_ms=_percentile(lat_buck, 99),
                buckets=buckets)


def refresh_standing(engine, dg, alg: str, sources, prev, mark) -> dict:
    """Refresh a standing query set after mutations: warm-start when the
    window allows (monotone program + insert-only batches), cold otherwise.
    Runs the cold path too, so the report can state the superstep savings
    honestly.  Returns the new results + metrics.
    """
    from repro.algorithms import (bfs_batched, bfs_incremental, sssp_batched,
                                  sssp_incremental)

    dirty, monotone = dg.dirty_since(mark)
    incremental = {"bfs": (bfs_incremental, bfs_batched),
                   "sssp": (sssp_incremental, sssp_batched)}.get(alg)
    cold_fn = (incremental[1] if incremental
               else (lambda e, s: (run_query_batch(e, alg, s), None)))
    t0 = time.perf_counter()
    cold_out = cold_fn(engine, sources)
    cold_ms = (time.perf_counter() - t0) * 1e3
    cold_res, cold_steps = cold_out if incremental else (cold_out[0], None)
    rec = dict(mode="cold", cold_ms=cold_ms,
               cold_steps=(None if cold_steps is None
                           else [int(s) for s in cold_steps]))
    result = cold_res
    if incremental is not None and monotone:
        warm_fn = incremental[0]
        t0 = time.perf_counter()
        warm_res, warm_steps = warm_fn(engine, prev, dirty)
        rec.update(mode="incremental", warm_ms=(time.perf_counter() - t0)
                   * 1e3, warm_steps=[int(s) for s in warm_steps],
                   bitwise_equal=bool(np.array_equal(warm_res, cold_res)))
        result = warm_res
    return dict(rec, result=result)


def serve_mutating(engine, dg, alg: str, *, batches, batch: int,
                   standing: int, query_batches_per_round: int,
                   seed: int = 1, compact: bool = True,
                   skew_drift_threshold: float = 0.5,
                   resplit_threshold: float = 0.10) -> dict:
    """Interleave mutation batches with query batches against the resident
    graph — the evolving-graph serving regime end to end.

    Per round: one mutation batch is applied in place (edges/s), fresh
    random queries are served cold, and a *standing* query set is kept
    fresh — warm-started from its previous fixpoint when the window is
    monotone, recomputed cold otherwise — under the zero-retrace contract
    (the dynamic runner's jit cache must not grow after warmup; a
    compaction pause is the one excepted, separately-reported event).
    Compactions trigger on the staleness signals (including degree-skew
    drift at ``skew_drift_threshold``) or, on the hybrid backend, on
    ``engine.should_resplit_hybrid`` — the ``perf_model.should_resplit``
    vote that the drifted degree ranking beats the frozen split's
    predicted makespan by ``resplit_threshold``.
    """
    from repro.core import bsp

    rng = np.random.default_rng(seed)
    n = dg.pg.num_vertices
    standing_sources = rng.integers(0, n, size=standing)

    # warm-up: compile the cold path + serve loop before timing
    prev = run_query_batch(engine, alg, standing_sources)
    mark = dg.mark()
    cache_fns = [bsp._run_dyn_jit, bsp._run_dyn_hybrid_jit]

    def cache_entries():
        return sum(f._cache_size() for f in cache_fns)

    rounds, lat_ms = [], []
    mut_edges = mut_s = 0.0
    compact_ms = 0.0
    resplits = 0
    warm_steps_all, cold_steps_all = [], []
    retraces = 0
    entries_prev = rebinds_prev = rebuilds_prev = None
    warm_versions = set()     # graph versions whose warm path has compiled
    t_all = time.perf_counter()
    for i, mb in enumerate(batches):
        rep = dg.apply_mutations(mb)
        mut_edges += rep["num_edges"]
        mut_s += rep["apply_ms"] / 1e3
        if rep["compacted"]:
            # capacity-overflow auto-compaction inside apply_mutations —
            # --no-compact only disables the *threshold-driven* kind
            compact_ms += dg.last_compaction_ms
        if compact and dg.should_compact(
                max_skew_drift=skew_drift_threshold):
            compact_ms += dg.compact()
        elif compact and engine.should_resplit_hybrid(resplit_threshold):
            # re-ranking the degree split rides a compaction: the rebind
            # re-runs the perf-model plan on the mutated graph
            compact_ms += dg.compact()
            resplits += 1
        for _ in range(query_batches_per_round):
            srcs = rng.integers(0, n, size=batch)
            t0 = time.perf_counter()
            run_query_batch(engine, alg, srcs)
            lat_ms.append((time.perf_counter() - t0) * 1e3)
        ref = refresh_standing(engine, dg, alg, standing_sources, prev, mark)
        prev = ref.pop("result")
        mark = dg.mark()
        if ref.get("warm_steps"):
            warm_steps_all.append(max(ref["warm_steps"]))
        if ref.get("cold_steps"):
            cold_steps_all.append(max(ref["cold_steps"]))
        rounds.append(dict(round=i, mutation=dict(
            (k, v) for k, v in rep.items() if k != "dirty"), refresh=ref))
        # Zero-retrace accounting, per round: cache growth counts as a
        # retrace unless something legitimately new compiled this round —
        # a compaction rebind (shape-changed loops recompile), or the warm
        # path's first run at the current graph version (its relaxation
        # program compiles once per shape).  Those rounds just reset the
        # baseline; the gate stays armed for every other round (round 0
        # seeds the baseline after warm-up compiles).
        legit = (engine.dynamic_rebinds != rebinds_prev
                 or engine.hybrid_dyn_rebuilds != rebuilds_prev)
        if (ref.get("mode") == "incremental"
                and dg.version not in warm_versions):
            warm_versions.add(dg.version)
            legit = True
        if entries_prev is not None and not legit:
            retraces += cache_entries() - entries_prev
        entries_prev = cache_entries()
        rebinds_prev = engine.dynamic_rebinds
        rebuilds_prev = engine.hybrid_dyn_rebuilds
    wall_s = time.perf_counter() - t_all

    report = dict(
        algorithm=alg, batch=batch, rounds=len(rounds),
        standing=standing,
        mutation_edges_per_sec=(mut_edges / mut_s) if mut_s else None,
        mutation_edges=int(mut_edges),
        incremental_steps=(int(np.mean(warm_steps_all))
                           if warm_steps_all else None),
        cold_steps=(int(np.mean(cold_steps_all))
                    if cold_steps_all else None),
        batch_p50_ms=_percentile(lat_ms, 50),
        batch_p99_ms=_percentile(lat_ms, 99),
        compactions=dg.compactions, compaction_pause_ms=compact_ms,
        resplits=resplits,
        dynamic_rebinds=engine.dynamic_rebinds,
        hybrid_rebuilds=engine.hybrid_dyn_rebuilds,
        retraces=retraces,
        wall_s=wall_s, per_round=rounds,
        staleness=dg.staleness())
    return report


# ---------------------------------------------------------------------------
# fault-tolerant serving (docs/robustness.md)
# ---------------------------------------------------------------------------

def chunked_refresh(engine, alg: str, sources, *, chunk: int,
                    on_chunk=None, round_i: int = 0):
    """Refresh a standing query set through the checkpointable chunked run
    mode.  Returns ([Q, n] results, steps [Q], info)."""
    import jax.numpy as jnp

    from repro.algorithms.bfs import (BFS_PROGRAM, gather_batch,
                                      multi_source_state)
    from repro.algorithms.sssp import SSSP_PROGRAM
    from repro.runtime import chaos

    pg = engine.pg
    if alg == "bfs":
        program, key = BFS_PROGRAM, "level"
        state = {"level": jnp.asarray(multi_source_state(pg, sources))}
    elif alg == "sssp":
        program, key = SSSP_PROGRAM, "dist"
        d0 = multi_source_state(pg, sources)
        state = {"dist": jnp.asarray(d0),
                 "active": jnp.asarray(np.isfinite(d0))}
    else:
        raise ValueError(f"chunked refresh supports bfs/sssp, not {alg!r}")
    if chaos.visit("query.poison", round=round_i):
        # data-level fault drill: corrupt query 0's initial state — the
        # quarantine scan must catch it at the first chunk boundary
        arr = np.asarray(state[key]).copy()
        arr[0] = np.nan
        state[key] = jnp.asarray(arr)
    state, steps_q, info = engine.execute(
        program, state, chunk=chunk, on_chunk=on_chunk,
        chaos_ctx={"round": round_i})
    return gather_batch(pg, state[key]), np.asarray(steps_q), info


def serve_fault_tolerant(args, manager, *, midrun_manager=None,
                         hard_limit_s=None):
    """Mutating serving session that survives injected (or real) faults.

    Per round: apply one mutation batch (acknowledged only after the
    device scatter completes), serve a fresh query batch through the
    degradation ladder (primary backend → retry → reference fallback),
    refresh the standing set through the chunked run mode with the
    quarantine scan and the superstep watchdog at every chunk boundary,
    then snapshot ``{standing results, dynamic payload}`` +
    ``{round, acked cursor}`` via ``save_tree``.

    Recovery (on a retryable fault anywhere in the round): exponential
    backoff, rebuild the graph from base, **replay the acknowledged
    mutation log**, restore the latest round snapshot, and assert the
    replayed device payload is bitwise identical to the snapshotted one —
    a crash between compactions loses no acknowledged mutation.  The
    watchdog's ``hard_limit_s`` triggers checkpoint-now: the in-flight
    chunk carry is snapshotted to ``midrun_manager`` without waiting for
    the round boundary.

    Returns (report, standing results [Q, n], quarantined query-id set).
    """
    from repro.core import bsp
    from repro.core.bsp import BSPEngine
    from repro.core.dynamic import DynamicGraph
    from repro.core.graph import apply_mutation_batches
    from repro.data.graphs import edge_stream
    from repro.runtime import (RETRYABLE_EXCEPTIONS, DegradationLadder,
                               QuarantinePolicy, RestartPolicy, StepWatchdog,
                               chaos)

    from repro.core import graph as G

    gen = G.rmat if args.graph == "rmat" else G.uniform
    g = gen(args.scale, args.edge_factor, seed=args.seed)
    if args.alg == "sssp":
        g = g.with_uniform_weights(seed=args.seed + 1)
    kw = {}
    if args.backend == "fused":
        kw = dict(fused=True, block_e=args.block_e)
    elif args.backend == "hybrid":
        kw = dict(backend="hybrid")

    rounds = args.mutation_rounds
    stream = edge_stream(g, rounds, args.mutation_batch, churn=args.churn,
                         seed=args.seed)
    rng = np.random.default_rng(args.seed)
    standing = rng.integers(0, g.num_vertices, size=(args.standing, 1))

    def build_session():
        dg = DynamicGraph(g, args.parts, args.strategy,
                          mutation_capacity=args.mutation_batch)
        primary = BSPEngine(dg, **kw)
        fallback = BSPEngine(dg) if kw else primary
        return dg, primary, fallback

    policy = RestartPolicy(max_failures=args.max_restarts,
                           backoff_s=args.restart_backoff_s)
    quar = QuarantinePolicy(superstep_budget=args.superstep_budget)
    ladder = DegradationLadder(retries=1)
    wd = StepWatchdog(warmup_steps=2, hard_limit_s=hard_limit_s)
    midrun_snapshots = 0

    dg, engine, fb_engine = build_session()
    # warm both rungs of the ladder so later downgrades reuse the caches
    warm = rng.integers(0, g.num_vertices, size=args.batch)
    run_query_batch(engine, args.alg, warm)
    if fb_engine is not engine:
        run_query_batch(fb_engine, args.alg, warm)

    cache_fns = [bsp._run_dyn_jit, bsp._run_dyn_hybrid_jit,
                 bsp._run_dyn_chunk_jit, bsp._run_dyn_hybrid_chunk_jit]

    def cache_entries():
        return sum(f._cache_size() for f in cache_fns)

    acked = 0                 # durable cursor: stream[:acked] acknowledged
    round_i = 0
    prev = None
    snapshots = 0
    entries0 = None
    n = g.num_vertices

    def recover():
        nonlocal dg, engine, fb_engine, round_i, prev
        dg, engine, fb_engine = build_session()
        if acked:
            dg.replay(stream[:acked])   # the durable log IS the truth
        latest = manager.latest_step()
        if latest is None:
            round_i, prev = 0, None
            return
        dyn_tree, dyn_extra = dg.snapshot()
        like = {"standing": np.zeros((args.standing, n), np.float32),
                "dyn": dyn_tree}
        _, tree = manager.restore_tree(like, latest)
        extra = manager.manifest_extra(latest)
        round_i = int(extra["round"])
        prev = tree["standing"]
        if (int(extra["cursor"]) == dyn_extra["cursor"]
                and int(extra["version"]) == dyn_extra["version"]):
            # zero-lost-mutations proof: rebuilding from base + replaying
            # the acked log reproduces the snapshotted delta/tombstone
            # payload bitwise
            from repro.checkpoint.manager import _flatten
            snap_flat = _flatten(tree["dyn"])
            live_flat = {k: np.asarray(v)
                         for k, v in _flatten(dyn_tree).items()}
            for name, a in snap_flat.items():
                if not np.array_equal(np.asarray(a), live_flat[name]):
                    raise RuntimeError(
                        f"replayed payload leaf {name!r} differs from the "
                        f"snapshot — a mutation was lost or double-applied")

    while round_i < rounds:
        try:
            chaos.visit("serve.round", round=round_i)
            if round_i >= acked:
                dg.apply_mutations(stream[round_i])
                acked = round_i + 1
            # fresh queries ride the degradation ladder
            srcs = np.random.default_rng(
                args.seed + 100 + round_i).integers(0, n, size=args.batch)
            r = round_i

            def primary():
                chaos.visit("kernel.dispatch", round=r,
                            backend=args.backend)
                return run_query_batch(engine, args.alg, srcs)

            ladder.run(primary,
                       lambda: run_query_batch(fb_engine, args.alg, srcs),
                       label=f"round{r}:{args.alg}")

            # standing refresh through the checkpointable chunked mode
            quar.begin(args.standing)
            t_chunk = [time.perf_counter()]

            def on_chunk(snap):
                nonlocal midrun_snapshots
                now = time.perf_counter()
                flagged = wd.report(snap["step"], now - t_chunk[0])
                t_chunk[0] = now
                if flagged and midrun_manager is not None:
                    # checkpoint-now: persist the in-flight chunk carry
                    midrun_manager.save_tree(
                        snap["step"],
                        {"state": snap["state"], "fin": snap["fin"],
                         "steps_q": snap["steps_q"]},
                        extra={"round": r, "step": snap["step"],
                               "mid_run": True}, blocking=True)
                    midrun_snapshots += 1
                return quar.scan(snap)

            prev, steps_q, info = chunked_refresh(
                engine, args.alg, standing, chunk=args.checkpoint_every,
                on_chunk=on_chunk, round_i=round_i)

            dyn_tree, dyn_extra = dg.snapshot()
            manager.save_tree(
                round_i + 1,
                {"standing": np.asarray(prev), "dyn": dyn_tree},
                extra=dict(round=round_i + 1, acked=acked, **dyn_extra),
                blocking=True)
            snapshots += 1
            round_i += 1
            if entries0 is None:
                entries0 = cache_entries()
        except RETRYABLE_EXCEPTIONS as e:
            # Only the restart whitelist (worker faults, XLA runtime errors,
            # exchange corruption) burns the retry budget; programming bugs
            # propagate — matching RestartPolicy.handle's own contract.
            sleep_s = policy.handle(e, context=dict(round=round_i))
            if sleep_s:
                time.sleep(sleep_s)
            recover()

    # ledger-vs-oracle audit: the served graph equals a from-scratch apply
    # of every acknowledged batch
    mut = dg.mutated_csr()
    oracle = apply_mutation_batches(g, stream[:acked])
    if not (np.array_equal(mut.row_ptr, oracle.row_ptr)
            and np.array_equal(mut.col, oracle.col)):
        raise RuntimeError("mutated CSR diverged from the mutation-log "
                           "oracle — acknowledged mutations were lost")

    retraces = (cache_entries() - entries0) if entries0 is not None else 0
    report = dict(
        rounds=rounds, acked=acked, snapshots=snapshots,
        midrun_snapshots=midrun_snapshots,
        failures=policy.failures, restarts=policy.restarts,
        downgrades=ladder.downgrades, quarantined=quar.quarantined,
        stragglers=len(wd.stragglers), retraces=retraces,
        backend=args.backend, algorithm=args.alg)
    quarantined_ids = {rec["query"] for rec in quar.quarantined}
    return report, np.asarray(prev), quarantined_ids


# ---------------------------------------------------------------------------
# continuous batching (docs/serving.md)
# ---------------------------------------------------------------------------

def serve_continuous(engine, g, cfg: ServeConfig, sources, *,
                     dg=None, mutation_stream=None, parity: bool = False,
                     warm: bool = True) -> dict:
    """Serve ``sources`` through one resident :class:`ServeSession` and
    report it against fixed-batch drain at the same Q.

    Non-mutating: the whole stream is submitted up front ("under load" —
    every query's latency includes its queue wait) and drained by ONE
    resident compiled loop; the same stream then runs through drain-batch
    ``run_batched`` for the q/s / p99 baseline and, with ``parity=True``,
    the bitwise oracle.  With ``mutation_stream`` (requires a dynamic
    ``dg``), the stream is served in waves — drain, mutate, drain — so
    every query completes against exactly one graph version and parity
    holds per wave.
    """
    from repro.runtime import ServeSession, drain_reference

    deg = g.out_degrees()
    scheduler = "depth" if cfg.depth_buckets else "fifo"
    depth_key = (lambda s: -int(deg[s])) if cfg.depth_buckets else None

    def make_session():
        return ServeSession(
            engine, cfg.alg, slots=cfg.batch, chunk=cfg.chunk,
            queue_capacity=cfg.queue_capacity, deadline_ms=cfg.deadline_ms,
            scheduler=scheduler, depth_key=depth_key)

    if warm:
        # pay every compile (chunk jit, slot swap, drain-batch loop)
        # outside the timed run: a 2x-slots throwaway stream forces one
        # refill cycle, and the oracle warms run_batched
        warm_srcs = np.resize(np.asarray(sources), 2 * cfg.batch)
        ws = make_session()
        ws.submit(warm_srcs)
        ws.drain()
        drain_reference(engine, cfg.alg, warm_srcs[:cfg.batch], cfg.batch)

    waves = [np.asarray(sources).reshape(-1)]
    if mutation_stream is not None:
        if dg is None:
            raise ValueError("mutation_stream needs the dynamic graph (dg)")
        waves = np.array_split(np.asarray(sources).reshape(-1),
                               len(mutation_stream) + 1)

    session = make_session()
    mismatches = 0
    checked = 0
    drain_lat: list = []
    drain_wall = 0.0
    t_all = time.perf_counter()
    cont_wall = 0.0
    for w, wave in enumerate(waves):
        if w > 0:
            session.mutate(mutation_stream[w - 1])
        qids = session.submit(wave)
        t0 = time.perf_counter()
        session.drain()
        cont_wall += time.perf_counter() - t0
        # fixed-batch drain of the same wave on the same graph version:
        # the q/s + p99 baseline, and (parity=True) the bitwise oracle
        t0 = time.perf_counter()
        num = len(wave)
        ref_rows = []
        for i in range(0, num, cfg.batch):
            batch = np.resize(wave[i:i + cfg.batch], cfg.batch)
            ref_rows.append(run_query_batch(engine, cfg.alg, batch))
            # a drained query's latency is its batch's completion time
            done_ms = (time.perf_counter() - t0) * 1e3
            drain_lat.extend([done_ms] * min(cfg.batch, num - i))
        drain_wall += time.perf_counter() - t0
        if parity:
            ref = np.concatenate(ref_rows, axis=0)[:num]
            by_qid = {q: j for j, q in enumerate(qids) if q is not None}
            for r in session.poll():
                if r["query"] in by_qid:
                    checked += 1
                    if not np.array_equal(r["result"],
                                          ref[by_qid[r["query"]]]):
                        mismatches += 1
    wall_s = time.perf_counter() - t_all
    rep = session.report()
    cont_lat = sorted(session._latency_ms.values())
    completed = rep["completed"]
    report = dict(
        mode="continuous", algorithm=cfg.alg, slots=cfg.batch,
        chunk=cfg.chunk, stream=len(np.asarray(sources).reshape(-1)),
        waves=len(waves), completed=completed,
        rejected=rep["rejected"], windows=rep["windows"],
        refills=rep["refills"],
        min_slot_refills=rep["min_slot_refills"],
        max_slot_refills=rep["max_slot_refills"],
        retraces=rep["retraces"], sla_misses=rep["sla_misses"],
        scheduler=scheduler,
        continuous_qps=(completed / cont_wall) if cont_wall else None,
        continuous_p50_ms=_percentile(cont_lat, 50),
        continuous_p99_ms=_percentile(cont_lat, 99),
        drain_qps=(len(drain_lat) / drain_wall) if drain_wall else None,
        drain_p50_ms=_percentile(drain_lat, 50),
        drain_p99_ms=_percentile(drain_lat, 99),
        wall_s=wall_s,
        backend=getattr(engine, "backend", None),
        engine=type(engine).__name__)
    if parity:
        report["parity_checked"] = checked
        report["parity_mismatches"] = mismatches
    return report


def run_chaos_drill(args) -> int:
    """``--chaos``: clean session vs fault-injected session, with recovery
    and parity asserts (the CI chaos job).

    Injected faults: a crash between mutation batches (``serve.round``), a
    shard/worker death mid-refresh (``superstep.chunk``), a crash
    mid-mutation-batch before the device scatter (``mutation.scatter``), a
    kernel-dispatch fault that exhausts its retry (``kernel.dispatch`` ×2 →
    reference fallback), and a poisoned query (``query.poison`` → NaN
    state, quarantined every round).  Asserts: the session recovers within
    the restart budget, the mutation log replays with zero lost mutations,
    non-quarantined standing results are **bitwise identical** to the
    uninjected run, retraces stay bounded by restarts, and the clean path
    quarantines nothing.
    """
    import tempfile

    from repro.checkpoint import CheckpointManager
    from repro.runtime import FaultInjector, chaos

    rounds = args.mutation_rounds
    with tempfile.TemporaryDirectory() as td:
        clean_rep, clean_res, clean_quar = serve_fault_tolerant(
            args, CheckpointManager(td + "/clean", keep=3))
        print(f"clean session: rounds={clean_rep['rounds']} "
              f"snapshots={clean_rep['snapshots']} "
              f"retraces={clean_rep['retraces']} "
              f"quarantined={len(clean_rep['quarantined'])}", flush=True)
        assert clean_rep["failures"] == 0 and not clean_quar
        assert clean_rep["retraces"] == 0, \
            f"clean path retraced: {clean_rep['retraces']}"

        inj = FaultInjector(sites={
            "serve.round": [{"round": min(1, rounds - 1)}],
            "superstep.chunk": [{"round": min(1, rounds - 1), "chunk": 1}],
            "mutation.scatter": [{"index": min(2, rounds - 1)}],
            "kernel.dispatch": [{"round": min(2, rounds - 1)},
                                {"round": min(2, rounds - 1)}],
            "query.poison": [{"round": r, "flag": True}
                             for r in range(rounds)],
        })
        with chaos.active(inj):
            faulty_rep, faulty_res, faulty_quar = serve_fault_tolerant(
                args, CheckpointManager(td + "/faulty", keep=3),
                midrun_manager=CheckpointManager(td + "/midrun", keep=3),
                hard_limit_s=0.0)

    print(f"faulty session: failures={faulty_rep['failures']} "
          f"restarts={[r.get('round') for r in faulty_rep['restarts']]} "
          f"downgrades={len(faulty_rep['downgrades'])} "
          f"quarantined={sorted(faulty_quar)} "
          f"midrun_snapshots={faulty_rep['midrun_snapshots']} "
          f"retraces={faulty_rep['retraces']}", flush=True)

    assert faulty_rep["failures"] >= 3, \
        "expected >=3 injected worker faults to fire"
    assert faulty_rep["acked"] == rounds, "mutation log not fully replayed"
    assert len(faulty_rep["downgrades"]) == 1, \
        "kernel fault did not fall back to the reference backend"
    assert faulty_quar == {0}, \
        f"poisoned query 0 not quarantined: {faulty_quar}"
    assert any(rec["reason"] == "nonfinite"
               for rec in faulty_rep["quarantined"])
    assert faulty_rep["midrun_snapshots"] > 0, \
        "watchdog checkpoint-now never fired"
    assert faulty_rep["retraces"] <= faulty_rep["failures"], \
        (f"retraces ({faulty_rep['retraces']}) exceed restarts "
         f"({faulty_rep['failures']})")

    ok = np.ones(len(clean_res), bool)
    for q in faulty_quar | clean_quar:
        ok[q] = False
    assert np.array_equal(clean_res[ok], faulty_res[ok]), \
        "recovered results diverge from the uninjected run"
    print(f"chaos parity: {int(ok.sum())}/{len(ok)} standing queries "
          f"bitwise identical to the uninjected run "
          f"(quarantined: {sorted(faulty_quar)})", flush=True)
    print("CHAOS OK")
    return 0


def run_corrupt_drill(args) -> int:
    """``--corrupt``: the silent-corruption drill (the CI corruption job).

    Worker faults raise; silent faults don't — this drill flips bits at
    every data-corruption seam and asserts the integrity layer converts
    each one into a *detection* (checksum mismatch, monitor fire, or
    certifier rejection with a recompute) or a *mask* (the harvested
    result is bitwise identical to the clean run anyway).  Per backend
    (reference, fused, hybrid):

    - clean pass: a certified ``ServeSession`` and a certified chunked
      refresh produce **zero** false positives (no recompute, no monitor
      fire, every fixpoint certifies);
    - ``state.corrupt``: a bit-flipped state row at a window boundary is
      caught by the invariant monitor and/or the harvest certifier, and
      the recompute-once policy restores the right answer;
    - ``exchange.payload``: a corrupted outbox element mismatches its
      inbox-side reduction tag → ``ExchangeCorruption`` → a clean window
      replay reproduces the uncorrupted result bitwise (the hybrid
      single-device path has no wire exchange, so the site is inert
      there and the result must stay bitwise clean).

    Backend-independent sites, drilled once: ``checkpoint.torn`` (a torn
    tensor fails its manifest CRC at restore; the previous snapshot still
    loads) and ``tombstone.flip`` (a resurrected deleted edge on the
    dynamic path yields a fixpoint the certifier rejects against the true
    mutated graph).
    """
    import tempfile

    from repro.checkpoint import CheckpointManager
    from repro.checkpoint.manager import CheckpointCorruption
    from repro.runtime import (ExchangeCorruption, FaultInjector,
                               QuarantinePolicy, ResultCertifier,
                               ServeSession, chaos, monitor_for)

    rng = np.random.default_rng(args.seed)
    detections = 0
    masked = 0

    def flag(site, **ctx):
        return FaultInjector(sites={site: [dict(ctx, flag=True)]})

    for backend in ("reference", "fused", "hybrid"):
        a = argparse.Namespace(**vars(args))
        a.backend = backend
        g, _, engine = build_engine(a)
        sources = rng.integers(0, g.num_vertices, size=args.num_queries)
        certifier = ResultCertifier(args.alg, g)

        def run_session():
            # all three detection layers armed: in-loop monitors, the
            # non-finite/budget quarantine, and harvest certification
            s = ServeSession(engine, args.alg, slots=args.batch,
                             chunk=args.checkpoint_every,
                             quarantine=QuarantinePolicy(
                                 superstep_budget=args.superstep_budget),
                             certifier=ResultCertifier(args.alg, g),
                             monitor=monitor_for(args.alg,
                                                 chunk=args.checkpoint_every))
            s.submit(sources)
            s.drain()
            rep = s.report()
            res = {r["query"]: r["result"] for r in s.poll()}
            return res, rep, set(s.quarantined_qids)

        # -- clean pass: zero false positives -----------------------------
        clean, rep, cq = run_session()
        assert rep["recomputed"] == 0 and not rep["certify_failed"], \
            f"[{backend}] clean session raised certifier false positives: " \
            f"{rep['certify_failed']}"
        assert rep["monitors_fired"] == 0 and not cq, \
            f"[{backend}] clean session fired {rep['monitors_fired']} " \
            f"invariant monitors, quarantined {sorted(cq)}"
        std = sources[:args.batch]
        clean_chunk, _, _ = chunked_refresh(
            engine, args.alg, std, chunk=args.checkpoint_every)
        verdicts = certifier.certify_batch(clean_chunk, sources=std)
        assert all(v.ok for v in verdicts), \
            f"[{backend}] clean chunked fixpoint failed certification: " \
            f"{[v.reason() for v in verdicts if not v.ok]}"
        print(f"[{backend}] clean: {rep['completed']} queries certified, "
              f"0 false positives, 0 monitor fires", flush=True)

        # -- state.corrupt: bit-flipped state row at a window boundary ----
        with chaos.active(flag("state.corrupt", step=0)):
            dirty, rep, dq = run_session()
        hits = rep["monitors_fired"] + rep["recomputed"] + len(dq)
        parity = all(np.array_equal(dirty[q], clean[q])
                     for q in clean if q not in dq)
        assert hits or parity, \
            f"[{backend}] state.corrupt neither detected nor masked"
        assert all(r["recovered"] for r in rep["certify_failed"]), \
            f"[{backend}] certifier recompute did not recover: " \
            f"{rep['certify_failed']}"
        assert parity or rep["recomputed"], \
            f"[{backend}] state.corrupt changed results without a recompute"
        detections += bool(hits)
        masked += bool(not hits)
        print(f"[{backend}] state.corrupt: "
              f"{'detected' if hits else 'masked'} "
              f"(monitors={rep['monitors_fired']} "
              f"recomputes={rep['recomputed']} "
              f"quarantined={sorted(dq)})", flush=True)

        # -- exchange.payload: corrupted wire block vs reduction tags -----
        try:
            with chaos.active(flag("exchange.payload", step=0)):
                got, _, _ = chunked_refresh(
                    engine, args.alg, std, chunk=args.checkpoint_every)
            caught = None
        except ExchangeCorruption as e:
            caught = e
        if caught is not None:
            # bounded window-replay: the clean re-run IS the recovery
            replay, _, _ = chunked_refresh(
                engine, args.alg, std, chunk=args.checkpoint_every)
            assert np.array_equal(replay, clean_chunk), \
                f"[{backend}] post-corruption replay diverged"
            detections += 1
            print(f"[{backend}] exchange.payload: detected "
                  f"({caught}); replay bitwise clean", flush=True)
        else:
            assert backend == "hybrid", \
                f"[{backend}] corrupted exchange escaped the tag check"
            assert np.array_equal(got, clean_chunk), \
                "[hybrid] inert exchange site still changed the result"
            masked += 1
            print("[hybrid] exchange.payload: masked (single-device hybrid "
                  "supersteps have no wire exchange)", flush=True)

    # -- checkpoint.torn: torn tensor vs manifest CRC (backend-free) ------
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, keep=3)
        tree = {"state": rng.standard_normal(64).astype(np.float32)}
        mgr.save_tree(0, tree, blocking=True)
        with chaos.active(flag("checkpoint.torn", step=1)):
            mgr.save_tree(1, tree, blocking=True)
        try:
            mgr.restore_tree(tree)
            raise AssertionError("torn checkpoint restored silently")
        except CheckpointCorruption as e:
            detections += 1
            print(f"checkpoint.torn: detected ({e})", flush=True)
        _, prev = mgr.restore_tree(tree, step=0)
        assert np.array_equal(prev["state"], tree["state"]), \
            "fallback snapshot does not match the saved state"
        print("checkpoint.torn: fallback to step 0 bitwise clean",
              flush=True)

    # -- tombstone.flip: resurrected deleted edge on the dynamic path -----
    from repro.data.graphs import edge_stream

    a = argparse.Namespace(**vars(args))
    a.backend, a.alg = "reference", "bfs"
    g, dg, engine = build_engine(a, dynamic=True)
    batch = edge_stream(g, 1, args.mutation_batch, churn=0.5,
                        seed=args.seed)[0]
    dg.apply_mutations(batch)
    truth = dg.mutated_csr()
    cert = ResultCertifier("bfs", truth)
    std = rng.integers(0, g.num_vertices, size=args.batch)
    base, _, _ = chunked_refresh(engine, "bfs", std,
                                 chunk=args.checkpoint_every)
    verdicts = cert.certify_batch(base, sources=std)
    assert all(v.ok for v in verdicts), \
        "clean dynamic fixpoint failed certification against the " \
        "mutated graph"
    # flip at EVERY window so the engine converges to a consistent fixpoint
    # of the *wrong* graph — the hardest case: only a certifier that checks
    # against the true mutated topology can tell
    persistent = FaultInjector(sites={"tombstone.flip": [
        {"step": s, "flag": True}
        for s in range(0, 64, args.checkpoint_every)]})
    with chaos.active(persistent):
        flipped, _, _ = chunked_refresh(engine, "bfs", std,
                                        chunk=args.checkpoint_every)
    verdicts = cert.certify_batch(flipped, sources=std)
    bad = [v.reason() for v in verdicts if not v.ok]
    if np.array_equal(flipped, base):
        masked += 1
        assert not bad, f"masked tombstone flip still failed: {bad}"
        print("tombstone.flip: masked (min-semiring path redundancy "
              "absorbed the flipped slot; fixpoint bitwise clean)",
              flush=True)
    else:
        detections += 1
        assert bad, "tombstone flip changed the fixpoint but every " \
                    "certifier check passed"
        print(f"tombstone.flip: detected ({bad[0]})", flush=True)
    # teeth proof: had the flip produced ANY wrong fixpoint, the certifier
    # rejects it — perturb one reached vertex's level by one and re-certify
    slot = next(i for i in range(len(std))
                if (np.isfinite(flipped[i]) & (flipped[i] > 0)).any())
    wrong = np.asarray(flipped[slot]).copy()
    v = int(np.flatnonzero(np.isfinite(wrong) & (wrong > 0))[0])
    wrong[v] -= 1.0
    verdict = cert.certify(wrong, source=int(std[slot]))
    assert not verdict.ok, \
        "certifier accepted a provably wrong BFS fixpoint"
    print(f"tombstone.flip: certifier rejects a perturbed fixpoint "
          f"({verdict.reason()})", flush=True)

    print(f"corruption drill: {detections} detected, {masked} masked, "
          f"0 false positives across 3 backends", flush=True)
    print("CORRUPT OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--graph", choices=("rmat", "uniform"), default="rmat")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--strategy", default="high",
                    choices=("rand", "high", "low"))
    ap.add_argument("--backend", default="fused",
                    choices=("reference", "fused", "hybrid"))
    ap.add_argument("--block-e", type=int, default=256)
    ap.add_argument("--win-blocks", type=int, default=8,
                    help="edge blocks per out-of-core streaming window "
                         "(with --hbm-budget; the double-buffer costs "
                         "2*win_blocks*block_e edge slots of HBM)")
    ap.add_argument("--hbm-budget", type=int, default=None, metavar="BYTES",
                    help="out-of-core tiering: device-memory byte budget "
                         "for the graph arenas; partitions that do not fit "
                         "go host-tier and stream through double-buffered "
                         "windows (admission charges only the HBM figure "
                         "against this budget)")
    ap.add_argument("--alg", default="bfs",
                    choices=("bfs", "sssp", "bc", "ppr"))
    ap.add_argument("--batch", type=int, default=32,
                    help="queries per batch (the Q axis)")
    ap.add_argument("--num-queries", type=int, default=256,
                    help="synthetic query stream length")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default=None, help="write the report JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (scale 8, 3 batches of 4)")
    # --- dynamic-graph serving (docs/dynamic.md) ---
    ap.add_argument("--mutate", action="store_true",
                    help="interleave edge-mutation batches with query "
                         "batches against a resident DynamicGraph")
    ap.add_argument("--mutation-batch", type=int, default=256,
                    help="edges per mutation batch")
    ap.add_argument("--mutation-rounds", type=int, default=8,
                    help="mutation batches in the stream")
    ap.add_argument("--churn", type=float, default=0.7,
                    help="insert fraction of each mutation batch (the rest "
                         "deletes; 1.0 keeps warm starts monotone)")
    ap.add_argument("--standing", type=int, default=8,
                    help="standing query set kept fresh across mutations")
    ap.add_argument("--no-compact", action="store_true",
                    help="disable threshold-driven compaction (capacity-"
                         "overflow auto-compaction still applies; its "
                         "pauses are reported either way)")
    # --- depth-bucketing scheduler (ROADMAP open item) ---
    ap.add_argument("--depth-buckets", type=int, default=0, metavar="B",
                    help="serve the stream in B estimated-depth buckets and "
                         "report per-bucket p99 vs the unbucketed baseline")
    # --- fault tolerance & SLA (docs/robustness.md) ---
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection drill: a clean mutating "
                         "session, then the same session with injected "
                         "crashes; assert recovery, zero lost mutations, "
                         "and bitwise parity")
    ap.add_argument("--corrupt", action="store_true",
                    help="run the silent-corruption drill: inject bit-flips "
                         "at every data-corruption site (state rows, "
                         "exchange payloads, checkpoint tensors, tombstone "
                         "masks) across all three backends; assert every "
                         "fault is detected-or-masked and the clean path "
                         "raises zero false positives")
    ap.add_argument("--checkpoint-every", type=int, default=2,
                    help="supersteps per checkpointable chunk in the "
                         "fault-tolerant refresh path")
    ap.add_argument("--superstep-budget", type=int, default=64,
                    help="quarantine standing queries still unconverged "
                         "after this many supersteps (divergence watchdog)")
    ap.add_argument("--max-restarts", type=int, default=5,
                    help="retryable-failure budget for the serving session")
    ap.add_argument("--restart-backoff-s", type=float, default=0.0,
                    help="base exponential-backoff sleep between restarts")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-query SLA deadline; misses are reported")
    ap.add_argument("--queue-capacity", type=int, default=None,
                    help="admission-control bound on the query queue; "
                         "overflow is rejected with a reason")
    # --- continuous batching (docs/serving.md) ---
    ap.add_argument("--continuous", action="store_true",
                    help="serve through one resident ServeSession: refill "
                         "converged query slots mid-loop instead of "
                         "draining the batch (composes with --mutate, "
                         "--deadline-ms, --queue-capacity, --depth-buckets)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.scale = min(args.scale, 8)
        args.batch = min(args.batch, 4)
        args.num_queries = min(args.num_queries, 3 * args.batch)
        args.mutation_batch = min(args.mutation_batch, 32)
        args.mutation_rounds = min(args.mutation_rounds, 3)
        args.standing = min(args.standing, 4)

    try:
        cfg = ServeConfig.from_args(args)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if cfg.mode == "chaos":
        return run_chaos_drill(args)

    if cfg.mode == "corrupt":
        return run_corrupt_drill(args)

    if cfg.mode == "continuous":
        dg = stream = None
        if cfg.mutate:
            from repro.data.graphs import edge_stream

            g, dg, engine = build_engine(args, dynamic=True)
            stream = edge_stream(g, args.mutation_rounds,
                                 args.mutation_batch, churn=args.churn,
                                 seed=args.seed)
        else:
            g, _, engine = build_engine(args)
        print(f"resident graph: |V|={g.num_vertices:,} "
              f"|E|={g.num_edges:,} parts={args.parts} "
              f"backend={args.backend} continuous slots={cfg.batch}",
              flush=True)
        rng = np.random.default_rng(args.seed)
        sources = rng.integers(0, g.num_vertices, size=args.num_queries)
        report = serve_continuous(engine, g, cfg, sources, dg=dg,
                                  mutation_stream=stream,
                                  parity=args.smoke)
        print(f"{cfg.alg}: {report['completed']}/{report['stream']} "
              f"queries through {report['slots']} resident slots "
              f"({report['waves']} wave(s)) -> "
              f"{report['continuous_qps']:.1f} q/s continuous vs "
              f"{report['drain_qps']:.1f} q/s drain; p99 "
              f"{report['continuous_p99_ms']:.1f} vs "
              f"{report['drain_p99_ms']:.1f} ms; "
              f"refills={report['refills']} "
              f"(min/slot={report['min_slot_refills']}); "
              f"retraces={report['retraces']}", flush=True)
        if "parity_checked" in report:
            print(f"parity: {report['parity_checked']} checked, "
                  f"{report['parity_mismatches']} mismatches", flush=True)
            assert report["parity_mismatches"] == 0, \
                "continuous results diverge from drain-batch"
        if report["retraces"]:
            print(f"WARNING: {report['retraces']} compile-cache entries "
                  f"added after warmup — refills are retracing",
                  file=sys.stderr)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(dict(vars(args), **report), f, indent=2)
            print(f"wrote {args.out}")
        print("GRAPH SERVE OK")
        return 0

    if cfg.mode == "mutate":
        from repro.data.graphs import edge_stream

        g, dg, engine = build_engine(args, dynamic=True)
        print(f"resident dynamic graph: |V|={g.num_vertices:,} "
              f"|E|={g.num_edges:,} parts={args.parts} "
              f"strategy={args.strategy} backend={args.backend} "
              f"delta_slots={dg.delta_slots}/partition", flush=True)
        stream = edge_stream(g, args.mutation_rounds, args.mutation_batch,
                             churn=args.churn, seed=args.seed)
        report = serve_mutating(
            engine, dg, args.alg, batches=stream, batch=args.batch,
            standing=args.standing, query_batches_per_round=2,
            seed=args.seed, compact=not args.no_compact)
        inc = report["incremental_steps"]
        cold = report["cold_steps"]
        savings = (f"{inc} vs {cold} supersteps "
                   f"({cold / max(inc, 1):.1f}x fewer)"
                   if inc is not None and cold else "n/a (non-monotone)")
        print(f"{args.alg}: {report['rounds']} mutation rounds x "
              f"{args.mutation_batch} edges -> "
              f"{report['mutation_edges_per_sec']:.0f} edges/s applied; "
              f"incremental refresh {savings}; query batch "
              f"p50={report['batch_p50_ms']:.1f} "
              f"p99={report['batch_p99_ms']:.1f} ms; "
              f"compactions={report['compactions']} "
              f"({report['compaction_pause_ms']:.0f} ms paused); "
              f"retraces={report['retraces']}", flush=True)
        if report["retraces"]:
            print(f"WARNING: {report['retraces']} compile-cache entries "
                  f"added after warmup without a compaction — mutation "
                  f"batches are retracing", file=sys.stderr)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(dict(vars(args), **report), f, indent=2)
            print(f"wrote {args.out}")
        print("GRAPH SERVE OK")
        return 0

    g, pg, engine = build_engine(args)
    print(f"resident graph: |V|={g.num_vertices:,} |E|={g.num_edges:,} "
          f"parts={args.parts} strategy={args.strategy} "
          f"backend={args.backend}", flush=True)
    if engine.tier_plan is not None:
        # Admission charges the *HBM* figure only against the device
        # budget: host-tier partitions stream from DRAM and must not be
        # counted as device residency (memory_footprint_bytes per-tier
        # split).  The arena figure is what the tier split itself gated.
        stats = engine.tiered_stats()
        resid = engine.residency_bytes()
        print(f"tiered: {stats['num_hot']} hot / {stats['num_cold']} "
              f"host-tier partitions; arena hbm={stats['hbm_resident_bytes']:,}"
              f" B <= budget {args.hbm_budget:,} B; residency "
              f"hbm={resid['hbm_bytes']:,} B host={resid['host_bytes']:,} B "
              f"(streams {stats['streamed_bytes_per_superstep']:,} B/"
              f"superstep over {stats['window_count']} windows)", flush=True)
        if stats["hbm_resident_bytes"] > args.hbm_budget:
            print("error: tier plan exceeds the HBM budget", file=sys.stderr)
            return 2

    rng = np.random.default_rng(args.seed)
    sources = rng.integers(0, g.num_vertices, size=args.num_queries)

    if cfg.mode == "depth":
        rep = serve_depth_bucketed(engine, g, args.alg, sources, args.batch,
                                   num_buckets=args.depth_buckets)
        for b in rep["buckets"]:
            print(f"bucket {b['bucket']} (deg>={b['min_degree']}, "
                  f"{b['queries']} queries): p99 "
                  f"{b['baseline_p99_ms']:.1f} -> "
                  f"{b['bucketed_p99_ms']:.1f} ms "
                  f"(p50 {b['baseline_p50_ms']:.1f} -> "
                  f"{b['bucketed_p50_ms']:.1f})", flush=True)
        print(f"stream p99 {rep['baseline_p99_ms']:.1f} -> "
              f"{rep['bucketed_p99_ms']:.1f} ms with {args.depth_buckets} "
              f"depth buckets", flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(dict(vars(args), **rep), f, indent=2)
            print(f"wrote {args.out}")
        print("GRAPH SERVE OK")
        return 0

    report = serve(engine, args.alg, sources, args.batch,
                   deadline_ms=args.deadline_ms,
                   queue_capacity=args.queue_capacity)
    if "admission" in report:
        a = report["admission"]
        print(f"admission: {a['admitted']} admitted, {a['rejected']} "
              f"rejected ({', '.join(a['reject_reasons']) or 'none'}) at "
              f"capacity {a['capacity']}", flush=True)
    if "sla" in report:
        s = report["sla"]
        print(f"SLA {s['deadline_ms']:.0f} ms: {s['met']} met, "
              f"{s['misses']} missed", flush=True)

    if report["ms_per_query"] is None:
        # Single-batch stream: everything landed in the cold batch.
        print(f"{args.alg}: {report['num_queries']} queries in one cold "
              f"batch of {args.batch} -> {report['cold_ms']:.0f} ms incl. "
              f"compilation (add batches for steady-state numbers)",
              flush=True)
    else:
        print(f"{args.alg}: {report['num_queries']} queries in batches of "
              f"{args.batch} -> {report['queries_per_sec']:.1f} q/s, "
              f"{report['ms_per_query']:.2f} ms/query amortized "
              f"(cold first batch {report['cold_ms']:.0f} ms; warm batch "
              f"p50={report['batch_p50_ms']:.1f} "
              f"p90={report['batch_p90_ms']:.1f} "
              f"p99={report['batch_p99_ms']:.1f} ms; "
              f"retraces={report['retraces']})", flush=True)
    if report["retraces"]:
        print(f"WARNING: {report['retraces']} compile-cache entries added "
              f"after warmup — batches are retracing", file=sys.stderr)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(dict(vars(args), **report), f, indent=2)
        print(f"wrote {args.out}")
    print("GRAPH SERVE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
