"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (CPU smoke → 1 device; a real pod → the
production mesh).  Integrates every substrate layer: seekable data, AdamW,
sharded params, async checkpointing, straggler watchdog, restart-on-failure.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.launch import mesh as M
from repro.launch import sharding as shd
from repro.models import api
from repro.optim.adamw import AdamW
from repro.runtime import StepWatchdog, run_with_restarts


def build_everything(arch: str, smoke: bool, batch: int, seq: int,
                     microbatches: int, lr: float, production: bool):
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    model = api.build(cfg)
    opt = AdamW(learning_rate=lr)
    n_mb = microbatches or 1
    step_fn = api.make_train_step(model, opt, microbatches=n_mb)
    stream = TokenStream(cfg, batch, seq)

    if production:
        mesh = M.make_production_mesh()
        params_shape = model.params_shape()
        pspecs = shd.param_specs(params_shape, mesh)

        def wrapped(params, opt_state, batch):
            with shd.activation_rules(mesh):
                return step_fn(params, opt_state, batch)

        jitted = jax.jit(wrapped,
                         in_shardings=(shd.named(pspecs, mesh), None, None))
    else:
        jitted = jax.jit(step_fn)
    return cfg, model, opt, jitted, stream


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg, model, opt, jitted, stream = build_everything(
        args.arch, args.smoke, args.batch, args.seq, args.microbatches,
        args.lr, args.production_mesh)
    print(f"arch={cfg.name} params≈"
          f"{sum(int(np.prod(x.shape)) for x in jax.tree.leaves(model.params_shape()))/1e6:.1f}M")

    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    manager = CheckpointManager(args.ckpt_dir)
    watchdog = StepWatchdog(
        on_straggler=lambda s, d, m: print(
            f"[watchdog] step {s} straggled: {d*1e3:.0f}ms vs {m*1e3:.0f}ms"))

    def one_step(step, state):
        watchdog.start()
        batch = stream.batch_at(step)
        params, opt_state, metrics = jitted(state["params"],
                                            state["opt_state"], batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        watchdog.stop(step)
        return {"params": params, "opt_state": opt_state}, metrics

    t0 = time.time()
    losses = []

    def log(step, metrics):
        losses.append(metrics["loss"])
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"({time.time()-t0:.1f}s)")

    state = {"params": params, "opt_state": opt_state}
    state, summary = run_with_restarts(
        one_step, state, args.steps, manager,
        checkpoint_every=args.ckpt_every, on_metrics=log)
    print(f"done: final loss {losses[-1]:.4f} (start {losses[0]:.4f}), "
          f"restarts={summary['failures']}, "
          f"mean step {watchdog.mean_step_s*1e3:.0f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
