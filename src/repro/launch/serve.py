"""Batched serving driver: prefill a prompt batch, then decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import TokenStream
from repro.models import api


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(
        args.arch)
    model = api.build(cfg)
    params = model.init(jax.random.key(0))
    stream = TokenStream(cfg, args.batch, args.prompt_len)
    batch = stream.batch_at(0)
    prompt = {k: (v[:, : args.prompt_len] if k == "tokens" else v)
              for k, v in batch.items()}

    max_len = args.prompt_len + args.gen + (
        cfg.frontend_len if cfg.frontend == "vision" else 0)
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, prompt)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    prefill_tok = args.batch * args.prompt_len

    key = jax.random.key(1)
    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tokens]
    t1 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tokens)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tokens = jax.random.categorical(
                sub, logits / args.temperature).astype(jnp.int32)
        else:
            tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tokens)
    jax.block_until_ready(generated[-1])
    t_decode = time.time() - t1
    gen_tok = args.batch * (args.gen - 1)

    out = np.stack([np.asarray(t) for t in generated], 1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {prefill_tok} tok in {t_prefill:.2f}s "
          f"({prefill_tok/t_prefill:.0f} tok/s incl compile)")
    print(f"decode:  {gen_tok} tok in {t_decode:.2f}s "
          f"({gen_tok/max(t_decode,1e-9):.0f} tok/s)")
    print("sample token ids:", out[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
