"""Sharding rules: logical activation axes + parameter PartitionSpecs.

The models annotate activations with *logical* names ("batch", "ffn", …);
this module resolves them to mesh axes.  Parameters are sharded by leaf-name
convention:

  - column-parallel weights (wq/wk/wv/w_gate/w_up/moe_w1): last dim → model
  - row-parallel weights  (wo/w_down/moe_w2): contracted dim → model
  - embeddings / lm head: vocab dim → model
  - everything big additionally FSDP-shards over the data(+pod) axes
  - MoE expert stacks: expert dim → model (expert parallelism)

This is Megatron-style TP × FSDP, hierarchical across pods (the "pod" axis
joins the FSDP/data-parallel group).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def activation_rules(mesh: Mesh, *, seq_sharded: bool = False):
    """Install logical-axis → mesh-axis rules for the enclosed trace."""
    batch = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    rules = {
        "batch": batch,
        "heads": ("model",),
        "ffn": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_cap": batch,
        "seq": ("model",) if seq_sharded else None,
        "kv_len": None,
        "embed": None,
    }
    prev = _rules()
    prev_mesh = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev
        _state.mesh = prev_mesh


def data_shard_count() -> int:
    """Number of shards on the data(+pod) axes of the active mesh (1 when
    no mesh rules are installed — smoke tests)."""
    mesh = getattr(_state, "mesh", None)
    if mesh is None or _rules() is None:
        return 1
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a in ("pod", "data")]))


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    rules = _rules()
    mesh = getattr(_state, "mesh", None)
    if rules is None or mesh is None:
        return x
    spec = []
    for dim, n in zip(x.shape, names):
        axes = rules.get(n) if n else None
        if axes:
            size = int(np.prod([mesh.shape[a] for a in axes]))
            axes = axes if dim % size == 0 else None
        spec.append(axes if axes else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

_COL_NAMES = ("wq", "wk", "wv", "w_gate", "w_up", "moe_w1", "in_proj",
              "patch_proj", "frame_proj")
_ROW_NAMES = ("wo", "w_down", "moe_w2", "out_proj")
_VOCAB_NAMES = ("embed", "lm_head")
_EXPERT_NAMES = ("moe_w1", "moe_w2", "moe_wg")


def _leaf_spec(path: str, ndim: int, shape, fsdp_axes: Tuple[str, ...],
               mesh: Mesh, fsdp_min_size: int = 1 << 20) -> P:
    name = path.split("/")[-1]
    big = int(np.prod(shape)) >= fsdp_min_size
    is_expert = any(name.startswith(e) for e in _EXPERT_NAMES)
    spec = [None] * ndim

    def put(dim: int, axes) -> bool:
        """Assign mesh axes to dim if the size divides evenly."""
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        size = int(np.prod([mesh.shape[a] for a in axes_t]))
        if shape[dim] % size == 0 and spec[dim] is None:
            spec[dim] = axes_t if len(axes_t) > 1 else axes_t[0]
            return True
        return False

    if any(name == v for v in _VOCAB_NAMES):
        # [V, D] or [D, V]: shard the larger (vocab) dim on model.
        vdim = int(np.argmax(shape[-2:])) + ndim - 2
        other = ndim - 1 if vdim == ndim - 2 else ndim - 2
        put(vdim, "model")
        if big:
            put(other, fsdp_axes)
        return P(*spec)

    if is_expert and ndim >= 3:
        # [L, E, ...]: expert parallelism on the E axis.
        put(1, "model")
        if big and ndim >= 4:
            put(2, fsdp_axes)
        return P(*spec)

    if any(name.startswith(c) for c in _COL_NAMES) and ndim >= 2:
        put(ndim - 1, "model")
        if big:
            put(ndim - 2, fsdp_axes)
        return P(*spec)
    if any(name.startswith(r) for r in _ROW_NAMES) and ndim >= 2:
        put(ndim - 2, "model")
        if big:
            put(ndim - 1, fsdp_axes)
        return P(*spec)
    # norms / biases / small tensors: replicated (still FSDP the huge ones).
    if big and ndim >= 2:
        put(ndim - 1, fsdp_axes) or put(ndim - 2, fsdp_axes)
    return P(*spec)


def param_specs(params_shape, mesh: Mesh):
    """PartitionSpec pytree matching a params (shape-)pytree."""
    fsdp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        shape = tree.shape
        return _leaf_spec(prefix, len(shape), shape, fsdp, mesh)

    return walk(params_shape)


def named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_shape, mesh: Mesh):
    """Inputs: leading batch dim sharded over pod+data (when divisible —
    long_500k has global_batch=1, which stays replicated)."""
    fsdp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    size = int(np.prod([mesh.shape[a] for a in fsdp]))

    def leaf(x):
        if len(x.shape) and x.shape[0] % size == 0:
            return P(fsdp, *([None] * (len(x.shape) - 1)))
        return P(*([None] * len(x.shape)))

    return jax.tree.map(leaf, batch_shape)
