"""Distributed self-test: run the BSP engine sharded over N host devices and
compare against the single-device engine.  Invoked in a subprocess (so the
device-count env var doesn't leak into the main test process):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.selftest
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np


def main() -> int:
    n_dev = len(jax.devices())
    assert n_dev >= 2, f"need >1 device, got {n_dev}"
    from repro.core import graph as G
    from repro.core import partition as PT
    from repro.core.bsp import (BSPEngine, DistributedBSPEngine,
                                batch_state, unbatch_state)
    from repro.algorithms import bfs, pagerank
    from repro.algorithms.bfs import BFS_PROGRAM
    from repro.algorithms.pagerank import pagerank_distributed

    mesh = jax.make_mesh((n_dev,), ("parts",))
    g = G.rmat(10, 8, seed=7)
    pg = PT.partition(g, n_dev, PT.HIGH, align=8)

    local = BSPEngine(pg)
    dist = DistributedBSPEngine(pg, mesh)

    # BFS
    lv_local, _ = bfs(local, source=0)
    level0 = np.full((pg.num_parts, pg.v_max), np.inf, dtype=np.float32)
    sp = int(pg.assignment.part_of[0])
    sl = int(pg.assignment.local_id[0])
    level0[sp, sl] = 0.0
    state_b, steps_q = dist.execute(BFS_PROGRAM,
                                    batch_state({"level":
                                                 jnp.asarray(level0)}))
    state, steps = unbatch_state(state_b), steps_q[0]
    lv_dist = pg.gather_global(np.asarray(state["level"]))
    np.testing.assert_array_equal(lv_local, lv_dist)
    print(f"BFS distributed == local over {n_dev} devices "
          f"({int(steps)} supersteps)")

    # PageRank
    pr_local = pagerank(local, num_iterations=10)
    pr_dist = pagerank_distributed(dist, num_iterations=10)
    np.testing.assert_allclose(pr_local, pr_dist, rtol=1e-5, atol=1e-8)
    print("PageRank distributed == local")

    # Fused superstep path (Pallas kernel) sharded over the mesh: the
    # compat shard_map shim + fused compute must compose.
    fused = DistributedBSPEngine(pg, mesh, fused=True)
    state_b, _ = fused.execute(BFS_PROGRAM,
                               batch_state({"level": jnp.asarray(level0)}))
    lv_fused = pg.gather_global(np.asarray(unbatch_state(state_b)["level"]))
    np.testing.assert_array_equal(lv_local, lv_fused)
    pr_fused = pagerank_distributed(fused, num_iterations=10)
    np.testing.assert_allclose(pr_local, pr_fused, rtol=1e-5, atol=1e-8)
    print("Fused superstep distributed == local")
    print("SELFTEST OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
