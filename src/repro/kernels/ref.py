"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_spmv_ref(x: jax.Array, a: jax.Array) -> jax.Array:
    """y = x @ a with f32 accumulation."""
    return jnp.dot(x, a, preferred_element_type=jnp.float32)


def dense_spmv_minplus_ref(x: jax.Array, a: jax.Array) -> jax.Array:
    """y[m, n] = min_k x[m, k] + a[k, n] (tropical matmul)."""
    return jnp.min(x[:, :, None] + a[None, :, :], axis=1)


def ell_spmv_ref(col: jax.Array, val: jax.Array, x: jax.Array,
                 combine: str | None = None,
                 semiring: str | None = None) -> jax.Array:
    from repro.kernels.ell_spmv import resolve_semiring
    sr = resolve_semiring(combine, semiring)
    gathered = jnp.take(x, col, axis=0)
    if sr == "plus_times":
        return jnp.sum(gathered * val, axis=1).astype(jnp.float32)
    if sr == "min":
        return jnp.min(gathered, axis=1).astype(jnp.float32)
    return jnp.min(gathered + val, axis=1).astype(jnp.float32)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """Naive softmax attention, [BH, S, D] (same masking semantics)."""
    bh, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def segment_reduce_ref(msgs: jax.Array, seg_ids: jax.Array,
                       num_segments: int, combine: str = "sum") -> jax.Array:
    if combine == "sum":
        return jax.ops.segment_sum(msgs, seg_ids,
                                   num_segments=num_segments)
    return jax.ops.segment_min(msgs, seg_ids, num_segments=num_segments)
