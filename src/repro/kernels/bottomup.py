"""Bottom-up (pull) traversal scan Pallas kernel — direction optimization.

Direction-optimized BFS (Sallinen/Gharaibeh/Ripeanu, arXiv 1503.04359) flips
dense-frontier supersteps from top-down push (every frontier vertex scatters
along its out-edges) to bottom-up pull: every destination row scans its
*in*-neighbours and stops at the first parent already in the frontier.  On a
scale-free graph the dense middle steps find a parent within a slot or two —
the in-neighbour slots are packed degree-descending, so slot 0 is the
neighbour most likely to be reached first — and the traversal examines a
small fraction of the edges the push direction would.

This kernel is the ELL ``min``/``min_plus`` SpMV (kernels/ell_spmv.py) with a
second output: alongside ``y[v] = ⊕_k x[col[v,k]] (⊗ val[v,k])`` it emits
``scanned[v]``, the number of slots a sequential early-exit scan of row ``v``
would examine:

  - ``early_exit=True`` (uniform-frontier programs — BFS, where every live
    message this superstep equals ``step+1``): ``min(first_hit + 1, kreal)``,
    where ``first_hit`` is the first slot whose gathered ``x`` is live
    (``< +inf``, the ⊕-identity of min combines).  Early exit is *exact*
    only under message uniformity: the first live parent's value IS the min.
  - ``early_exit=False`` (CC labels, SSSP distances — messages differ per
    parent): the full ``kreal[v]`` real slots.

The reduction itself always covers every slot (the VPU form is a vectorized
gather + row-min, bitwise identical to ``ell_spmv``'s — that's the parity
guarantee); ``scanned`` is the deterministic *work model* of the sequential
scan a scalar core (or a chunked-K TPU kernel that breaks once a whole row
block has hit) would perform.  Under the same uniformity licence a row's
first write is its fixpoint value, so a sequential bottom-up visits only
still-unvisited rows — ``ops.bottomup_scan_op``'s ``skip`` mask zeroes the
charge for rows already holding a value.  The engine sums the result into
the per-query ``edges_examined`` counter — the observable the bench gates
on.

``kreal[v]`` is the row's real (non-sentinel) slot count; sentinel slots
gather the +inf sink and can never register a hit, so rows report at most
their real work.  x carries the query-batch axis exactly as in ell_spmv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_counts(gathered, kreal, early_exit: bool):
    """Slots a sequential early-exit scan would touch, per row."""
    if not early_exit:
        return kreal
    k = gathered.shape[1]
    hit = gathered < jnp.inf
    idx = jax.lax.broadcasted_iota(jnp.int32, gathered.shape, 1)
    first = jnp.min(jnp.where(hit, idx, k), axis=1)
    return jnp.minimum(first + 1, kreal)


def _bu_kernel_min(col_ref, kreal_ref, x_ref, o_ref, s_ref, *,
                   early_exit: bool):
    cols = col_ref[...]                      # [bv, K] int32
    x = x_ref[0]                             # [x_len]: this query's row
    gathered = jnp.take(x, cols, axis=0)     # [bv, K]
    o_ref[...] = jnp.min(gathered, axis=1)[None]
    s_ref[...] = _scan_counts(gathered, kreal_ref[..., 0], early_exit)[None]


def _bu_kernel_min_plus(col_ref, val_ref, kreal_ref, x_ref, o_ref, s_ref, *,
                        early_exit: bool):
    cols = col_ref[...]
    vals = val_ref[...]
    x = x_ref[0]
    gathered = jnp.take(x, cols, axis=0)
    o_ref[...] = jnp.min(gathered + vals, axis=1)[None]
    # A "hit" is a live *parent* (x finite), judged before the ⊗ add —
    # the scan stops on reaching any frontier in-neighbour.
    s_ref[...] = _scan_counts(gathered, kreal_ref[..., 0], early_exit)[None]


@functools.partial(jax.jit,
                   static_argnames=("semiring", "early_exit", "block_v",
                                    "interpret"))
def bottomup_scan(col: jax.Array, val: jax.Array | None, x: jax.Array,
                  kreal: jax.Array, *, semiring: str,
                  early_exit: bool = False, block_v: int = 512,
                  interpret: bool = False):
    """Bottom-up scan over a (query, row-block) grid.

    col: [V, K] int32 in-neighbour ids into ``x`` (sentinel = x_len-1);
    val: [V, K] f32 (``min_plus``) or None (``min``); x: [Q, x_len] with the
    ⊕-identity sink appended per row; kreal: [V, 1] int32 real slot counts.
    Returns ``(y [Q, V] f32, scanned [Q, V] int32)``.  V must be a multiple
    of block_v (ops.py pads).
    """
    if semiring not in ("min", "min_plus"):
        raise ValueError(f"bottom-up scan needs a min combine, "
                         f"got {semiring!r}")
    v, k = col.shape
    q = x.shape[0]
    assert x.ndim == 2, "ops.bottomup_scan_op adds the query-batch axis"
    assert v % block_v == 0, "ops.bottomup_scan_op pads to block multiples"
    assert kreal.shape == (v, 1)
    row_specs = [pl.BlockSpec((block_v, k), lambda b, i: (i, 0))]
    args = [col]
    if semiring == "min_plus":
        assert val is not None and val.shape == (v, k)
        kernel = functools.partial(_bu_kernel_min_plus, early_exit=early_exit)
        row_specs.append(pl.BlockSpec((block_v, k), lambda b, i: (i, 0)))
        args.append(val)
    else:
        kernel = functools.partial(_bu_kernel_min, early_exit=early_exit)
    return pl.pallas_call(
        kernel,
        grid=(q, v // block_v),
        in_specs=row_specs + [
            pl.BlockSpec((block_v, 1), lambda b, i: (i, 0)),
            # one query's x row, VMEM resident across its row blocks
            pl.BlockSpec((1, x.shape[1]), lambda b, i: (b, 0)),
        ],
        out_specs=[pl.BlockSpec((1, block_v), lambda b, i: (b, i)),
                   pl.BlockSpec((1, block_v), lambda b, i: (b, i))],
        out_shape=[jax.ShapeDtypeStruct((q, v), jnp.float32),
                   jax.ShapeDtypeStruct((q, v), jnp.int32)],
        interpret=interpret,
    )(*args, kreal, x)
