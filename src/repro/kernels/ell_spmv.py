"""ELLPACK SpMV Pallas kernel — the sparse/VPU path of the hybrid engine.

The low-degree remainder of a degree-partitioned scale-free graph has a tight
degree bound, so ELLPACK padding is cheap: ``col[V, K]`` holds up to K
neighbour ids per vertex (sentinel-padded), ``val[V, K]`` the edge values.
The kernel streams row-blocks HBM→VMEM (grid pipelining double-buffers the
DMA — the latency-hiding role the GPU's hardware multithreading plays in the
paper) and keeps the source-value vector ``x`` VMEM-resident across the whole
grid, the analogue of the paper's cache-resident summary data structure.

Two combine modes cover the TOTEM algorithms (paper §3.4 reduction classes):
  - ``sum``: y[v] = Σ_k x[col[v,k]] · val[v,k]        (PageRank)
  - ``min``: y[v] = min_k x[col[v,k]] + val[v,k]      (BFS/SSSP/CC)

Sentinel slots (col == x_len-1, the padded sink) carry val = 0 / +inf so they
are identity under the respective combine.

TPU note: the row gather ``x[col_block]`` lowers to Mosaic's 32-bit dynamic
VMEM gather on v4+; on older targets the fallback is a one-hot matmul
(``dense_spmv`` path).  Validated here with interpret=True per task spec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel_sum(col_ref, val_ref, x_ref, o_ref):
    cols = col_ref[...]                      # [bv, K] int32
    vals = val_ref[...]                      # [bv, K]
    x = x_ref[...]                           # [x_len] (VMEM resident)
    gathered = jnp.take(x, cols, axis=0)     # [bv, K]
    o_ref[...] = jnp.sum(gathered * vals, axis=1)


def _ell_kernel_min(col_ref, val_ref, x_ref, o_ref):
    cols = col_ref[...]
    vals = val_ref[...]
    x = x_ref[...]
    gathered = jnp.take(x, cols, axis=0)
    o_ref[...] = jnp.min(gathered + vals, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("combine", "block_v", "interpret"))
def ell_spmv(col: jax.Array, val: jax.Array, x: jax.Array, *,
             combine: str = "sum", block_v: int = 512,
             interpret: bool = False) -> jax.Array:
    """ELL SpMV over a row-blocked grid.

    col: [V, K] int32 neighbour ids into ``x``; val: [V, K]; x: [x_len].
    Returns y: [V] f32.  V must be a multiple of block_v (ops.py pads).
    """
    v, k = col.shape
    assert val.shape == (v, k)
    assert v % block_v == 0, "ops.ell_spmv_op pads to block multiples"
    kernel = _ell_kernel_sum if combine == "sum" else _ell_kernel_min
    grid = (v // block_v,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, k), lambda i: (i, 0)),
            pl.BlockSpec((block_v, k), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0,)),   # whole x, VMEM resident
        ],
        out_specs=pl.BlockSpec((block_v,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((v,), jnp.float32),
        interpret=interpret,
    )(col, val, x)
