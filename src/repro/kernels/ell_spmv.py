"""ELLPACK SpMV Pallas kernel — the sparse/VPU path of the hybrid engine.

The low-degree remainder of a degree-partitioned scale-free graph has a tight
degree bound, so ELLPACK padding is cheap: ``col[V, K]`` holds up to K
neighbour ids per vertex (sentinel-padded), ``val[V, K]`` the edge values.
The kernel streams row-blocks HBM→VMEM (grid pipelining double-buffers the
DMA — the latency-hiding role the GPU's hardware multithreading plays in the
paper) and keeps the source-value vector ``x`` VMEM-resident across the whole
grid, the analogue of the paper's cache-resident summary data structure.

Three semirings cover the TOTEM algorithms (paper §3.4 reduction classes):
  - ``plus_times``: y[v] = Σ_k x[col[v,k]] · val[v,k]      (PageRank, BC)
  - ``min_plus``:   y[v] = min_k x[col[v,k]] + val[v,k]    (BFS, SSSP)
  - ``min``:        y[v] = min_k x[col[v,k]]               (CC label prop)

``min`` is ``min_plus`` with all-zero values, but gets its own kernel so the
pure-propagation algorithms skip the add on the VPU.  Sentinel slots
(col == x_len-1, the padded sink) carry the ⊗-identity value (1/0/ignored)
and x's sink entry carries the ⊕-identity (0/+inf), so padding never
contributes.  ``combine="sum"|"min"`` remains as a back-compat alias for
``plus_times``/``min_plus``.

The value vector carries a leading **query-batch axis**: ``x[Q, x_len]`` →
``y[Q, V]``.  The topology (``col``/``val``) is shared across the batch —
the grid is ``(Q, V/block_v)`` with the batch axis outermost, so each
query's x row stays VMEM-resident while its row blocks stream through; the
adjacency HBM traffic is paid once per query, never duplicated per edge.

TPU note: the row gather ``x[col_block]`` lowers to Mosaic's 32-bit dynamic
VMEM gather on v4+; on older targets the fallback is a one-hot matmul
(``dense_spmv`` path).  Validated here with interpret=True per task spec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel_sum(col_ref, val_ref, x_ref, o_ref):
    cols = col_ref[...]                      # [bv, K] int32
    vals = val_ref[...]                      # [bv, K]
    x = x_ref[0]                             # [x_len]: this query's row
    gathered = jnp.take(x, cols, axis=0)     # [bv, K]
    o_ref[...] = jnp.sum(gathered * vals, axis=1)[None]


def _ell_kernel_min_plus(col_ref, val_ref, x_ref, o_ref):
    cols = col_ref[...]
    vals = val_ref[...]
    x = x_ref[0]
    gathered = jnp.take(x, cols, axis=0)
    o_ref[...] = jnp.min(gathered + vals, axis=1)[None]


def _ell_kernel_min(col_ref, val_ref, x_ref, o_ref):
    del val_ref                              # pure propagation: no ⊗
    cols = col_ref[...]
    x = x_ref[0]
    o_ref[...] = jnp.min(jnp.take(x, cols, axis=0), axis=1)[None]


# semiring → (kernel, ⊕ name, ⊕ identity, ⊗ identity for sentinel slots)
SEMIRINGS = {
    "plus_times": (_ell_kernel_sum, "sum", 0.0, 1.0),
    "min_plus": (_ell_kernel_min_plus, "min", float("inf"), 0.0),
    "min": (_ell_kernel_min, "min", float("inf"), 0.0),
}
_COMBINE_ALIAS = {"sum": "plus_times", "min": "min_plus"}


def resolve_semiring(combine: str | None, semiring: str | None) -> str:
    """Map the legacy ``combine`` name / explicit ``semiring`` to a key."""
    if semiring is not None:
        if semiring not in SEMIRINGS:
            raise ValueError(f"unknown semiring {semiring!r}")
        return semiring
    return _COMBINE_ALIAS[combine or "sum"]


@functools.partial(jax.jit,
                   static_argnames=("combine", "semiring", "block_v",
                                    "interpret"))
def ell_spmv(col: jax.Array, val: jax.Array, x: jax.Array, *,
             combine: str | None = None, semiring: str | None = None,
             block_v: int = 512, interpret: bool = False) -> jax.Array:
    """ELL SpMV over a (query, row-block) grid.

    col: [V, K] int32 neighbour ids into ``x``; val: [V, K]; x: [Q, x_len]
    (the query-batch axis; topology is shared across it).  Returns
    y: [Q, V] f32.  V must be a multiple of block_v (ops.py pads).
    """
    v, k = col.shape
    q = x.shape[0]
    assert val.shape == (v, k)
    assert x.ndim == 2, "ops.ell_spmv_op adds the query-batch axis"
    assert v % block_v == 0, "ops.ell_spmv_op pads to block multiples"
    kernel = SEMIRINGS[resolve_semiring(combine, semiring)][0]
    grid = (q, v // block_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, k), lambda b, i: (i, 0)),
            pl.BlockSpec((block_v, k), lambda b, i: (i, 0)),
            # one query's x row, VMEM resident across its row blocks
            pl.BlockSpec((1, x.shape[1]), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_v), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((q, v), jnp.float32),
        interpret=interpret,
    )(col, val, x)
