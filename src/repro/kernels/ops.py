"""jit'd public wrappers around the Pallas kernels.

These handle shape padding / alignment (callers see arbitrary shapes, the
kernels see 128-aligned tiles), dtype policy (bf16 compute, f32 accumulate),
interpret-mode selection (CPU container → interpret=True, real TPU → False),
and the CSR→ELL / CSR→dense-block packing used by the hybrid engine.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph
from repro.kernels import dense_spmv as _dense
from repro.kernels import ell_spmv as _ell
from repro.kernels import flash_attention as _flash


# jax 0.4.x ships lax.optimization_barrier without a vmap rule; the barrier
# is dim-wise transparent, so batching is operand pass-through.  Newer jax
# registers its own rule — the guard keeps this a no-op there.  The barrier
# is how kernel callers pin FMA-contraction seams (see hybrid_spmv and the
# out-of-core tiered path, which must round bitwise-identically).
from jax.interpreters import batching as _batching  # noqa: E402

if jax.lax.optimization_barrier_p not in _batching.primitive_batchers:
    def _barrier_batcher(args, dims):
        return jax.lax.optimization_barrier_p.bind(*args), dims
    _batching.primitive_batchers[jax.lax.optimization_barrier_p] = \
        _barrier_batcher


def pin(x: jax.Array) -> jax.Array:
    """``lax.optimization_barrier`` with the vmap shim above guaranteed
    registered — importing this function is what loads the rule, so
    callers outside the kernel layer (e.g. an ``apply_fn`` that must not
    be FMA-contracted) use this spelling."""
    return jax.lax.optimization_barrier(x)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult: int, axis: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# dense-block SpMV
# ---------------------------------------------------------------------------

def dense_spmv_op(x: jax.Array, a: jax.Array, *, block: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    """y = x @ a for arbitrary [M, K] × [K, N]; pads K and N to tiles."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = x.shape
    _, n = a.shape
    bk = min(block, max(128, 1 << (k - 1).bit_length()))
    bn = min(block, max(128, 1 << (n - 1).bit_length()))
    xp = _pad_to(x, bk, 1)
    ap = _pad_to(_pad_to(a, bk, 0), bn, 1)
    y = _dense.dense_spmv(xp, ap, block_n=bn, block_k=bk,
                          interpret=interpret)
    return y[:, :n]


def dense_spmv_minplus_op(x: jax.Array, a: jax.Array, *, block: int = 256,
                          interpret: bool | None = None) -> jax.Array:
    """y[m, n] = min_k x[m, k] + a[k, n]; pads K and N with +inf."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = x.shape
    _, n = a.shape
    bk = min(block, max(128, 1 << (k - 1).bit_length()))
    bn = min(block, max(128, 1 << (n - 1).bit_length()))
    xp = _pad_to(x, bk, 1, value=jnp.inf)
    ap = _pad_to(_pad_to(a, bk, 0, value=jnp.inf), bn, 1, value=jnp.inf)
    y = _dense.dense_spmv_minplus(xp, ap, block_n=bn, block_k=bk,
                                  interpret=interpret)
    return y[:, :n]


# ---------------------------------------------------------------------------
# ELL SpMV
# ---------------------------------------------------------------------------

def csr_to_ell(g: CSRGraph, combine: str | None = None,
               semiring: str | None = None,
               transpose: bool = True) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pack a CSR graph into ELLPACK (numpy preprocessing).

    ``transpose=True`` packs *in*-edges per vertex (pull form: y[v] reduces
    over in-neighbours), which is the natural SpMV orientation.  Sentinel
    slots point at index ``num_vertices`` (callers append a ⊕-identity slot
    to x) with ⊗-identity values.

    Two value policies, kept separate for back-compat:

    - legacy ``combine=``: exactly the pre-semiring packing — ``"sum"`` →
      1.0 per edge (multiplicity counts, weights ignored), ``"min"`` →
      weights (1.0 unweighted).
    - explicit ``semiring=``: ``plus_times`` → weight (1 unweighted),
      ``min_plus`` → weight (0 unweighted: the message carries the
      distance, the edge adds nothing), ``min`` → 0 (values unused by the
      kernel).  The hybrid engine passes explicit weights, so the
      unweighted fallbacks only matter for direct callers.
    """
    sr = _ell.resolve_semiring(combine, semiring)
    legacy = semiring is None
    gg = g.reverse() if transpose else g
    deg = gg.out_degrees()
    kmax = max(int(deg.max()) if len(deg) else 1, 1)
    n = gg.num_vertices
    mul_ident = _ell.SEMIRINGS[sr][3]
    col = np.full((n, kmax), n, dtype=np.int32)
    val = np.full((n, kmax), mul_ident, dtype=np.float32)
    # Vectorized ELL pack: each edge's (row, slot) from its rank within the
    # CSR row, then one fancy-indexed scatter instead of an O(V) Python loop.
    rows = np.repeat(np.arange(n, dtype=np.int64), deg)
    slots = np.arange(gg.num_edges, dtype=np.int64) - \
        np.repeat(gg.row_ptr[:-1], deg)
    col[rows, slots] = gg.col
    if sr == "plus_times" and legacy:
        val[rows, slots] = 1.0
    elif sr == "min" and not legacy:
        val[rows, slots] = 0.0
    elif gg.weights is not None:
        val[rows, slots] = gg.weights
    else:
        unweighted = 1.0 if sr == "plus_times" or legacy else 0.0
        val[rows, slots] = unweighted
    return col, val, kmax


def ell_spmv_op(col: jax.Array, val: jax.Array, x: jax.Array, *,
                combine: str | None = None, semiring: str | None = None,
                block_v: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """ELL SpMV for arbitrary V; pads rows to the block size.

    ``x`` may be ``[x_len]`` (one query, returns ``[V]``) or ``[Q, x_len]``
    (query batch, returns ``[Q, V]``); the topology is shared across Q.
    """
    if interpret is None:
        interpret = _interpret_default()
    sr = _ell.resolve_semiring(combine, semiring)
    v = col.shape[0]
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    bv = min(block_v, max(8, 1 << (v - 1).bit_length()))
    mul_ident = _ell.SEMIRINGS[sr][3]
    sentinel = x.shape[1] - 1  # callers append the ⊕-identity slot
    colp = _pad_to(col, bv, 0, value=sentinel)
    valp = _pad_to(val, bv, 0, value=mul_ident)
    y = _ell.ell_spmv(colp, valp, x, semiring=sr, block_v=bv,
                      interpret=interpret)[:, :v]
    return y[0] if squeeze else y


def bottomup_scan_op(col: jax.Array, val: jax.Array | None, x: jax.Array,
                     kreal: jax.Array, *, semiring: str,
                     early_exit: bool = False, skip: jax.Array | None = None,
                     block_v: int = 512,
                     interpret: bool | None = None):
    """Bottom-up pull scan for arbitrary V; pads rows to the block size.

    ``col`` [V, K] in-neighbour ids (sentinel = x_len-1), ``val`` [V, K]
    (``min_plus``) or None (``min``), ``x`` [Q, x_len] with the ⊕-identity
    sink appended per row, ``kreal`` [V] real slot counts.  Returns
    ``(y [Q, V], scanned [Q, V] int32)`` — the row reduction (bitwise equal
    to ``ell_spmv_op``'s) plus the early-exit scan-work model
    (kernels/bottomup.py).  Padding rows report zero scanned slots.

    ``skip`` [Q, V] bool (uniform-frontier programs only, alongside
    ``early_exit``) marks rows whose value is already final — under
    message uniformity a vertex's first write is its fixpoint value, so
    a sequential bottom-up pass visits only the still-unvisited rows
    (Beamer's frontier loop) and skipped rows charge zero scanned slots.
    The reduction still covers them (that is the bitwise-parity
    guarantee); only the work model changes.
    """
    from repro.kernels import bottomup as _bu

    if interpret is None:
        interpret = _interpret_default()
    v = col.shape[0]
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
        if skip is not None and skip.ndim == 1:
            skip = skip[None]
    bv = min(block_v, max(8, 1 << (v - 1).bit_length()))
    sentinel = x.shape[1] - 1  # callers append the ⊕-identity slot
    colp = _pad_to(col, bv, 0, value=sentinel)
    valp = (_pad_to(val, bv, 0, value=_ell.SEMIRINGS[semiring][3])
            if val is not None else None)
    krealp = _pad_to(kreal.astype(jnp.int32), bv, 0)[:, None]
    y, scanned = _bu.bottomup_scan(colp, valp, x, krealp, semiring=semiring,
                                   early_exit=early_exit, block_v=bv,
                                   interpret=interpret)
    y, scanned = y[:, :v], scanned[:, :v]
    if skip is not None and early_exit:
        scanned = jnp.where(skip, 0, scanned)
    if squeeze:
        return y[0], scanned[0]
    return y, scanned


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def flash_attention_op(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       block_q: int = 512, block_k: int = 512,
                       interpret: bool | None = None) -> jax.Array:
    """[B, H, S, D] attention; repeats KV heads for GQA; pads S and D."""
    if interpret is None:
        interpret = _interpret_default()
    b, h, s, d = q.shape
    kv_heads = k.shape[1]
    if kv_heads != h:
        rep = h // kv_heads
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    out = _flash.flash_attention(qf, kf, vf, causal=causal, window=window,
                                 block_q=min(block_q, s),
                                 block_k=min(block_k, s),
                                 interpret=interpret)
    return out.reshape(b, h, s, d)


# ---------------------------------------------------------------------------
# sorted segment reduce (TOTEM message reduction)
# ---------------------------------------------------------------------------

def segment_reduce_op(msgs: jax.Array, seg_ids: np.ndarray,
                      num_segments: int, *, combine: str = "sum",
                      block_e: int = 1024, max_span: int = 4096,
                      interpret: bool | None = None) -> jax.Array:
    """Two-phase sorted segment reduce.

    ``seg_ids`` must be a *static* (numpy, sorted ascending) id array —
    it is preprocessing output in the engine (partition.py sorts edges by
    destination).  Falls back to plain ``jax.ops.segment_*`` when any
    block's segment-id span exceeds ``max_span`` (sparse/gappy data).
    """
    from repro.kernels import segment_reduce as _seg

    if interpret is None:
        interpret = _interpret_default()
    seg_ids = np.asarray(seg_ids)
    e = len(seg_ids)
    assert np.all(np.diff(seg_ids) >= 0), "seg_ids must be sorted"
    ident = 0.0 if combine == "sum" else np.inf

    pad = (-e) % block_e
    ids_p = np.concatenate([seg_ids,
                            np.full(pad, num_segments, seg_ids.dtype)])
    nb = len(ids_p) // block_e
    blocks = ids_p.reshape(nb, block_e)
    base = blocks[:, 0].astype(np.int32)                  # per-block min id
    span = int((blocks.max(axis=1) - base).max()) + 1
    if span > max_span:
        op = (jax.ops.segment_sum if combine == "sum"
              else jax.ops.segment_min)
        return op(msgs, jnp.asarray(seg_ids), num_segments=num_segments)

    span = max(8, -(-span // 8) * 8)
    local = (blocks - base[:, None]).astype(np.int32).reshape(-1)
    msgs_p = jnp.concatenate(
        [msgs.astype(jnp.float32),
         jnp.full((pad,), ident, jnp.float32)])
    partials = _seg.segment_reduce_blocks(
        msgs_p, jnp.asarray(local), span=span, block_e=block_e,
        combine=combine, interpret=interpret)            # [nb, span]

    # phase 2: merge block partials (blocks may share boundary segments)
    out_ids = (base[:, None] + np.arange(span)[None]).reshape(-1)
    out_ids = np.minimum(out_ids, num_segments)          # pad sink
    op = jax.ops.segment_sum if combine == "sum" else jax.ops.segment_min
    final = op(partials.reshape(-1), jnp.asarray(out_ids),
               num_segments=num_segments + 1)
    return final[:num_segments]


# ---------------------------------------------------------------------------
# source-side outbox aggregation (distributed hybrid boundary leg, §3.4)
# ---------------------------------------------------------------------------

def outbox_reduce_op(x: jax.Array, src: jax.Array, local: jax.Array,
                     mask: jax.Array, base: jax.Array, weight, *,
                     num_slots: int, combine: str = "sum", weight_op=None,
                     span: int, block_e: int = 256, max_span: int = 4096,
                     gather_chunk: int = 256,
                     interpret: bool | None = None) -> jax.Array:
    """Reduce boundary messages into the flat outbox-slot space.

    ``x`` is one shard's per-query per-vertex message matrix ``[Q, x_len]``
    (+ identity sink at the end of each row; a 1-D ``x`` is treated as
    ``Q=1``); ``src``/``local``/``mask``/``base``/``weight`` follow
    ``hybrid.shard_degree_split`` — boundary edges sorted by flat slot id
    with per-block base/local offsets, arriving as *operands* so each shard
    carries its own maps under ``shard_map`` (and shared across the query
    batch).  ``weight_op`` is the EdgeMessage's ⊗ ("add"/"mul"/None).
    Returns the [Q, num_slots] aggregated outboxes (⊕-identity for unused
    slots), or [num_slots] for 1-D input.

    Falls back to the plain gather → ``jax.ops.segment_*`` chain when the
    static ``span`` bound exceeds ``max_span`` or the VMEM budget for the
    kernel's [block_e, span] intermediates — correctness never depends on
    the kernel (same contract as ``fused_superstep_op``).
    """
    from repro.kernels import outbox_reduce as _obox

    if interpret is None:
        interpret = _interpret_default()
    ident = 0.0 if combine == "sum" else jnp.inf
    seg_op = jax.ops.segment_sum if combine == "sum" else jax.ops.segment_min
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    q = x.shape[0]
    e_pad = src.shape[0]
    nb = e_pad // block_e
    q_offs = (jnp.arange(q, dtype=jnp.int32) * (num_slots + 1))

    def apply_weight(msgs):
        if weight_op == "add":
            return msgs + weight
        if weight_op == "mul":
            return msgs * weight
        return msgs

    if span > fused_span_limit(block_e, combine, max_span):
        # Reference chain: reconstruct flat slot ids from base + local.
        ids = (jnp.repeat(base, block_e) + local).astype(jnp.int32)
        msgs = apply_weight(jnp.take(x, src, axis=1))       # [Q, e_pad]
        msgs = jnp.where(mask > 0, msgs, ident)
        ids = jnp.minimum(ids, num_slots)[None] + q_offs[:, None]
        acc = seg_op(msgs.ravel(), ids.ravel(),
                     num_segments=q * (num_slots + 1))
        acc = acc.reshape(q, num_slots + 1)[:, :num_slots]
        return acc[0] if squeeze else acc

    x_pad = _pad_to(x, gather_chunk, 1, value=ident)
    partials = _obox.outbox_reduce_blocks(
        x_pad, src, local, mask,
        weight if weight_op is not None else None, combine=combine,
        weight_op=weight_op, span=span, block_e=block_e,
        gather_chunk=gather_chunk, interpret=interpret)     # [Q, nb, span]

    # phase 2: merge block partials (blocks may share a boundary slot);
    # span overhang past the slot space drops into a sink.
    ids = jnp.minimum(base[:, None] + jnp.arange(span, dtype=jnp.int32),
                      num_slots)                            # [nb, span]
    ids = ids[None] + q_offs[:, None, None]
    acc = seg_op(partials.ravel(), ids.ravel(),
                 num_segments=q * (num_slots + 1))
    acc = acc.reshape(q, num_slots + 1)[:, :num_slots]
    return acc[0] if squeeze else acc


# ---------------------------------------------------------------------------
# fused superstep compute phase (TOTEM gather + message + reduction)
# ---------------------------------------------------------------------------

# VMEM byte budget for the kernel's dominant [block_e, span] intermediates
# (one f32 one-hot for sum; a bool hit + f32 select pair for min).  A TPU
# core has ~16 MiB of VMEM; half is left for the state block, edge blocks,
# gather scratch, and output partials.
_VMEM_BLOCK_BUDGET = 8 << 20


def fused_span_limit(block_e: int, combine: str = "sum",
                     max_span: int = 4096) -> int:
    """Largest block span the fused kernel will compile for.

    The caller's ``max_span`` bounds reassociation span; on top of that the
    [block_e, span] intermediates must fit the VMEM budget — ``min`` combines
    materialize two such arrays, halving the limit.  Spans above this fall
    back to the reference path (see ``fused_superstep_op``).
    """
    copies = 2 if combine == "min" else 1
    return min(max_span, _VMEM_BLOCK_BUDGET // (4 * block_e * copies))


def fused_superstep_op(msg_fn, vstate: jax.Array, weight, scal: jax.Array,
                       src: jax.Array, local: jax.Array, mask: jax.Array,
                       base: jax.Array, dst_ext: jax.Array, *,
                       num_segments: int, combine: str = "sum", span: int,
                       block_e: int = 1024, max_span: int = 4096,
                       gather_chunk: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """Fused compute phase: per-query accumulator [Q, Pl, num_segments].

    Inputs follow ``partition.build_block_metadata``: ``vstate`` is the
    stacked [Q, Pl, K, v_pad] gathered-state matrix, ``scal`` [Q, Pl, S]
    carries (step, *per-query per-partition consts), ``src``/``local``/
    ``mask`` are the [Pl, e_pad] block arrays (shared across the query
    batch), ``base`` [Pl, nb] the per-block segment bases, and
    ``span``/``block_e`` their static geometry.  ``msg_fn(vals, weight,
    scals) -> msgs`` is elementwise/broadcast-safe, so the same callable
    runs on [be]-shaped values inside the kernel and on
    [Q, Pl, e_max]-shaped values in the fallback.

    Falls back to the reference gather → message → ``jax.ops.segment_*``
    chain when the measured block span exceeds ``fused_span_limit`` — either
    ``max_span`` (adversarially gappy destinations) or the VMEM budget for
    the kernel's [block_e, span] intermediates.  Correctness never depends
    on the kernel, the same contract as ``segment_reduce_op``.
    """
    from repro.kernels import fused_superstep as _fused

    if interpret is None:
        interpret = _interpret_default()
    q, pl_count = vstate.shape[0], vstate.shape[1]
    ident = 0.0 if combine == "sum" else jnp.inf
    seg_op = jax.ops.segment_sum if combine == "sum" else jax.ops.segment_min

    if span > fused_span_limit(block_e, combine, max_span):
        # Reference path expressed through the elementwise form.
        e_max = dst_ext.shape[1]
        src_b = jnp.broadcast_to(src[None, :, :e_max], (q, pl_count, e_max))
        vals = tuple(
            jnp.take_along_axis(vstate[:, :, k_, :], src_b, axis=2)
            for k_ in range(vstate.shape[2]))
        scals = tuple(scal[:, :, j:j + 1] for j in range(scal.shape[2]))
        w = weight[:, :e_max] if weight is not None else None
        msgs = msg_fn(vals, w, scals).astype(jnp.float32)
        msgs = jnp.where(mask[:, :e_max] > 0, msgs, ident)
        offs = (jnp.arange(q * pl_count, dtype=jnp.int32)
                * num_segments).reshape(q, pl_count, 1)
        acc = seg_op(msgs.ravel(), (dst_ext[None] + offs).ravel(),
                     num_segments=q * pl_count * num_segments)
        return acc.reshape(q, pl_count, num_segments)

    partials = _fused.fused_superstep_blocks(
        vstate, scal, src, local, mask, weight, msg_fn=msg_fn,
        combine=combine, span=span, block_e=block_e,
        gather_chunk=gather_chunk, interpret=interpret)  # [Q, Pl, nb, span]

    # phase 2: merge block partials (blocks may share boundary segments);
    # ids past the segment space (base + span overhang) drop into a sink.
    ids = jnp.minimum(base[:, :, None] + jnp.arange(span, dtype=jnp.int32),
                      num_segments)                      # [Pl, nb, span]
    offs = (jnp.arange(q * pl_count, dtype=jnp.int32) *
            (num_segments + 1)).reshape(q, pl_count, 1, 1)
    acc = seg_op(partials.ravel(), (ids[None] + offs).ravel(),
                 num_segments=q * pl_count * (num_segments + 1))
    return acc.reshape(q, pl_count, num_segments + 1)[:, :, :num_segments]
