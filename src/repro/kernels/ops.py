"""jit'd public wrappers around the Pallas kernels.

These handle shape padding / alignment (callers see arbitrary shapes, the
kernels see 128-aligned tiles), dtype policy (bf16 compute, f32 accumulate),
interpret-mode selection (CPU container → interpret=True, real TPU → False),
and the CSR→ELL / CSR→dense-block packing used by the hybrid engine.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph
from repro.kernels import dense_spmv as _dense
from repro.kernels import ell_spmv as _ell
from repro.kernels import flash_attention as _flash


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult: int, axis: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# dense-block SpMV
# ---------------------------------------------------------------------------

def dense_spmv_op(x: jax.Array, a: jax.Array, *, block: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    """y = x @ a for arbitrary [M, K] × [K, N]; pads K and N to tiles."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = x.shape
    _, n = a.shape
    bk = min(block, max(128, 1 << (k - 1).bit_length()))
    bn = min(block, max(128, 1 << (n - 1).bit_length()))
    xp = _pad_to(x, bk, 1)
    ap = _pad_to(_pad_to(a, bk, 0), bn, 1)
    y = _dense.dense_spmv(xp, ap, block_n=bn, block_k=bk,
                          interpret=interpret)
    return y[:, :n]


# ---------------------------------------------------------------------------
# ELL SpMV
# ---------------------------------------------------------------------------

def csr_to_ell(g: CSRGraph, combine: str = "sum",
               transpose: bool = True) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pack a CSR graph into ELLPACK (numpy preprocessing).

    ``transpose=True`` packs *in*-edges per vertex (pull form: y[v] reduces
    over in-neighbours), which is the natural SpMV orientation.  Sentinel
    slots point at index ``num_vertices`` (callers append an identity slot to
    x) with identity values.
    """
    gg = g.reverse() if transpose else g
    deg = gg.out_degrees()
    kmax = max(int(deg.max()) if len(deg) else 1, 1)
    n = gg.num_vertices
    ident = 0.0 if combine == "sum" else np.inf
    col = np.full((n, kmax), n, dtype=np.int32)
    val = np.full((n, kmax), ident, dtype=np.float32)
    w = gg.weights if gg.weights is not None else np.ones(gg.num_edges,
                                                          dtype=np.float32)
    fill = 1.0 if combine == "sum" else w
    for v_ in range(n):
        lo, hi = gg.row_ptr[v_], gg.row_ptr[v_ + 1]
        col[v_, : hi - lo] = gg.col[lo:hi]
        val[v_, : hi - lo] = (np.ones(hi - lo) if combine == "sum"
                              else w[lo:hi])
    del fill
    return col, val, kmax


def ell_spmv_op(col: jax.Array, val: jax.Array, x: jax.Array, *,
                combine: str = "sum", block_v: int = 512,
                interpret: bool | None = None) -> jax.Array:
    """ELL SpMV for arbitrary V; pads rows to the block size."""
    if interpret is None:
        interpret = _interpret_default()
    v = col.shape[0]
    bv = min(block_v, max(8, 1 << (v - 1).bit_length()))
    ident = 0.0 if combine == "sum" else jnp.inf
    sentinel = x.shape[0] - 1  # callers append the identity slot
    colp = _pad_to(col, bv, 0, value=sentinel)
    valp = _pad_to(val, bv, 0, value=ident)
    y = _ell.ell_spmv(colp, valp, x, combine=combine, block_v=bv,
                      interpret=interpret)
    return y[:v]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def flash_attention_op(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       block_q: int = 512, block_k: int = 512,
                       interpret: bool | None = None) -> jax.Array:
    """[B, H, S, D] attention; repeats KV heads for GQA; pads S and D."""
    if interpret is None:
        interpret = _interpret_default()
    b, h, s, d = q.shape
    kv_heads = k.shape[1]
    if kv_heads != h:
        rep = h // kv_heads
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    out = _flash.flash_attention(qf, kf, vf, causal=causal, window=window,
                                 block_q=min(block_q, s),
                                 block_k=min(block_k, s),
                                 interpret=interpret)
    return out.reshape(b, h, s, d)


# ---------------------------------------------------------------------------
# sorted segment reduce (TOTEM message reduction)
# ---------------------------------------------------------------------------

def segment_reduce_op(msgs: jax.Array, seg_ids: np.ndarray,
                      num_segments: int, *, combine: str = "sum",
                      block_e: int = 1024, max_span: int = 4096,
                      interpret: bool | None = None) -> jax.Array:
    """Two-phase sorted segment reduce.

    ``seg_ids`` must be a *static* (numpy, sorted ascending) id array —
    it is preprocessing output in the engine (partition.py sorts edges by
    destination).  Falls back to plain ``jax.ops.segment_*`` when any
    block's segment-id span exceeds ``max_span`` (sparse/gappy data).
    """
    from repro.kernels import segment_reduce as _seg

    if interpret is None:
        interpret = _interpret_default()
    seg_ids = np.asarray(seg_ids)
    e = len(seg_ids)
    assert np.all(np.diff(seg_ids) >= 0), "seg_ids must be sorted"
    ident = 0.0 if combine == "sum" else np.inf

    pad = (-e) % block_e
    ids_p = np.concatenate([seg_ids,
                            np.full(pad, num_segments, seg_ids.dtype)])
    nb = len(ids_p) // block_e
    blocks = ids_p.reshape(nb, block_e)
    base = blocks[:, 0].astype(np.int32)                  # per-block min id
    span = int((blocks.max(axis=1) - base).max()) + 1
    if span > max_span:
        op = (jax.ops.segment_sum if combine == "sum"
              else jax.ops.segment_min)
        return op(msgs, jnp.asarray(seg_ids), num_segments=num_segments)

    span = max(8, -(-span // 8) * 8)
    local = (blocks - base[:, None]).astype(np.int32).reshape(-1)
    msgs_p = jnp.concatenate(
        [msgs.astype(jnp.float32),
         jnp.full((pad,), ident, jnp.float32)])
    partials = _seg.segment_reduce_blocks(
        msgs_p, jnp.asarray(local), span=span, block_e=block_e,
        combine=combine, interpret=interpret)            # [nb, span]

    # phase 2: merge block partials (blocks may share boundary segments)
    out_ids = (base[:, None] + np.arange(span)[None]).reshape(-1)
    out_ids = np.minimum(out_ids, num_segments)          # pad sink
    op = jax.ops.segment_sum if combine == "sum" else jax.ops.segment_min
    final = op(partials.reshape(-1), jnp.asarray(out_ids),
               num_segments=num_segments + 1)
    return final[:num_segments]
