"""Sorted segment-reduce Pallas kernel — TOTEM's message reduction (§3.4).

The BSP engine's hot op is the reduction of edge messages into (local vertex
| outbox slot) segments.  Edges are pre-sorted by destination (partition.py
does this at load, the paper's §4.3.1 ordering), so each block of ``be``
messages touches a *contiguous span* of segment ids.  That makes a
TPU-native two-phase reduction possible:

  phase 1 (this kernel): per block, build the one-hot matrix of local
  segment offsets and contract it against the messages on the **MXU**
  (``onehot.T @ msgs``) — the gather/scatter-free formulation of a segment
  sum; ``min`` combines use a masked VPU reduction.  Output: per-block
  partials ``[n_blocks, span]`` + the block's base segment id.

  phase 2 (ops.py, plain jnp): a tiny segment-sum over n_blocks·span
  partials merges blocks that share a boundary segment.

``span`` must bound (max segment id − min segment id + 1) within any block;
ops.py measures it during preprocessing and falls back to plain
``jax.ops.segment_sum`` when the data is too sparse for the span bound
(adversarial gaps) — the engine's correctness never depends on the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seg_sum_kernel(msgs_ref, local_ref, o_ref, *, span: int):
    msgs = msgs_ref[...]                          # [be]
    local = local_ref[...]                        # [be] offsets in [0, span)
    onehot = (local[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, span), 1)
              ).astype(jnp.float32)               # [be, span]
    # MXU contraction: segment partials in one matmul
    o_ref[...] = jax.lax.dot_general(
        msgs.astype(jnp.float32)[None, :], onehot,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _seg_min_kernel(msgs_ref, local_ref, o_ref, *, span: int):
    msgs = msgs_ref[...]
    local = local_ref[...]
    hit = (local[:, None] ==
           jax.lax.broadcasted_iota(jnp.int32, (1, span), 1))
    vals = jnp.where(hit, msgs.astype(jnp.float32)[:, None], jnp.inf)
    o_ref[...] = jnp.min(vals, axis=0)[None]


@functools.partial(jax.jit,
                   static_argnames=("span", "block_e", "combine",
                                    "interpret"))
def segment_reduce_blocks(msgs: jax.Array, local: jax.Array, *, span: int,
                          block_e: int = 1024, combine: str = "sum",
                          interpret: bool = False) -> jax.Array:
    """Phase-1 partials.  msgs, local: [E] (E % block_e == 0; ``local`` is
    segment id minus the block's base id).  Returns [E/block_e, span]."""
    e = msgs.shape[0]
    assert e % block_e == 0
    grid = (e // block_e,)
    kernel = functools.partial(
        _seg_sum_kernel if combine == "sum" else _seg_min_kernel, span=span)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_e,), lambda i: (i,)),
                  pl.BlockSpec((block_e,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, span), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e // block_e, span), jnp.float32),
        interpret=interpret,
    )(msgs, local)
