"""Fused BSP superstep compute phase — gather + edge message + segment
reduce in one Pallas kernel (paper §3.4, §4.3.1).

The reference compute phase is three HBM-bound passes: gather per-edge source
state (``take_along_axis``), materialize the ``[Pl, e_max]`` message array,
then scatter-reduce it over extended destination ids.  Edges are sorted by
destination at partition time, so — exactly as in ``segment_reduce.py`` — a
block of ``be`` consecutive edges reduces into a contiguous ``span`` of
segment ids.  This kernel runs the whole chain per (partition, edge-block)
grid cell without ever leaving VMEM:

  1. **gather** — the partition's ``[K, v_pad]`` stacked vertex state is
     VMEM-resident; per-edge source values are extracted with a chunked
     masked-max one-hot (``where(src == iota, state, -inf)`` + max).  A
     select/reduce rather than an MXU contraction because graph state
     legitimately contains ``+inf`` (BFS/SSSP/CC/BC distances), and
     ``0 * inf = nan`` would poison a multiply-accumulate gather.  State must
     not contain ``-inf`` (no algorithm uses it).
  2. **edge message** — the algorithm's elementwise ``edge_msg`` function is
     inlined on the gathered ``[be, K]`` values (plus optional edge weight
     and per-partition scalars); padding edges are masked to the combine
     identity.
  3. **reduce** — messages contract against the block's one-hot local-offset
     matrix on the **MXU** (``sum``) or a masked VPU min (``min``), yielding
     ``[span]`` partials per block.

The ``[be]`` messages exist only between steps 2 and 3 in VMEM; the kernel's
HBM output is the ``[Q, Pl, nb, span]`` partials array (merged by a tiny
static segment reduce in ops.py — phase 2 of the two-phase scheme).

**Query-batch axis**: vertex state and per-partition scalars carry a leading
``Q`` axis (``vstate[Q, Pl, K, v_pad]``, ``scal[Q, Pl, S]``) and the grid is
``(Q, Pl, nb)`` with the batch outermost.  The edge topology
(``src``/``local``/``mask``/``weight``) stays ``[Pl, e_pad]`` — its block
index maps ignore the query coordinate, so a batch of Q concurrent
traversals reuses one copy of the graph structure; only the message values
grow with Q.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_state(vstate_ref, src, *, gather_chunk: int):
    """Per-edge source-state gather from the VMEM state block.

    vstate_ref: [1, 1, K, v_pad] ref (one query's slice of one partition);
    src: [be] int32.  Returns [be, K] f32.  Chunked over v_pad so the
    one-hot select never materializes a full [be, v_pad] matrix in VMEM.
    """
    k = vstate_ref.shape[2]
    v_pad = vstate_ref.shape[3]
    be = src.shape[0]

    def body(c, acc):
        off = c * gather_chunk
        chunk = vstate_ref[0, 0, :, pl.ds(off, gather_chunk)]   # [K, chunk]
        hit = (src[:, None] == off +
               jax.lax.broadcasted_iota(jnp.int32, (1, gather_chunk), 1))
        vals = jnp.where(hit[:, None, :], chunk[None, :, :], -jnp.inf)
        return jnp.maximum(acc, jnp.max(vals, axis=2))

    init = jnp.full((be, k), -jnp.inf, jnp.float32)
    return jax.lax.fori_loop(0, v_pad // gather_chunk, body, init)


def _fused_kernel(scal_ref, vstate_ref, src_ref, local_ref, mask_ref, *rest,
                  msg_fn, combine: str, span: int, gather_chunk: int,
                  n_consts: int, has_weight: bool):
    if has_weight:
        weight_ref, o_ref = rest
    else:
        weight_ref, o_ref = None, rest[0]

    src = src_ref[0]                                     # [be] int32
    gathered = _gather_state(vstate_ref, src, gather_chunk=gather_chunk)
    vals = tuple(gathered[:, i] for i in range(gathered.shape[1]))
    step = scal_ref[0, 0, 0]
    consts = tuple(scal_ref[0, 0, 1 + i] for i in range(n_consts))
    weight = weight_ref[0] if has_weight else None

    msgs = msg_fn(vals, weight, (step,) + consts).astype(jnp.float32)
    ident = 0.0 if combine == "sum" else jnp.inf
    msgs = jnp.where(mask_ref[0] > 0, msgs, ident)       # padding → identity

    local = local_ref[0]                                 # [be] in [0, span)
    hit = (local[:, None] ==
           jax.lax.broadcasted_iota(jnp.int32, (1, span), 1))
    if combine == "sum":
        onehot = hit.astype(jnp.float32)                 # [be, span]
        o_ref[...] = jax.lax.dot_general(
            msgs[None, :], onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[None, None]
    else:
        picked = jnp.where(hit, msgs[:, None], jnp.inf)
        o_ref[...] = jnp.min(picked, axis=0)[None, None, None]


@functools.partial(jax.jit,
                   static_argnames=("msg_fn", "combine", "span", "block_e",
                                    "gather_chunk", "interpret"))
def fused_superstep_blocks(vstate: jax.Array, scal: jax.Array,
                           src: jax.Array, local: jax.Array,
                           mask: jax.Array, weight, *, msg_fn,
                           combine: str = "sum", span: int,
                           block_e: int = 1024, gather_chunk: int = 256,
                           interpret: bool = False) -> jax.Array:
    """Phase-1 fused partials.

    vstate: [Q, Pl, K, v_pad] f32 (v_pad % gather_chunk == 0); scal:
    [Q, Pl, S] f32 with scal[..., 0] = superstep and scal[..., 1:] per-query
    per-partition consts; src/local/mask (int32) and weight (f32 or None):
    [Pl, e_pad] with e_pad % block_e == 0 — shared across the query batch.
    ``msg_fn(vals_tuple, weight, scal_tuple) -> [be]`` must be
    elementwise/broadcast-safe.  Returns [Q, Pl, e_pad/block_e, span].
    """
    q, pl_count, _, v_pad = vstate.shape
    e_pad = src.shape[1]
    assert e_pad % block_e == 0 and v_pad % gather_chunk == 0
    nb = e_pad // block_e
    n_scal = scal.shape[2]
    has_weight = weight is not None

    kernel = functools.partial(
        _fused_kernel, msg_fn=msg_fn, combine=combine, span=span,
        gather_chunk=gather_chunk, n_consts=n_scal - 1,
        has_weight=has_weight)

    # Topology blocks ignore the query coordinate: one copy serves all Q.
    edge_spec = pl.BlockSpec((1, block_e), lambda s, p, b: (p, b))
    in_specs = [
        pl.BlockSpec((1, 1, n_scal), lambda s, p, b: (s, p, 0)),
        pl.BlockSpec((1, 1, vstate.shape[2], v_pad),
                     lambda s, p, b: (s, p, 0, 0)),
        edge_spec, edge_spec, edge_spec,
    ]
    args = [scal, vstate, src, local, mask]
    if has_weight:
        in_specs.append(edge_spec)
        args.append(weight)

    return pl.pallas_call(
        kernel,
        grid=(q, pl_count, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, 1, span), lambda s, p, b: (s, p, b, 0)),
        out_shape=jax.ShapeDtypeStruct((q, pl_count, nb, span), jnp.float32),
        interpret=interpret,
    )(*args)
