"""Source-side outbox aggregation Pallas kernel (paper §3.4, §4.3, Fig. 6).

The distributed hybrid engine routes every inter-partition edge through the
outbox-slot segment space of ``partition.py``: one slot per unique
(source-partition, remote-vertex) pair, so aggregation-β (the paper's §3.4
argument) is structural.  This kernel performs the whole boundary leg of the
compute phase in one pass per edge block, entirely in VMEM:

  1. **gather** — the shard's per-vertex message vector ``x`` (the
     ``EdgeMessage`` already evaluated once per vertex with the ⊗-identity
     weight) is VMEM-resident; per-edge source values come from a chunked
     masked-max one-hot select (graph state legitimately contains ``+inf``,
     so an MXU gather would produce ``0·inf = nan``; state never holds
     ``-inf`` — same contract as ``fused_superstep``).
  2. **⊗ weight** — the semiring's weight application is inlined:
     ``add`` (min_plus relaxation) or ``mul`` (weighted plus_times);
     weightless programs skip it.
  3. **reduce** — boundary edges are pre-sorted by flat outbox slot id, so
     a block of ``be`` edges reduces into a contiguous ``span`` of slots:
     one-hot MXU contraction for ``sum``, masked VPU min for ``min``.

The per-edge boundary messages never exist in HBM — the ``all_to_all``
exchange afterwards moves ``β_with_reduction·|E|`` aggregated slot values
instead of per-edge messages.  Slot ids/bases arrive as *operands* (not
trace constants): under ``shard_map`` every shard carries its own static
maps, stacked on the mesh axis.

The message vector carries a leading **query-batch axis**: ``x[Q, x_pad]``
→ ``[Q, nb, span]`` partials over a ``(Q, nb)`` grid.  The boundary maps
(``src``/``local``/``mask``/``weight``) are shared across the batch — a
batch of Q concurrent queries aggregates Q outboxes against one copy of
the slot topology.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_x(x_ref, src, *, gather_chunk: int):
    """Per-edge gather from the VMEM-resident message vector.

    x_ref: [1, x_pad] ref (one query's row; x_pad % gather_chunk == 0);
    src: [be] int32.  Masked-max one-hot select, chunked so the [be, chunk]
    hit matrix never grows to [be, x_pad].
    """
    x_pad = x_ref.shape[1]
    be = src.shape[0]

    def body(c, acc):
        off = c * gather_chunk
        chunk = x_ref[0, pl.ds(off, gather_chunk)]           # [chunk]
        hit = (src[:, None] == off +
               jax.lax.broadcasted_iota(jnp.int32, (1, gather_chunk), 1))
        vals = jnp.where(hit, chunk[None, :], -jnp.inf)
        return jnp.maximum(acc, jnp.max(vals, axis=1))

    init = jnp.full((be,), -jnp.inf, jnp.float32)
    return jax.lax.fori_loop(0, x_pad // gather_chunk, body, init)


def _outbox_kernel(x_ref, src_ref, local_ref, mask_ref, *rest,
                   combine: str, weight_op, span: int, gather_chunk: int):
    if weight_op is not None:
        w_ref, o_ref = rest
    else:
        w_ref, o_ref = None, rest[0]

    src = src_ref[...]                                       # [be]
    msgs = _gather_x(x_ref, src, gather_chunk=gather_chunk)
    if weight_op == "add":
        msgs = msgs + w_ref[...]
    elif weight_op == "mul":
        msgs = msgs * w_ref[...]
    ident = 0.0 if combine == "sum" else jnp.inf
    msgs = jnp.where(mask_ref[...] > 0, msgs, ident)

    local = local_ref[...]                                   # [be] in [0,span)
    hit = (local[:, None] ==
           jax.lax.broadcasted_iota(jnp.int32, (1, span), 1))
    if combine == "sum":
        o_ref[...] = jax.lax.dot_general(
            msgs[None, :], hit.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[None]
    else:
        picked = jnp.where(hit, msgs[:, None], jnp.inf)
        o_ref[...] = jnp.min(picked, axis=0)[None, None]


@functools.partial(jax.jit,
                   static_argnames=("combine", "weight_op", "span", "block_e",
                                    "gather_chunk", "interpret"))
def outbox_reduce_blocks(x: jax.Array, src: jax.Array, local: jax.Array,
                         mask: jax.Array, weight, *, combine: str,
                         weight_op=None, span: int, block_e: int = 256,
                         gather_chunk: int = 256,
                         interpret: bool = False) -> jax.Array:
    """Phase-1 outbox partials.

    x: [Q, x_pad] f32 (x_pad % gather_chunk == 0); src/local/mask (int32)
    and weight (f32 or None): [e_pad] with e_pad % block_e == 0 — shared
    across the query batch.  Returns [Q, e_pad/block_e, span] per-block
    slot partials (phase 2 in ops.py merges blocks sharing a boundary
    slot).
    """
    e_pad = src.shape[0]
    q = x.shape[0]
    assert x.ndim == 2, "ops.outbox_reduce_op adds the query-batch axis"
    assert e_pad % block_e == 0 and x.shape[1] % gather_chunk == 0
    nb = e_pad // block_e

    kernel = functools.partial(_outbox_kernel, combine=combine,
                               weight_op=weight_op, span=span,
                               gather_chunk=gather_chunk)
    # Boundary-map blocks ignore the query coordinate: one copy serves all Q.
    edge_spec = pl.BlockSpec((block_e,), lambda s, b: (b,))
    in_specs = [pl.BlockSpec((1, x.shape[1]), lambda s, b: (s, 0)),
                edge_spec, edge_spec, edge_spec]
    args = [x, src, local, mask]
    if weight_op is not None:
        in_specs.append(edge_spec)
        args.append(weight)

    return pl.pallas_call(
        kernel,
        grid=(q, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, span), lambda s, b: (s, b, 0)),
        out_shape=jax.ShapeDtypeStruct((q, nb, span), jnp.float32),
        interpret=interpret,
    )(*args)
