"""Dense-block SpMV Pallas kernel — the MXU path of the hybrid engine.

TOTEM's insight is to hand each part of a heterogeneous workload to the
processing element it fits best (paper §6.2).  On TPU the "CPU-like" element
for the high-degree vertex block is the **MXU**: the adjacency sub-matrix
among the top-degree vertices of a scale-free graph is dense enough that
SpMV-as-GEMM beats gather-based SpMV (see
``perf_model.mxu_crossover_density``).  The paper's cache-resident "visited"
bitmap (§6.3.2) maps to the VMEM residency of the value slice ``x``: the
x-block is re-used across all output tiles of a row stripe.

Computes ``y[M, N] = x[M, K] @ a[K, N]`` where ``a`` is the (bf16) dense
adjacency block of the high-degree partition, ``x`` carries the per-vertex
values (rank / frontier levels / multi-source batch on the M axis).

Grid: ``(N/bn, K/bk)`` — the contraction (k) axis is innermost so the output
tile stays resident in VMEM while partial products accumulate (revolving
accumulator), and Pallas grid pipelining double-buffers the HBM→VMEM streams
of ``a`` — the TPU analogue of the paper's mapped-memory streaming (§8).
Tiles are 128-aligned for the 128×128 systolic array.

``dense_spmv_minplus`` is the tropical (min, +) twin for the traversal
algorithms (BFS/SSSP/CC): ``y[m, n] = min_k x[m, k] + a[k, n]`` with the same
grid/tiling, except the reduction runs on the VPU (the MXU only contracts
(+, ×)) — the dense block still wins on locality: ``a``'s tiles stream
HBM→VMEM once and ``x`` stays resident, vs. a random gather per edge.
Non-edges hold +inf, the ⊕-identity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_spmv_kernel(x_ref, a_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU matmul with f32 accumulation (bf16 inputs are the target dtype).
    o_ref[...] += jnp.dot(x_ref[...], a_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def dense_spmv(x: jax.Array, a: jax.Array, *, block_n: int = 256,
               block_k: int = 256, interpret: bool = False) -> jax.Array:
    """``y = x @ a`` with explicit VMEM tiling.

    x: [M, K] (f32 or bf16), a: [K, N] (bf16 target). M is the value-channel
    axis (1 for plain SpMV, padded to 8 sublanes by ops.py).
    """
    m, k = x.shape
    k2, n = a.shape
    assert k == k2, (x.shape, a.shape)
    assert n % block_n == 0 and k % block_k == 0, (
        "ops.dense_spmv_op pads to block multiples")
    grid = (n // block_n, k // block_k)
    return pl.pallas_call(
        _dense_spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda j, kk: (0, kk)),
            pl.BlockSpec((block_k, block_n), lambda j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, a)


def _dense_minplus_kernel(x_ref, a_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    # VPU min-reduction over the contraction tile; the [m, bk, bn] candidate
    # cube stays in registers/VMEM for the small m this path uses.
    cand = jnp.min(x_ref[...][:, :, None] + a_ref[...][None, :, :], axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], cand)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def dense_spmv_minplus(x: jax.Array, a: jax.Array, *, block_n: int = 256,
                       block_k: int = 256,
                       interpret: bool = False) -> jax.Array:
    """``y[m, n] = min_k x[m, k] + a[k, n]`` with explicit VMEM tiling.

    Same contract as :func:`dense_spmv` (ops.py pads to block multiples);
    padding entries of ``x``/``a`` must hold +inf.
    """
    m, k = x.shape
    k2, n = a.shape
    assert k == k2, (x.shape, a.shape)
    assert n % block_n == 0 and k % block_k == 0, (
        "ops.dense_spmv_minplus_op pads to block multiples")
    grid = (n // block_n, k // block_k)
    return pl.pallas_call(
        _dense_minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda j, kk: (0, kk)),
            pl.BlockSpec((block_k, block_n), lambda j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, a)
