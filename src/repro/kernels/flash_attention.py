"""Flash-attention Pallas kernel (LM-side hot-spot, beyond-paper).

Online-softmax attention with VMEM-resident running (max, denom, acc) state,
KV blocks streamed HBM→VMEM by the grid pipeline.  Needed so the 32k-token
prefill shapes fit: naive attention materializes S² scores (32k² × bf16 =
2 GiB per head) while this kernel keeps O(block²) in VMEM.

Supports causal masking and an optional sliding window (``window > 0``),
which covers both gemma3's 5:1 local:global pattern and full causal decode.

Grid: ``(batch·heads, S/bq, S/bk)`` — the kv axis (j) is innermost so the
accumulator tile stays in VMEM across the kv sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, num_k_blocks: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                     # [bq, d]
    k = k_ref[0]                                     # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # [bq, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                           # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                  # [bq, 1]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q, k, v: [BH, S, D] (kv already head-repeated for GQA). Returns [BH, S, D]."""
    bh, s, d = q.shape
    assert k.shape == v.shape == (bh, s, d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
        ],
        interpret=interpret,
    )(q, k, v)
