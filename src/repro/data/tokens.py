"""Deterministic, seekable synthetic token pipeline.

Statelessness is the fault-tolerance property: ``batch_at(step)`` is a pure
function of (seed, step), so a restarted run resumes from a checkpointed
step with byte-identical data — no iterator state to persist, and elastic
re-sharding just re-slices the same global batch.  The synthetic
distribution is Zipfian (vocabulary skew), matching the degree-skew theme of
the paper and exercising the same heavy-hitter code paths (embedding rows,
MoE experts) that uniform tokens would miss.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig
from repro.models.api import ShapeSpec


@dataclasses.dataclass(frozen=True)
class TokenStream:
    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    zipf_a: float = 1.2

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = self._rng(step)
        # Zipf-distributed tokens clipped to the vocabulary.
        toks = rng.zipf(self.zipf_a, size=(self.batch, self.seq + 1))
        toks = np.minimum(toks - 1, self.cfg.vocab - 1).astype(np.int32)
        out = {"tokens": jnp.asarray(toks)}
        if self.cfg.frontend == "vision":
            out["patches"] = jnp.asarray(
                rng.normal(size=(self.batch, self.cfg.frontend_len,
                                 self.cfg.d_model)) * 0.02, jnp.float32)
        if self.cfg.enc_dec:
            out["frames"] = jnp.asarray(
                rng.normal(size=(self.batch, min(self.seq, 4096),
                                 self.cfg.d_model)) * 0.02, jnp.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
