from repro.data.tokens import TokenStream
from repro.data.graphs import load_workload

__all__ = ["TokenStream", "load_workload"]
