"""Graph workload loading (paper Table 2 stand-ins)."""
from __future__ import annotations

from repro.core.graph import CSRGraph, rmat, uniform
from repro.configs.totem_rmat import GraphWorkload


def load_workload(w: GraphWorkload, seed: int = 1,
                  weighted: bool = False) -> CSRGraph:
    if w.kind == "rmat":
        g = rmat(w.scale, w.edge_factor, seed=seed)
    elif w.kind == "uniform":
        g = uniform(w.scale, w.edge_factor, seed=seed)
    else:
        raise ValueError(w.kind)
    return g.with_uniform_weights(seed=seed) if weighted else g
