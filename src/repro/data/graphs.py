"""Graph workload loading (paper Table 2 stand-ins).

Determinism contract: every stochastic choice is keyed off the caller's
explicit ``seed`` — the graph topology and the edge weights draw from
*separate* deterministic streams derived from it, so a workload loaded with
the same ``(workload, seed)`` pair is bit-identical across processes and
machines.  CI's bench-regression gate and the hybrid/fused parity tests
depend on this: cells are matched across runs by workload key, so the
underlying graphs must be reproducible.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import CSRGraph, rmat, uniform
from repro.configs.totem_rmat import GraphWorkload

# Stream labels mixed into the derived seeds so topology and weights never
# share a generator stream (adding weights must not perturb the topology).
_TOPOLOGY_STREAM = 0x70
_WEIGHT_STREAM = 0x7E


def derive_seed(seed: int, stream: int) -> int:
    """Deterministically derive an independent integer seed for a stream."""
    ss = np.random.SeedSequence([int(seed), int(stream)])
    return int(ss.generate_state(1, dtype=np.uint32)[0])


def load_workload(w: GraphWorkload, seed: int = 1,
                  weighted: bool = False) -> CSRGraph:
    """Materialize a workload; identical output for identical (w, seed)."""
    topo_seed = derive_seed(seed, _TOPOLOGY_STREAM)
    if w.kind == "rmat":
        g = rmat(w.scale, w.edge_factor, seed=topo_seed)
    elif w.kind == "uniform":
        g = uniform(w.scale, w.edge_factor, seed=topo_seed)
    else:
        raise ValueError(w.kind)
    if weighted:
        g = g.with_uniform_weights(seed=derive_seed(seed, _WEIGHT_STREAM))
    return g
