"""Graph workload loading (paper Table 2 stand-ins).

Determinism contract: every stochastic choice is keyed off the caller's
explicit ``seed`` — the graph topology and the edge weights draw from
*separate* deterministic streams derived from it, so a workload loaded with
the same ``(workload, seed)`` pair is bit-identical across processes and
machines.  CI's bench-regression gate and the hybrid/fused parity tests
depend on this: cells are matched across runs by workload key, so the
underlying graphs must be reproducible.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.graph import (CSRGraph, EdgeLedger, MutationBatch, rmat,
                              uniform)
from repro.configs.totem_rmat import GraphWorkload

# Stream labels mixed into the derived seeds so topology and weights never
# share a generator stream (adding weights must not perturb the topology).
_TOPOLOGY_STREAM = 0x70
_WEIGHT_STREAM = 0x7E
_MUTATION_STREAM = 0x4D


def derive_seed(seed: int, stream: int) -> int:
    """Deterministically derive an independent integer seed for a stream."""
    ss = np.random.SeedSequence([int(seed), int(stream)])
    return int(ss.generate_state(1, dtype=np.uint32)[0])


def load_workload(w: GraphWorkload, seed: int = 1,
                  weighted: bool = False) -> CSRGraph:
    """Materialize a workload; identical output for identical (w, seed)."""
    topo_seed = derive_seed(seed, _TOPOLOGY_STREAM)
    if w.kind == "rmat":
        g = rmat(w.scale, w.edge_factor, seed=topo_seed)
    elif w.kind == "uniform":
        g = uniform(w.scale, w.edge_factor, seed=topo_seed)
    else:
        raise ValueError(w.kind)
    if weighted:
        g = g.with_uniform_weights(seed=derive_seed(seed, _WEIGHT_STREAM))
    return g


def edge_stream(g: CSRGraph, num_batches: int, batch_size: int,
                churn: float = 0.7, skew: float = 0.5,
                symmetric: bool = False, seed: int = 1
                ) -> List[MutationBatch]:
    """Deterministic timestamped edge-mutation stream over ``g``.

    Models the evolving social-network regime the paper's workloads come
    from: each batch mixes ``churn``·``batch_size`` inserts with the
    remaining deletes.  Insert endpoints are degree-preferential —
    probability ∝ ``(deg + 1)^skew`` (``skew=0`` uniform; higher values
    concentrate churn on hubs, drifting the degree ranking the hybrid split
    was planned against — exactly what ``perf_model.should_resplit``
    watches).  Deletes sample *live* instances from the evolving edge
    multiset (replaying batch ``i`` requires batches ``0..i-1``), so every
    delete is valid by construction.  Weighted graphs get insert weights
    from the paper's uniform(1, 64) distribution.  ``symmetric=True`` emits
    each insert/delete in both orientations (the CC contract).

    Determinism: all randomness derives from ``(seed, _MUTATION_STREAM)``;
    identical inputs yield identical streams across processes — the same
    contract the workload loader gives CI's bench-matching.
    """
    rng = np.random.default_rng(derive_seed(seed, _MUTATION_STREAM))
    ledger = EdgeLedger(g)
    deg = g.out_degrees().astype(np.float64)
    p = (deg + 1.0) ** skew
    p /= p.sum()
    n = g.num_vertices
    weighted = g.weights is not None
    batches = []
    for _ in range(num_batches):
        n_ins = int(round(batch_size * churn))
        n_del = batch_size - n_ins
        src = rng.choice(n, size=n_ins, p=p)
        dst = rng.choice(n, size=n_ins, p=p)
        d_src, d_dst = ledger.sample_alive(rng, n_del)
        d_loop = np.empty(0, dtype=np.int64)
        if symmetric:
            # canonicalize each sampled pair to (lo, hi) — the mirror is
            # emitted below — deduplicating pairs whose two orientations
            # were both sampled (one symmetric delete covers both), and
            # setting self-loops aside (single instance, no mirror to pop)
            lo = np.minimum(d_src, d_dst)
            hi = np.maximum(d_src, d_dst)
            pairs = np.unique(np.stack([lo, hi], axis=1), axis=0)
            loops = pairs[:, 0] == pairs[:, 1]
            d_loop = pairs[loops, 0]
            d_src, d_dst = pairs[~loops, 0], pairs[~loops, 1]
        s = np.concatenate([src, d_src])
        d = np.concatenate([dst, d_dst])
        ins = np.concatenate([np.ones(n_ins, bool),
                              np.zeros(len(d_src), bool)])
        w = None
        if weighted:
            w = np.ones(len(s), dtype=np.float32)
            w[:n_ins] = rng.uniform(1.0, 64.0, size=n_ins)
        if symmetric:
            s, d = np.concatenate([s, d, d_loop]), np.concatenate([d, s,
                                                                   d_loop])
            ins = np.concatenate([ins, ins, np.zeros(len(d_loop), bool)])
            if w is not None:
                w = np.concatenate([w, w, np.ones(len(d_loop), np.float32)])
        batch = MutationBatch(s, d, ins, w)
        ledger.apply(batch)    # keep later delete samples valid
        batches.append(batch)
    return batches
