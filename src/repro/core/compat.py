"""Version-compat shims for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and its ``check_rep`` kwarg was renamed ``check_vma``).  The installed JAX in
a given container may be on either side of that move; everything in this repo
goes through this shim so the engine code stays on the new spelling.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` if present, else the experimental module.

    The legacy API spells ``check_vma`` as ``check_rep``; both toggles disable
    the same replication/varying-manual-axes check, so we forward the flag.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
