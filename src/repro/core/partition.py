"""Graph partitioning for hybrid/multi-shard processing (paper §4.3, §6).

Implements the paper's partition data layout in a JAX-friendly, fixed-shape
form:

- Each vertex is assigned to exactly one partition; vertex ids are re-labelled
  into a per-partition local space (paper Fig. 6).
- Per-partition CSR edges are flattened to edge-parallel ``(src_local,
  dst_ext)`` pairs.  ``dst_ext`` is an *extended* destination index: local
  destinations map to ``[0, v_max)``; boundary (remote) destinations map to an
  **outbox slot** ``v_max + 1 + peer * o_max + slot`` — exactly the paper's
  trick of storing the outbox index in the edge array (§4.3.1).
- The outbox has one slot per *unique* (source-partition, remote-vertex) pair:
  source-side message reduction (§3.4) therefore happens for free inside a
  single ``segment_min`` / ``segment_sum`` over ``dst_ext``.
- Outboxes/inboxes are symmetric (paper Fig. 6): ``inbox_dst[p, q, s]`` is the
  local id on ``p`` of the vertex that receives ``outbox[q, p, s]``.

Partitioning strategies (paper §6): RAND, HIGH (high-degree vertices to
partition 0 — the "CPU" / dense-path analogue), LOW (low-degree to partition
0).  The strategy is O(|V| log |V|) via sorting, matching the paper's cost
analysis (§6.2).

All of this is numpy preprocessing; the returned arrays are handed to JAX.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.graph import CSRGraph

RAND = "rand"
HIGH = "high"
LOW = "low"
STRATEGIES = (RAND, HIGH, LOW)

# Bin edges for BlockMetadata.span_histogram / degree_skew (one shared tuple
# so the skew signal can't drift from the histogram buckets).
SPAN_HIST_BINS = (1, 129, 513, 1025, 2049, 4097, 1 << 30)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass
class VertexAssignment:
    """Vertex → (partition, local id) mapping plus the inverse."""

    num_parts: int
    part_of: np.ndarray     # [n] int32, partition of each global vertex
    local_id: np.ndarray    # [n] int32, local id of each global vertex
    l2g: List[np.ndarray]   # per-partition local → global

    @property
    def part_sizes(self) -> np.ndarray:
        return np.array([len(x) for x in self.l2g])


@dataclasses.dataclass
class EdgeArrays:
    """Fixed-shape per-partition edge-parallel arrays (stacked on axis 0)."""

    src: np.ndarray         # [P, e_max] int32 local source vertex
    dst_ext: np.ndarray     # [P, e_max] int32 extended destination index
    weight: Optional[np.ndarray]  # [P, e_max] float32 or None
    edge_mask: np.ndarray   # [P, e_max] bool (False for padding)
    outbox_dst: np.ndarray  # [P, P, o_max] int32 local id on the *peer*
    outbox_mask: np.ndarray  # [P, P, o_max] bool
    inbox_dst: np.ndarray   # [P, P, o_max] = outbox_dst.transpose(1, 0, 2)
    num_edges: np.ndarray   # [P] true edge counts
    # Original edge index of each slot (-1 padding): the dynamic layer's
    # tombstone locator (core/dynamic.py).  None for arrays built before
    # this field existed.
    edge_id: Optional[np.ndarray] = None  # [P, e_max] int64

    @property
    def e_max(self) -> int:
        return self.src.shape[1]

    @property
    def o_max(self) -> int:
        return self.outbox_dst.shape[2]


@dataclasses.dataclass
class PartitionedGraph:
    """A partitioned graph ready for the BSP engine."""

    num_parts: int
    num_vertices: int
    num_edges: int
    v_max: int                       # padded vertices per partition
    assignment: VertexAssignment
    fwd: EdgeArrays                  # out-edges (push direction)
    rev: Optional[EdgeArrays]        # in-edges (pull / BC backward)
    out_deg: np.ndarray              # [P, v_max] float32 true global out-degree
    vertex_mask: np.ndarray          # [P, v_max] bool
    # --- partition quality statistics (paper Fig. 4) ---
    alpha: np.ndarray                # [P] share of edges per partition
    beta_no_reduction: float         # boundary edges / |E|
    beta_with_reduction: float       # outbox slots / |E|  (paper §3.4)
    # The un-partitioned graph, kept for backends that re-derive their own
    # layout from it (the hybrid degree-split engine).  None for
    # PartitionedGraphs built before this field existed.
    source: Optional[CSRGraph] = None

    @property
    def seg_count(self) -> int:
        """Extended segment space: v_max locals + 1 sink + P*o_max outbox."""
        return self.v_max + 1 + self.num_parts * self.fwd.o_max

    def gather_global(self, per_part: np.ndarray) -> np.ndarray:
        """Collect a [P, v_max] per-partition state into global [n] order."""
        out = np.empty(self.num_vertices, dtype=per_part.dtype)
        for p, l2g in enumerate(self.assignment.l2g):
            out[l2g] = per_part[p, : len(l2g)]
        return out

    def scatter_global(self, global_vals: np.ndarray,
                       fill) -> np.ndarray:
        """Distribute a global [n] array into [P, v_max] partition layout."""
        out = np.full((self.num_parts, self.v_max), fill,
                      dtype=np.asarray(global_vals).dtype)
        for p, l2g in enumerate(self.assignment.l2g):
            out[p, : len(l2g)] = global_vals[l2g]
        return out

    def scatter_dirty(self, dirty_global: np.ndarray) -> np.ndarray:
        """Global [n] dirty-vertex mask (``DynamicGraph.dirty_since``) into
        [P, v_max] layout — the warm-start seeding helper
        (``BSPEngine.run_incremental``)."""
        return self.scatter_global(np.asarray(dirty_global, dtype=bool),
                                   False)


def assign_vertices(g: CSRGraph, num_parts: int, strategy: str = RAND,
                    cpu_edge_fraction: Optional[float] = None,
                    seed: int = 0) -> VertexAssignment:
    """Assign vertices to partitions (paper §6.2/§6.3.1).

    ``cpu_edge_fraction`` is the paper's α: the share of *edges* kept on
    partition 0 (the bottleneck / "CPU" partition).  The remaining edges are
    split evenly (by edge count) across partitions ``1..P-1``.  When ``None``,
    edges are split evenly across all partitions.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")
    n = g.num_vertices
    deg = g.out_degrees()
    rng = np.random.default_rng(seed)
    if strategy == RAND:
        order = rng.permutation(n)
    elif strategy == HIGH:
        # High-degree first → partition 0 (stable to keep determinism).
        order = np.argsort(-deg, kind="stable")
    else:  # LOW
        order = np.argsort(deg, kind="stable")

    cum = np.cumsum(deg[order])
    total = int(cum[-1]) if len(cum) else 0
    if cpu_edge_fraction is None:
        targets = [total * (p + 1) / num_parts for p in range(num_parts - 1)]
    else:
        rest = (1.0 - cpu_edge_fraction) / max(num_parts - 1, 1)
        fracs = [cpu_edge_fraction] + [rest] * (num_parts - 1)
        targets = list(np.cumsum(fracs)[:-1] * total)
    cuts = np.searchsorted(cum, targets, side="left")
    bounds = np.concatenate([[0], cuts, [n]]).astype(np.int64)

    part_of = np.empty(n, dtype=np.int32)
    local_id = np.empty(n, dtype=np.int32)
    l2g = []
    for p in range(num_parts):
        verts = order[bounds[p]: bounds[p + 1]]
        part_of[verts] = p
        local_id[verts] = np.arange(len(verts), dtype=np.int32)
        l2g.append(np.asarray(verts, dtype=np.int64))
    return VertexAssignment(num_parts, part_of, local_id, l2g)


def boundary_edges(ea: EdgeArrays, p: int, v_max: int):
    """One partition's boundary edges as (local src, flat outbox slot,
    weight-or-None), in ``dst_ext`` order (so flat slot ids ascend).

    The flat slot id is ``q * o_max + slot`` — the edge's position in the
    partition's ``[P, o_max]`` outbox — recovered from the extended
    destination index the edge arrays already carry (§4.3.1: the outbox
    index is stored in the edge array).  The distributed hybrid engine
    reduces boundary messages into exactly this segment space before the
    exchange (§3.4 source-side aggregation).
    """
    em = ea.edge_mask[p] & (ea.dst_ext[p] > v_max)
    src = ea.src[p][em]
    flat = ea.dst_ext[p][em] - (v_max + 1)
    w = ea.weight[p][em] if ea.weight is not None else None
    return src, flat, w


def _build_edge_arrays(g: CSRGraph, asg: VertexAssignment, v_max: int,
                       align: int, spare_outbox: int = 0) -> EdgeArrays:
    """Construct the edge-parallel arrays + outbox maps for one direction.

    ``spare_outbox`` reserves that many unassigned outbox slots per
    (partition, peer) pair — headroom the dynamic layer (core/dynamic.py)
    assigns to inserted boundary edges targeting previously-unmessaged
    remote vertices, without changing ``o_max`` (shape stability is the
    zero-retrace contract).
    """
    P = asg.num_parts
    src_g = g.edge_sources()
    dst_g = g.col
    sp = asg.part_of[src_g]       # partition of each edge's source
    dp = asg.part_of[dst_g]       # partition of each edge's destination

    # Unique remote destinations per (src_part, dst_part): the outbox slots.
    remote_sets: List[List[np.ndarray]] = [[None] * P for _ in range(P)]
    o_req = 0
    for p in range(P):
        for q in range(P):
            if p == q:
                remote_sets[p][q] = np.empty(0, dtype=np.int64)
                continue
            m = (sp == p) & (dp == q)
            uniq = np.unique(dst_g[m])
            remote_sets[p][q] = uniq
            o_req = max(o_req, len(uniq))
    o_max = max(_round_up(o_req + spare_outbox, align), align)

    e_req = int(np.bincount(sp, minlength=P).max()) if len(sp) else 0
    e_max = max(_round_up(e_req, align), align)

    src = np.zeros((P, e_max), dtype=np.int32)
    dst_ext = np.full((P, e_max), v_max, dtype=np.int32)  # default → sink
    weight = (np.zeros((P, e_max), dtype=np.float32)
              if g.weights is not None else None)
    edge_mask = np.zeros((P, e_max), dtype=bool)
    edge_id = np.full((P, e_max), -1, dtype=np.int64)
    outbox_dst = np.full((P, P, o_max), v_max, dtype=np.int32)  # pad → sink
    outbox_mask = np.zeros((P, P, o_max), dtype=bool)
    num_edges = np.zeros(P, dtype=np.int64)

    for p in range(P):
        em = sp == p
        e_ids = np.flatnonzero(em)
        e_src = asg.local_id[src_g[em]].astype(np.int32)
        e_dst_g = dst_g[em]
        e_dp = dp[em]
        ext = np.empty(len(e_src), dtype=np.int32)
        local = e_dp == p
        ext[local] = asg.local_id[e_dst_g[local]]
        for q in range(P):
            if q == p:
                continue
            mq = e_dp == q
            if not mq.any() and len(remote_sets[p][q]) == 0:
                continue
            uniq = remote_sets[p][q]          # sorted by *global* id
            # Order slots by the peer's local id (paper §4.3.4(i): inboxes
            # sorted by vertex id for prefetch/cache efficiency on scatter).
            loc = asg.local_id[uniq]
            by_local = np.argsort(loc, kind="stable")
            inv = np.empty_like(by_local)
            inv[by_local] = np.arange(len(by_local))
            # Slot of each remote edge destination within the (p,q) outbox.
            idx = np.searchsorted(uniq, e_dst_g[mq])
            ext[mq] = v_max + 1 + q * o_max + inv[idx].astype(np.int32)
            k = len(uniq)
            outbox_dst[p, q, :k] = loc[by_local]
            outbox_mask[p, q, :k] = True
        # Sort edges by extended destination: local edges first, then boundary
        # — the paper's locality ordering (§4.3.1), and it makes the segment
        # reduction access pattern monotonic.
        order = np.argsort(ext, kind="stable")
        k = len(e_src)
        src[p, :k] = e_src[order]
        dst_ext[p, :k] = ext[order]
        edge_mask[p, :k] = True
        edge_id[p, :k] = e_ids[order]
        if weight is not None:
            weight[p, :k] = g.weights[em][order]
        num_edges[p] = k

    return EdgeArrays(src=src, dst_ext=dst_ext, weight=weight,
                      edge_mask=edge_mask, outbox_dst=outbox_dst,
                      outbox_mask=outbox_mask,
                      inbox_dst=np.ascontiguousarray(
                          outbox_dst.transpose(1, 0, 2)),
                      num_edges=num_edges, edge_id=edge_id)


def partition(g: CSRGraph, num_parts: int, strategy: str = RAND,
              cpu_edge_fraction: Optional[float] = None, seed: int = 0,
              include_reverse: bool = False,
              align: int = 8, spare_outbox: int = 0) -> PartitionedGraph:
    """Partition ``g`` into ``num_parts`` fixed-shape partitions.

    ``spare_outbox`` reserves unassigned outbox slots per peer pair for the
    dynamic layer's in-place edge inserts (see core/dynamic.py)."""
    asg = assign_vertices(g, num_parts, strategy, cpu_edge_fraction, seed)
    v_max = max(_round_up(int(asg.part_sizes.max()), align), align)

    fwd = _build_edge_arrays(g, asg, v_max, align, spare_outbox)
    rev = (_build_edge_arrays(g.reverse(), asg, v_max, align, spare_outbox)
           if include_reverse else None)

    deg = g.out_degrees().astype(np.float32)
    out_deg = np.zeros((num_parts, v_max), dtype=np.float32)
    vertex_mask = np.zeros((num_parts, v_max), dtype=bool)
    for p, l2g in enumerate(asg.l2g):
        out_deg[p, : len(l2g)] = deg[l2g]
        vertex_mask[p, : len(l2g)] = True

    total_e = max(g.num_edges, 1)
    boundary = int((asg.part_of[g.edge_sources()] !=
                    asg.part_of[g.col]).sum())
    slots = int(fwd.outbox_mask.sum())
    return PartitionedGraph(
        num_parts=num_parts, num_vertices=g.num_vertices,
        num_edges=g.num_edges, v_max=v_max, assignment=asg, fwd=fwd, rev=rev,
        out_deg=out_deg, vertex_mask=vertex_mask,
        alpha=fwd.num_edges / total_e,
        beta_no_reduction=boundary / total_e,
        beta_with_reduction=slots / total_e,
        source=g,
    )


# ---------------------------------------------------------------------------
# Fused-superstep block metadata (kernels/fused_superstep.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BlockMetadata:
    """Static per-edge-block metadata for the fused superstep kernel.

    ``partition`` sorts each partition's edges by extended destination, so a
    block of ``block_e`` consecutive edges touches a contiguous span of
    segment ids.  This precomputes, per 128-aligned block: the base (minimum)
    segment id, each edge's local offset within the block's span, and the
    measured span itself — everything the one-hot MXU reduction needs to be
    gather/scatter-free.  Padding edges (``mask`` False) are assigned the
    preceding real edge's segment id so they never widen a block's span; the
    kernel masks their messages to the combine identity.
    """

    block_e: int
    span: int               # lane-aligned span bound the kernel compiles for
    span_req: int           # measured max over blocks (pre-alignment)
    base: np.ndarray        # [P, nb] int32: first segment id of each block
    local: np.ndarray       # [P, e_pad] int32: segment id − block base
    src: np.ndarray         # [P, e_pad] int32: src, zero-padded
    mask: np.ndarray        # [P, e_pad] int32: 1 for real edges
    weight: Optional[np.ndarray]  # [P, e_pad] f32 or None
    block_spans: np.ndarray  # [P, nb] int32: measured span of each block

    @property
    def num_blocks(self) -> int:
        return self.base.shape[1]

    @property
    def e_pad(self) -> int:
        return self.src.shape[1]

    def span_histogram(self, bins: Sequence[int] = SPAN_HIST_BINS
                       ) -> np.ndarray:
        """Per-partition histogram of block spans.

        The degree-skew signal behind the fused/reference decision: a
        partition whose high-degree (HIGH strategy) vertices concentrate many
        distinct destinations into single blocks shows mass in the top bins,
        predicting span-bound overflow before the kernel is ever compiled.
        """
        edges = np.asarray(bins)
        return np.stack([np.histogram(row, bins=edges)[0]
                         for row in self.block_spans])

    def fused_ok(self, max_span: int) -> bool:
        """True when every block fits the kernel's span bound."""
        return self.span <= max_span

    def degree_skew(self, min_span: int = 513) -> float:
        """Fraction of span-histogram mass at spans ≥ ``min_span``.

        The hybrid planner's skew signal: blocks whose destinations span a
        wide segment range come from high-degree vertices concentrating many
        distinct neighbours — the graphs where a top-K dense split pays.
        ``min_span`` must be one of ``SPAN_HIST_BINS``.
        """
        if min_span not in SPAN_HIST_BINS:
            raise ValueError(f"min_span must be a bin edge, got {min_span}")
        hist = self.span_histogram(SPAN_HIST_BINS)
        total = max(int(hist.sum()), 1)
        return float(hist[:, SPAN_HIST_BINS.index(min_span):].sum()) / total


def build_block_metadata(ea: EdgeArrays, *, block_e: int = 1024,
                         lane: int = 128) -> BlockMetadata:
    """Preprocess one direction's edge arrays for the fused kernel.

    Numpy-only (runs once at partition time); the returned arrays are static
    data the engine hands to JAX alongside ``src``/``dst_ext``.
    """
    if block_e % lane:
        raise ValueError(f"block_e ({block_e}) must be a multiple of {lane}")
    P, e_max = ea.src.shape
    e_pad = max(_round_up(e_max, block_e), block_e)

    # Fill padding slots with the last real segment id (rows are sorted by
    # dst_ext, so a forward max-accumulate over masked ids is a fill-forward);
    # an empty partition collapses to segment 0.
    masked = np.where(ea.edge_mask, ea.dst_ext, -1)
    filled = np.maximum.accumulate(masked, axis=1)
    filled = np.maximum(filled, 0)
    filled = np.pad(filled, ((0, 0), (0, e_pad - e_max)), mode="edge")

    nb = e_pad // block_e
    blocks = filled.reshape(P, nb, block_e)
    base = blocks[:, :, 0].astype(np.int32)
    block_spans = (blocks.max(axis=2) - base + 1).astype(np.int32)
    span_req = int(block_spans.max()) if block_spans.size else 1
    span = max(_round_up(span_req, lane), lane)
    local = (blocks - base[:, :, None]).reshape(P, e_pad).astype(np.int32)

    src = np.pad(ea.src, ((0, 0), (0, e_pad - e_max))).astype(np.int32)
    mask = np.pad(ea.edge_mask, ((0, 0), (0, e_pad - e_max))
                  ).astype(np.int32)
    weight = (np.pad(ea.weight, ((0, 0), (0, e_pad - e_max))
                     ).astype(np.float32) if ea.weight is not None else None)
    return BlockMetadata(block_e=block_e, span=span, span_req=span_req,
                         base=base, local=local, src=src, mask=mask,
                         weight=weight, block_spans=block_spans)


# ---------------------------------------------------------------------------
# Transposed (CSC-as-ELL) intra-partition layout: direction-optimized pull
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TransposedEll:
    """Per-partition transposed intra-edge layout for bottom-up traversal.

    The push arenas above are source-major (``src``/``dst_ext`` pairs, sorted
    by extended destination).  Direction-optimized supersteps additionally
    need the CSC view: for each *destination* row, its in-neighbour local
    source ids, packed ELL-style — ``col[p, v, k]`` is the k-th in-neighbour
    of local vertex ``v`` in partition ``p`` (sentinel ``v_max`` → the
    per-partition ⊕-identity sink column the engine appends to ``x``).

    The layout keeps the same clean-cut discipline the tier streamer relies
    on: rows *are* destinations, in ascending local id (destination-sorted by
    construction), grouped into ``lane``-aligned row blocks whose per-block
    metadata (``blk_kmax``/``blk_edges``) bounds each block's scan work —
    and since a row's slots never straddle a block boundary, every cut
    between row blocks is clean (no destination's reduction spans two
    blocks), so windowed execution combines pure ⊕-identities across cuts.

    Within a row, slots are ordered by in-neighbour *out-degree descending*
    (ties by local id): the bottom-up early exit terminates on the first
    frontier parent, and on scale-free graphs the high-degree neighbour is
    the likeliest to be reached already — the same ranking intuition as the
    hybrid degree split.

    ``deg_out``/``deg_bnd`` carry each local vertex's real total / boundary
    out-degree — the deterministic per-superstep ``edges_examined`` charges
    for the push direction and the always-push boundary leg.

    Only the *intra*-partition edges transpose: boundary edges keep their
    outbox-slot push path in both directions (the exchange is
    source-aggregated either way; see docs/traversal.md).
    """

    col: np.ndarray               # [P, v_max, kmax] int32 (sentinel = v_max)
    val: Optional[np.ndarray]     # [P, v_max, kmax] f32 ⊗ values, or None
    kreal: np.ndarray             # [P, v_max] int32 real in-slots per row
    deg_out: np.ndarray           # [P, v_max] int32 real out-degree
    deg_bnd: np.ndarray           # [P, v_max] int32 boundary out-degree
    kmax: int                     # shared in-degree bound (>= 1)
    lane: int                     # row-block alignment
    blk_kmax: np.ndarray          # [P, nb] max kreal per row block
    blk_edges: np.ndarray         # [P, nb] real intra edges per row block

    @property
    def num_blocks(self) -> int:
        return self.blk_kmax.shape[1]


def build_transposed_ell(ea: EdgeArrays, v_max: int, *,
                         lane: int = 128) -> TransposedEll:
    """Transpose one direction's intra-partition edges into ELL rows.

    Numpy preprocessing (runs once at bind time).  Tombstones/delta slots of
    a dynamic overlay are *not* reflected — the engine reconciles mutations
    into its own transposed arenas (hybrid) or keeps dynamic runs push-only
    (reference/fused); see core/bsp.py.
    """
    P, _ = ea.src.shape
    deg_out = np.zeros((P, v_max), dtype=np.int32)
    deg_bnd = np.zeros((P, v_max), dtype=np.int32)
    intra_edges = []            # per partition: (dst, src, w) intra arrays
    kmax = 1
    for p in range(P):
        em = ea.edge_mask[p]
        np.add.at(deg_out[p], ea.src[p][em], 1)
        bm = em & (ea.dst_ext[p] > v_max)
        np.add.at(deg_bnd[p], ea.src[p][bm], 1)
        im = em & (ea.dst_ext[p] < v_max)
        dst = ea.dst_ext[p][im]
        src = ea.src[p][im]
        w = ea.weight[p][im] if ea.weight is not None else None
        if len(dst):
            kmax = max(kmax, int(np.bincount(dst, minlength=1).max()))
        intra_edges.append((dst, src, w))

    col = np.full((P, v_max, kmax), v_max, dtype=np.int32)
    val = (np.zeros((P, v_max, kmax), dtype=np.float32)
           if ea.weight is not None else None)
    kreal = np.zeros((P, v_max), dtype=np.int32)
    for p, (dst, src, w) in enumerate(intra_edges):
        if not len(dst):
            continue
        # slot order: source out-degree descending, ties by (src, arrival)
        order = np.lexsort((np.arange(len(dst)), src,
                            -deg_out[p][src].astype(np.int64), dst))
        dst, src = dst[order], src[order]
        w = w[order] if w is not None else None
        counts = np.bincount(dst, minlength=v_max)
        slots = np.arange(len(dst)) - np.repeat(
            np.cumsum(counts) - counts, counts)[: len(dst)]
        # np.repeat over counts yields rows in ascending dst order — which
        # is exactly the sort order above, so slots align with (dst, src).
        col[p, dst, slots] = src
        if val is not None:
            val[p, dst, slots] = w
        kreal[p] = counts.astype(np.int32)

    v_pad = max(_round_up(v_max, lane), lane)
    nb = v_pad // lane
    kreal_pad = np.pad(kreal, ((0, 0), (0, v_pad - v_max)))
    blocks = kreal_pad.reshape(P, nb, lane)
    return TransposedEll(
        col=col, val=val, kreal=kreal, deg_out=deg_out, deg_bnd=deg_bnd,
        kmax=kmax, lane=lane,
        blk_kmax=blocks.max(axis=2).astype(np.int32),
        blk_edges=blocks.sum(axis=2).astype(np.int32))


def memory_footprint_bytes(pg: PartitionedGraph, state_bytes: int = 4,
                           vid_bytes: int = 4,
                           eid_bytes: int = 4,
                           dynamic=None, tier_plan=None) -> dict:
    """Per-partition memory footprint, the analogue of paper Table 5.

    Actual-size formula from §4.3.3:
    ``eid*|Vp| + vid*|Ep| (+ w*|Ep|) + (vid+s)*|Vi| + (vid+s)*|Vo|``.

    ``dynamic`` (a ``core.dynamic.DynamicGraph`` wrapping ``pg``, or any
    object with ``delta_slots``/``directions``/``weighted`` attributes) adds
    the resident delta-slot and tombstone buffers per direction — without it
    the serving driver's capacity planning under-reports a mutating graph's
    true residency.

    Each partition's record carries a per-tier split alongside ``total``:
    ``tier`` (``"hbm"`` or ``"host"``, from ``tier_plan`` — all-hbm without
    one), ``hbm`` and ``host`` byte subtotals with ``hbm + host == total``.
    A host-tier partition keeps its *graph* bytes — and its dynamic
    delta/tombstone overlay, which streams with the base blocks — in host
    DRAM; its vertex state and outbox/inbox slots stay device-resident
    (the exchange and scatter phases always run on device).  Capacity
    planning against device memory must therefore sum the ``hbm`` figures
    only (see :func:`memory_residency_bytes` and graph_serve's admission)
    — counting a flat ``total`` over-counts host-tier bytes against HBM.
    """
    P = pg.num_parts
    res = {}
    cold = set() if tier_plan is None else set(int(p)
                                               for p in tier_plan.cold)
    w_bytes = 4 if pg.fwd.weight is not None else 0
    for p in range(P):
        vp = int(pg.assignment.part_sizes[p])
        ep = int(pg.fwd.num_edges[p])
        vo = int(pg.fwd.outbox_mask[p].sum())          # remote vertices we msg
        vi = int(pg.fwd.outbox_mask[:, p].sum())       # local verts msg'd to
        res[p] = dict(
            graph=eid_bytes * vp + (vid_bytes + w_bytes) * ep,
            outbox=(vid_bytes + state_bytes) * vo,
            inbox=(vid_bytes + state_bytes) * vi,
            state=state_bytes * vp,
        )
        if dynamic is not None:
            d_max = int(dynamic.delta_slots)
            ndir = int(dynamic.directions)
            dw = 4 if dynamic.weighted else 0
            # delta slots: src + dst_ext (+ weight) per direction
            res[p]["delta"] = ndir * d_max * (2 * vid_bytes + dw)
            # tombstone masks: one byte per base edge slot per direction
            tomb = pg.fwd.e_max + (pg.rev.e_max if pg.rev is not None else 0)
            res[p]["tombstone"] = tomb
        res[p]["total"] = sum(res[p].values())
        host = 0
        if p in cold:
            host = (res[p]["graph"] + res[p].get("delta", 0)
                    + res[p].get("tombstone", 0))
        res[p]["tier"] = "host" if p in cold else "hbm"
        res[p]["hbm"] = res[p]["total"] - host
        res[p]["host"] = host
    return res


def memory_residency_bytes(pg: PartitionedGraph, tier_plan=None,
                           state_bytes: int = 4, dynamic=None) -> dict:
    """Aggregate device-vs-host residency of a (possibly tiered) layout.

    Sums :func:`memory_footprint_bytes`'s per-tier figures and adds the
    streaming double-buffer (two in-flight windows) to the device side —
    the honest capacity numbers ``ServeSession.report()`` and the serving
    driver's admission check consume: ``hbm_bytes`` is what actually
    occupies device memory, ``host_bytes`` what lives in the pinned host
    arena, ``total_bytes`` their sum.
    """
    per = memory_footprint_bytes(pg, state_bytes=state_bytes,
                                 dynamic=dynamic, tier_plan=tier_plan)
    hbm = sum(rec["hbm"] for rec in per.values())
    host = sum(rec["host"] for rec in per.values())
    if tier_plan is not None:
        hbm += int(tier_plan.stream_buffer_bytes)
    return dict(hbm_bytes=int(hbm), host_bytes=int(host),
                total_bytes=int(hbm + host))


# ---------------------------------------------------------------------------
# Tiered (out-of-core) memory plan: docs/memory.md
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WindowSchedule:
    """One direction's clean-cut streaming windows over the cold partitions.

    Every window is a contiguous run of at most ``win_blocks`` edge blocks
    of one cold partition, cut only at *clean* block boundaries — boundaries
    no destination run straddles — so each extended segment id receives its
    real contributions from exactly one window and the cross-window combine
    only ever adds the reduction identity: that is the whole bitwise-parity
    argument (edges are ``dst_ext``-sorted per partition; see
    docs/memory.md).  Windows have a *fixed* device shape
    ``win_e = win_blocks * block_e`` (short windows are sink-padded), so
    one compiled trace serves the entire schedule and the resident loop
    never retraces.
    """

    block_e: int
    win_blocks: int
    part: np.ndarray     # [W] int32 partition id of each window
    start: np.ndarray    # [W] int64 first edge slot covered
    count: np.ndarray    # [W] int64 real edge slots covered (<= win_e)

    @property
    def win_e(self) -> int:
        return self.win_blocks * self.block_e

    @property
    def num_windows(self) -> int:
        return len(self.part)


def _clean_cut_windows(ea: EdgeArrays, cold, block_e: int,
                       win_blocks: int) -> WindowSchedule:
    """Greedy clean-cut schedule: per cold partition, walk the blocks and
    cut each window at the latest clean boundary within ``win_blocks``."""
    part, start, count = [], [], []
    for p in cold:
        p = int(p)
        k = int(ea.num_edges[p])
        if k == 0:
            continue
        nb_used = -(-k // block_e)
        dst = ea.dst_ext[p]
        cur = 0
        while cur < nb_used:
            want = min(cur + win_blocks, nb_used)
            b = want
            while b > cur:
                i = b * block_e
                if i >= k or dst[i - 1] != dst[i]:
                    break                        # clean boundary
                b -= 1
            if b == cur:
                run = int(np.max(np.bincount(
                    dst[cur * block_e: min(k, want * block_e)])))
                raise ValueError(
                    f"partition {p}: a destination run of {run} edges "
                    f"spans more than win_blocks*block_e = "
                    f"{win_blocks * block_e} edge slots, so no clean "
                    f"window cut exists; raise win_blocks (or block_e) "
                    f"past the longest destination run")
            part.append(p)
            start.append(cur * block_e)
            count.append(min(k, b * block_e) - cur * block_e)
            cur = b
    return WindowSchedule(
        block_e=block_e, win_blocks=win_blocks,
        part=np.asarray(part, dtype=np.int32),
        start=np.asarray(start, dtype=np.int64),
        count=np.asarray(count, dtype=np.int64))


@dataclasses.dataclass
class TierPlan:
    """The two-tier residency decision ``perf_model.choose_tier_split``
    made for one partitioned graph.

    ``hot`` partitions keep their edge arenas device-resident exactly as
    before; ``cold`` partitions' arenas live in host DRAM and stream
    through the superstep in the double-buffered windows of ``fwd`` /
    ``rev``.  Byte figures use the *padded* device-arena measure (stacked
    ``[P, e_max]`` rows all cost the same), so ``hbm_bytes`` — hot arenas
    plus the two window buffers — is exactly what the tiered engine
    allocates and is ``<= hbm_budget_bytes`` by construction.
    """

    hbm_budget_bytes: int
    hot: np.ndarray                      # sorted int32, device-resident
    cold: np.ndarray                     # sorted int32, host-resident
    fwd: WindowSchedule
    rev: Optional[WindowSchedule]
    hbm_bytes: int                       # hot arenas + stream_buffer_bytes
    host_bytes: int                      # cold arenas (pinned host DRAM)
    streamed_bytes_per_superstep: int
    stream_buffer_bytes: int             # the two in-flight window buffers
    table: List[dict]                    # perf_model.rank_tier_split table

    @property
    def window_count(self) -> int:
        return self.fwd.num_windows + (self.rev.num_windows
                                       if self.rev is not None else 0)


def _arena_bytes_per_edge(weighted: bool, fused: bool) -> int:
    """Device bytes per padded edge slot: src + dst_ext (+ weight), plus
    the fused flavor's block metadata (blk_src/local/mask (+ weight_blk))."""
    b = 8 + (4 if weighted else 0)
    if fused:
        b += 12 + (4 if weighted else 0)
    return b


def build_tier_plan(pg: PartitionedGraph, hbm_budget_bytes: int, *,
                    block_e: int = 1024, win_blocks: int = 8,
                    fused: bool = True, dynamic=None) -> TierPlan:
    """Emit the :class:`TierPlan` for ``pg`` under an HBM budget.

    ``perf_model.choose_tier_split`` picks the HBM/host boundary (densest
    partitions stay hot — the MXU-friendly dense blocks the paper keeps on
    the GPU side); this derives the clean-cut window schedules for both
    directions and the arena byte accounting.  ``fused=False`` plans the
    reference-flavor arena only (no block metadata); ``dynamic`` adds the
    tombstone/delta overlay of a DynamicGraph to the cold arena and stream
    figures (the overlay streams with its base blocks).
    """
    from repro.core import perf_model

    P = pg.num_parts
    weighted = pg.fwd.weight is not None
    per_edge = _arena_bytes_per_edge(weighted, fused)
    win_e = win_blocks * block_e

    def _dir_bytes(ea: EdgeArrays) -> int:
        e_pad = max(_round_up(ea.e_max, block_e), block_e)
        b = (8 + (4 if weighted else 0)) * ea.e_max
        if fused:
            b += ((12 + (4 if weighted else 0)) * e_pad
                  + 4 * (e_pad // block_e))
        if dynamic is not None:
            b += ea.e_max                      # tombstone overlay, 1 B/slot
        return b

    part_bytes = np.full(P, _dir_bytes(pg.fwd), dtype=np.int64)
    if pg.rev is not None:
        part_bytes += _dir_bytes(pg.rev)
    if dynamic is not None:
        dw = 4 if dynamic.weighted else 0
        part_bytes += int(dynamic.directions) * int(dynamic.delta_slots) \
            * (8 + dw)
    window_bytes = per_edge * win_e + 4 * win_blocks \
        + (win_e if dynamic is not None else 0)

    part_edges = np.asarray(pg.fwd.num_edges, dtype=np.int64).copy()
    if pg.rev is not None:
        part_edges += np.asarray(pg.rev.num_edges, dtype=np.int64)
    hot, table = perf_model.choose_tier_split(
        part_bytes, int(hbm_budget_bytes), part_edges=part_edges,
        window_bytes=window_bytes)
    hot = np.asarray(sorted(hot), dtype=np.int32)
    cold = np.asarray([p for p in range(P) if p not in set(hot.tolist())],
                      dtype=np.int32)

    fwd_sched = _clean_cut_windows(pg.fwd, cold, block_e, win_blocks)
    rev_sched = (_clean_cut_windows(pg.rev, cold, block_e, win_blocks)
                 if pg.rev is not None else None)
    buffers = 0 if len(cold) == 0 else 2 * window_bytes
    hot_bytes = int(part_bytes[hot].sum()) if len(hot) else 0
    host_bytes = int(part_bytes[cold].sum()) if len(cold) else 0
    return TierPlan(
        hbm_budget_bytes=int(hbm_budget_bytes), hot=hot, cold=cold,
        fwd=fwd_sched, rev=rev_sched,
        hbm_bytes=hot_bytes + buffers, host_bytes=host_bytes,
        streamed_bytes_per_superstep=host_bytes,
        stream_buffer_bytes=buffers, table=table)
