"""The paper's hybrid performance model (§3) + TPU re-parameterization.

Equations (paper §3.2):

  t(G_p)   = |E_p^b| / c + |E_p| / r_p                         (Eq. 1)
  makespan = max_p t(G_p)                                      (Eq. 2)
  speedup  = t_cpu(G) / makespan                               (Eq. 3)
           = c / (beta * r_cpu + alpha * c)                    (Eq. 4)

The model is deliberately simple: processing rates in edges/second, one
communication rate for the interconnect, α = share of edges on the bottleneck
element, β = share of boundary edges.

TPU re-parameterization (DESIGN.md §2): the "CPU vs GPU" pair becomes the
"gather/VPU path vs dense/MXU path" pair on a single chip, and the PCI-E rate
becomes the ICI rate between shards.  Rates are derived from first principles
(bytes-per-edge over bandwidth; FLOPs-per-edge over peak) rather than
measured, since this container has no TPU.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# ---------------------------------------------------------------------------
# Hardware constants
# ---------------------------------------------------------------------------

# Paper Figure 1 values (2013 commodity parts).
PAPER_PCIE_GBPS = 12.0e9            # measured PCI-E gen3 bandwidth, B/s
PAPER_BYTES_PER_EDGE_MSG = 4.0      # 4-byte update per boundary edge
PAPER_C = PAPER_PCIE_GBPS / PAPER_BYTES_PER_EDGE_MSG   # 3 BE/s (paper)
PAPER_R_CPU = 1.0e9                 # ~1 BE/s (Nguyen et al. 2013 bests)
PAPER_R_GPU = 3.0e9

# TPU v5e-class target (task spec: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI).
TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9
TPU_ICI_LINK_BW = 50e9
TPU_ICI_LINKS = 4                   # 2D torus: 4 links/chip
TPU_VMEM_BYTES = 128 * 1024 * 1024  # ~128 MiB VMEM per chip
# Pinned host DRAM → device streaming rate (the PCI-E term of the paper's
# hybrid memory system, recast as the host-interface bandwidth a cold
# partition's edge blocks stream through).  Conservative PCIe-gen4-x16-class
# figure; the double-buffered window schedule overlaps this with compute.
HOST_STREAM_BW = 16.0e9


@dataclasses.dataclass
class ModelParams:
    """Parameters of Eq. 1–4."""

    r_bottleneck: float   # edges/s of the bottleneck element ("CPU")
    r_fast: float         # edges/s of the offload target ("GPU")
    c: float              # boundary edges/s over the interconnect

    @classmethod
    def paper_defaults(cls) -> "ModelParams":
        return cls(r_bottleneck=PAPER_R_CPU, r_fast=PAPER_R_GPU, c=PAPER_C)

    @classmethod
    def tpu_defaults(cls, bytes_per_edge: float = 8.0,
                     msg_bytes: float = 4.0) -> "ModelParams":
        """TPU rates from first principles.

        Sparse/gather path: every edge moves ~(4B col id + 4B neighbour
        state) from HBM → rate = HBM_BW / bytes_per_edge.
        Dense/MXU path: an edge inside a dense block costs 2 FLOP (MAC) at
        bf16 peak — but only the *occupied* fraction of the block does useful
        work, handled by :func:`dense_block_rate`.
        ICI: per-chip aggregate link bandwidth over message bytes.
        """
        return cls(
            r_bottleneck=TPU_HBM_BW / bytes_per_edge,
            r_fast=TPU_PEAK_FLOPS / 2.0,
            c=TPU_ICI_LINK_BW * TPU_ICI_LINKS / msg_bytes,
        )


def partition_time(num_edges: float, num_boundary: float, rate: float,
                   c: float) -> float:
    """Eq. 1: time to process one partition."""
    return num_boundary / c + num_edges / rate


def makespan(edge_counts, boundary_counts, rates, c: float) -> float:
    """Eq. 2: the slowest element bounds the system."""
    return max(partition_time(e, b, r, c)
               for e, b, r in zip(edge_counts, boundary_counts, rates))


def speedup(alpha: float, beta: float, r_cpu: float, c: float) -> float:
    """Eq. 4: predicted hybrid speedup vs. bottleneck-only processing."""
    return c / (beta * r_cpu + alpha * c)


def speedup_curve(alphas, beta: float, r_cpu: float, c: float) -> np.ndarray:
    return np.array([speedup(a, beta, r_cpu, c) for a in np.atleast_1d(alphas)])


# ---------------------------------------------------------------------------
# TPU-specific terms (DESIGN.md §2 "what changed and why")
# ---------------------------------------------------------------------------

def dense_block_rate(density: float, peak_flops: float = TPU_PEAK_FLOPS
                     ) -> float:
    """Effective edges/s of the MXU dense path for a block of given density.

    A dense K×K bf16 block SpMV costs 2·K² FLOP regardless of how many of the
    K² slots hold real edges; useful-edge throughput is peak/2 · density.
    """
    return peak_flops / 2.0 * density


def mxu_crossover_density(bytes_per_edge: float = 8.0,
                          peak_flops: float = TPU_PEAK_FLOPS,
                          hbm_bw: float = TPU_HBM_BW) -> float:
    """Density above which the MXU dense path beats the HBM gather path.

    gather rate = HBM/bytes_per_edge;  dense rate = peak/2 · density
    → crossover density = 2 · HBM / (bytes_per_edge · peak).

    With defaults: 2·819e9/(8·197e12) ≈ 1/962 — the MXU path wins even for
    blocks that are ~0.1% dense *if* the block streams from HBM at full rate;
    in practice VMEM residency of the frontier/rank slice is the binding
    constraint, so we use a conservative 1/16 planning threshold.
    """
    return 2.0 * hbm_bw / (bytes_per_edge * peak_flops)


def hybrid_makespan_tpu(e_dense: float, dense_density: float,
                        e_sparse: float, boundary_slots: float,
                        num_chips: int = 1,
                        bytes_per_edge: float = 8.0,
                        msg_bytes: float = 4.0) -> dict:
    """Makespan of the on-chip two-engine step (dense MXU + sparse VPU paths)
    across ``num_chips`` shards — the TPU recast of Eq. 2.

    Unlike the paper's CPU/GPU (truly concurrent), the MXU and VPU paths of
    one chip serialize; across chips the shards run concurrently, so:

      t_chip = e_dense/r_dense/chips + e_sparse/r_sparse/chips
      t_comm = boundary_slots·msg_bytes / (chips·ici_bw)
      makespan = t_comm + t_chip
    """
    r_dense = dense_block_rate(max(dense_density, 1e-12))
    r_sparse = TPU_HBM_BW / bytes_per_edge
    t_dense = e_dense / r_dense / num_chips
    t_sparse = e_sparse / r_sparse / num_chips
    t_comm = boundary_slots * msg_bytes / (TPU_ICI_LINK_BW * TPU_ICI_LINKS
                                           * num_chips)
    return dict(t_dense=t_dense, t_sparse=t_sparse, t_comm=t_comm,
                makespan=t_comm + t_dense + t_sparse)


# ---------------------------------------------------------------------------
# Direction-optimized traversal: the push/pull crossover (docs/traversal.md)
# ---------------------------------------------------------------------------

# Per-slot scan cost of the bottom-up path relative to the push path's
# per-edge cost, by backend.  The push direction pays a full gather +
# segment reduction per examined edge; the bottom-up scan is a contiguous
# ELL row walk (hybrid: the same kernel that already serves pull), so its
# relative per-slot cost is lowest there.  The reference/fused backends
# keep their boundary leg in push either way and pay an extra masked
# compute for it, so their scans are charged more conservatively.
DIRECTION_GAMMA = {"hybrid": 1.0, "fused": 1.5, "reference": 2.0}


def fit_pull_threshold(avg_degree: float, kmax: int | None = None, *,
                       backend: str = "hybrid",
                       gamma: float | None = None) -> float:
    """Fitted frontier-density threshold above which bottom-up (pull) wins.

    The α-style crossover of direction-optimized BFS (arXiv 1503.04359),
    recast for frontier density d (fraction of vertices live this
    superstep) on a graph of average degree ``deg``:

      push cost  ≈ d · V · deg            (edges out of the frontier)
      pull cost  ≈ V · E[scan] · γ        (early-exit row scans)

    with E[scan] ≈ min(1/d, kmax) — a random in-slot is live with
    probability ~d, so the expected first-hit position is ~1/d, capped by
    the ELL row width — and γ the backend's relative per-slot scan cost
    (``DIRECTION_GAMMA``).  Equating the two gives the crossover density

      d* = sqrt(γ / deg)          (uncapped scans)
      d* = γ · kmax / deg         (kmax-capped scans)

    and the fitted threshold is the smaller of the two, clamped to
    (0, 0.9].  Monotone non-increasing in ``avg_degree``: denser graphs
    flip to bottom-up at sparser frontiers — exactly the scale-free win.
    """
    if gamma is None:
        gamma = DIRECTION_GAMMA[backend]
    deg = max(float(avg_degree), 1e-9)
    thr = (gamma / deg) ** 0.5
    if kmax is not None:
        thr = min(thr, gamma * max(int(kmax), 1) / deg)
    return float(min(max(thr, 1e-4), 0.9))


def fit_shard_pull_thresholds(shard_avg_degrees, shard_kmaxes=None, *,
                              backend: str = "hybrid",
                              gamma: float | None = None) -> np.ndarray:
    """Per-shard crossover thresholds [S] for the distributed engines.

    HIGH/LOW partitioning gives shards very different degree profiles, so
    each shard fits (and applies) its own threshold — the per-shard
    direction decision of docs/traversal.md.
    """
    degs = np.atleast_1d(np.asarray(shard_avg_degrees, dtype=np.float64))
    if shard_kmaxes is None:
        kmaxes = [None] * len(degs)
    else:
        kmaxes = list(np.atleast_1d(np.asarray(shard_kmaxes)))
    return np.array([fit_pull_threshold(d, k, backend=backend, gamma=gamma)
                     for d, k in zip(degs, kmaxes)], dtype=np.float32)


# ---------------------------------------------------------------------------
# Degree-split selection (the paper's Eq. 4 role: the model picks the split)
# ---------------------------------------------------------------------------

# Largest dense block the planner will consider: the f32 H×H block plus the
# VMEM-resident value slice must fit comfortably in VMEM (k² · 4B ≤ VMEM/4).
K_DENSE_CAP = int((TPU_VMEM_BYTES / 4 / 4) ** 0.5) // 128 * 128


def k_dense_candidates(num_vertices: int, skewed: bool = True,
                       lane: int = 128) -> list:
    """Candidate dense-block sizes |H| for the degree split.

    A lane-aligned power-of-two ladder up to ``K_DENSE_CAP`` (VMEM bound) or
    the vertex count, plus 0 (pure sparse) and the full graph when it fits
    (pure dense).  ``skewed=False`` — no high-degree concentration in the
    block-span histograms (partition.BlockMetadata.span_histogram) — prunes
    the ladder to {0, one lane tile}: without skew no top-K block is dense
    enough for the MXU path to pay.
    """
    if not skewed:
        return [0, min(lane, K_DENSE_CAP)] if num_vertices >= lane else [0]
    cap = min(K_DENSE_CAP, num_vertices)
    cands = [0]
    k = lane
    while k < cap:
        cands.append(k)
        k *= 2
    cands.append(cap)
    return cands


def rank_k_dense(edge_max_rank: np.ndarray, num_edges: int, candidates,
                 num_chips: int = 1, bytes_per_edge: float = 8.0,
                 msg_bytes: float = 4.0, boundary_slots: float = 0.0) -> list:
    """Predict the two-engine makespan for each candidate |H| (Eq. 2 recast).

    ``edge_max_rank[e] = max(rank(src_e), rank(dst_e))`` under the
    degree-descending vertex ranking, so ``e_dense(k)`` — edges inside the
    H×H block — is a single ``searchsorted``.  Returns one record per
    candidate with the makespan terms from :func:`hybrid_makespan_tpu`.

    ``boundary_slots`` is the Eq. 1 communication term ``|E_p^b| / c``: the
    number of aggregated outbox slots this partition ships over the
    interconnect per superstep (β_with_reduction·|E_p|, paper §3.4 — the
    source-side reduction already collapsed per-edge messages into slots).
    It is independent of the split point, so it shifts every candidate's
    makespan by the same ICI time — but across *shards* it differs, which is
    what makes the sharded argmin (:func:`plan_shards`) strategy-sensitive.
    """
    ranks = np.sort(np.asarray(edge_max_rank))
    table = []
    for k in candidates:
        e_dense = int(np.searchsorted(ranks, k, side="left"))
        e_sparse = int(num_edges) - e_dense
        density = e_dense / max(int(k) * int(k), 1)
        pred = hybrid_makespan_tpu(e_dense, density, e_sparse,
                                   boundary_slots=boundary_slots,
                                   num_chips=num_chips,
                                   bytes_per_edge=bytes_per_edge,
                                   msg_bytes=msg_bytes)
        table.append(dict(k_dense=int(k), e_dense=e_dense, e_sparse=e_sparse,
                          density=density,
                          boundary_slots=float(boundary_slots), **pred))
    return table


def choose_k_dense(edge_max_rank: np.ndarray, num_edges: int, candidates,
                   **kwargs):
    """Pick |H| = argmin of predicted makespan; returns (k, ranked table)."""
    table = rank_k_dense(edge_max_rank, num_edges, candidates, **kwargs)
    best = min(table, key=lambda rec: rec["makespan"])
    return best["k_dense"], table


# ---------------------------------------------------------------------------
# Tiered-memory split selection (out-of-core: docs/memory.md)
# ---------------------------------------------------------------------------

def host_stream_time(streamed_bytes: float,
                     stream_bw: float = HOST_STREAM_BW) -> float:
    """The host-transfer term: seconds to stream ``streamed_bytes`` of cold
    edge blocks from pinned host DRAM per superstep.

    Sits alongside Eq. 1's ``|E_p^b| / c`` ICI term: a host-tier partition's
    superstep time grows by ``bytes/BW`` exactly like a boundary-heavy
    partition's grows by its outbox traffic — one more bandwidth-cost term,
    same model shape.
    """
    return float(streamed_bytes) / max(stream_bw, 1e-30)


def rank_tier_split(part_bytes, hbm_budget_bytes: int, *,
                    part_edges=None, window_bytes: int = 0,
                    stream_bw: float = HOST_STREAM_BW,
                    bytes_per_edge: float = 8.0) -> list:
    """Predict the per-superstep time of every HBM/host cut (Eq. 1 + stream).

    ``part_bytes[p]`` is partition ``p``'s device-resident edge-arena size;
    partitions are ranked densest-first (descending bytes, ties by id — the
    high-degree partitions the MXU path wants resident) and each candidate
    keeps the first ``h`` of that order in HBM.  A cut is *feasible* when the
    hot arenas plus the two streaming window buffers (``2 * window_bytes``,
    the double-buffer the host loop ping-pongs through) fit the budget; the
    all-resident cut needs no window buffers.  Returns one record per
    candidate with the compute term (``edges / gather rate``), the
    host-transfer term (:func:`host_stream_time` over the cold bytes), and
    the predicted makespan — the table :func:`choose_tier_split` picks from,
    and the "when does resident still win" evidence docs/memory.md cites.
    """
    part_bytes = np.asarray(part_bytes, dtype=np.int64)
    P = len(part_bytes)
    if part_edges is None:
        part_edges = part_bytes / max(bytes_per_edge, 1e-30)
    part_edges = np.asarray(part_edges, dtype=np.float64)
    # Densest-first by *real* edge count (stacked device arenas are padded to
    # a shared e_max, so bytes alone cannot rank), ties by partition id.
    order = np.lexsort((np.arange(P), -part_edges))
    r_gather = TPU_HBM_BW / bytes_per_edge
    total_edges = float(part_edges.sum())
    table = []
    for h in range(P + 1):
        hot = order[:h]
        cold = order[h:]
        hot_bytes = int(part_bytes[hot].sum())
        host_bytes = int(part_bytes[cold].sum())
        buffers = 0 if h == P else 2 * int(window_bytes)
        t_stream = host_stream_time(host_bytes, stream_bw)
        t_compute = total_edges / r_gather
        table.append(dict(
            num_hot=h, hot=tuple(int(p) for p in np.sort(hot)),
            hbm_bytes=hot_bytes + buffers, host_bytes=host_bytes,
            streamed_bytes_per_superstep=host_bytes,
            t_stream=t_stream, t_compute=t_compute,
            makespan=t_compute + t_stream,
            feasible=hot_bytes + buffers <= hbm_budget_bytes))
    return table


def choose_tier_split(part_bytes, hbm_budget_bytes: int,
                      **kwargs) -> "tuple[tuple, list]":
    """Pick the HBM/host boundary: the argmin-makespan *feasible* cut.

    Streaming only ever adds the host-transfer term, so the argmin over
    feasible cuts is the longest densest-first **prefix** whose arenas fit
    the budget — which is what makes the choice monotone: a bigger budget
    keeps a superset of partitions hot (pinned by tests/test_oocore.py).
    Returns ``(hot_ids, table)`` like :func:`choose_k_dense` returns
    ``(k, table)``; raises when even the all-cold cut (two window buffers)
    cannot fit, with the fix spelled out.
    """
    table = rank_tier_split(part_bytes, hbm_budget_bytes, **kwargs)
    feasible = [rec for rec in table if rec["feasible"]]
    if not feasible:
        need = min(rec["hbm_bytes"] for rec in table)
        raise ValueError(
            f"hbm_budget_bytes={hbm_budget_bytes} cannot hold even the "
            f"streaming double-buffer (needs >= {need} bytes); raise the "
            f"budget or shrink the window (smaller win_blocks/block_e)")
    best = min(feasible, key=lambda rec: (rec["makespan"], -rec["num_hot"]))
    return best["hot"], table


def plan_shards(shard_ranks, shard_edges, shard_slots, candidates,
                k_dense: "int | None" = None, **kwargs) -> dict:
    """Per-shard split decision for the distributed hybrid engine (Eq. 1–2).

    Each shard ``p`` runs its own two-engine step over its intra-partition
    edges and ships its aggregated outbox slots over the ICI, so its
    predicted superstep time is ``t_p = |slots_p|/c + t_dense + t_sparse``
    (Eq. 1 with the §3.4 reduced boundary term) and the system is bound by
    ``max_p t_p`` (Eq. 2).  ``shard_ranks[p]`` / ``shard_edges[p]`` /
    ``shard_slots[p]`` describe shard ``p``'s intra edges and cross-shard
    outbox slots; each shard's ``k_dense`` is the argmin of *its own*
    comm-inclusive makespan (pass ``k_dense=`` to force one size for all).

    ``candidates`` is one ladder shared by every shard, or a per-shard
    sequence of ladders (shards have different vertex counts, so their
    VMEM-capped ladders differ).

    Returns ``dict(per_shard=[{shard, k_dense, makespan, t_comm, ..,
    table}], k_dense=max chosen |H| (the padded uniform block size the SPMD
    step compiles for), makespan=max_p, bottleneck=argmax_p)``.
    """
    nested = (len(candidates) > 0
              and isinstance(candidates[0], (list, tuple, np.ndarray)))
    per_shard = []
    for s, (ranks, edges, slots) in enumerate(
            zip(shard_ranks, shard_edges, shard_slots)):
        cands = list(candidates[s]) if nested else list(candidates)
        cands = (sorted(set(cands) | {k_dense})
                 if k_dense is not None else cands)
        table = rank_k_dense(ranks, edges, cands,
                             boundary_slots=slots, **kwargs)
        if k_dense is None:
            best = min(table, key=lambda rec: rec["makespan"])
        else:
            best = next(r for r in table if r["k_dense"] == k_dense)
        per_shard.append(dict(shard=s, num_edges=int(edges),
                              boundary_slots=float(slots), table=table,
                              **{k: best[k] for k in
                                 ("k_dense", "e_dense", "e_sparse", "density",
                                  "t_dense", "t_sparse", "t_comm",
                                  "makespan")}))
    bottleneck = max(per_shard, key=lambda rec: rec["makespan"])
    return dict(per_shard=per_shard,
                k_dense=max((rec["k_dense"] for rec in per_shard), default=0),
                makespan=bottleneck["makespan"],
                bottleneck=bottleneck["shard"])


def should_resplit(edge_max_rank: np.ndarray, num_edges: int, candidates,
                   current_k: int, threshold: float = 0.10,
                   **kwargs) -> "tuple[bool, dict]":
    """Decide whether a drifted (mutated) graph warrants re-splitting.

    The dynamic layer keeps the degree split frozen between compactions —
    a stale split is a performance choice, never a correctness one — so the
    expensive re-ranking (``choose_k_dense``, or ``plan_shards`` per shard)
    should only run when it pays.  This evaluates the *current* split on the
    *drifted* graph's ranks against the argmin over ``candidates`` and
    votes to resplit only when the predicted makespan improves by more than
    ``threshold`` (relative).  Returns ``(resplit, info)`` with
    ``info = dict(current_makespan, best_makespan, best_k, improvement,
    table)``.
    """
    cands = sorted(set(int(c) for c in candidates) | {int(current_k)})
    table = rank_k_dense(edge_max_rank, num_edges, cands, **kwargs)
    cur = next(r for r in table if r["k_dense"] == int(current_k))
    best = min(table, key=lambda rec: rec["makespan"])
    improvement = 1.0 - best["makespan"] / max(cur["makespan"], 1e-30)
    return improvement > threshold, dict(
        current_k=int(current_k), current_makespan=cur["makespan"],
        best_k=best["k_dense"], best_makespan=best["makespan"],
        improvement=improvement, table=table)


def split_mode(k_dense: int, num_vertices: int, e_sparse: int) -> str:
    """Classify a chosen split: the engine runs dense, sparse, or both."""
    if k_dense == 0:
        return "sparse"
    if e_sparse == 0 or k_dense >= num_vertices:
        return "dense"
    return "hybrid"


def predicted_vs_measured(pred: np.ndarray, meas: np.ndarray) -> dict:
    """Pearson correlation + average error — paper Table 3 metrics."""
    pred = np.asarray(pred, dtype=np.float64)
    meas = np.asarray(meas, dtype=np.float64)
    corr = float(np.corrcoef(pred, meas)[0, 1]) if len(pred) > 1 else 1.0
    avg_err = float(np.mean((pred - meas) / meas))
    return dict(correlation=corr, avg_error=avg_err)
