"""The dynamic-graph layer: in-place edge mutations over a resident layout.

The paper's target workloads — social-network graphs — mutate continuously,
yet every layer of the static stack (CSR build → partition → degree split →
block metadata → engine) assumes a frozen graph and pays a full rebuild plus
a recompile for any edge change.  :class:`DynamicGraph` makes mutation a
first-class axis with **static shapes**:

- **Delta edge slots.**  Each partition reserves ``delta_slots`` padded edge
  slots (``[P, d_max]`` arrays mirroring ``src``/``dst_ext``/``weight``);
  inserted edges occupy slots (occupancy is data, not shape), unoccupied and
  cleared slots point their extended destination at the segment sink, so the
  engine's ⊕-reduction drops them for free — the same trick ``partition.py``
  already plays for padding edges.
- **Tombstones.**  Deleting a base edge flips one bit in a ``[P, e_max]``
  mask; the engine redirects tombstoned edges to the sink (reference path)
  or zeroes their block mask (fused kernel).  Nothing moves.
- **Spare outbox slots.**  ``partition(..., spare_outbox=k)`` reserves ``k``
  unassigned slots per (partition, peer) pair; an inserted boundary edge to
  a previously-unmessaged remote vertex claims one and the symmetric
  ``inbox_dst`` entry is scattered in — ``o_max`` never changes, so neither
  does any compiled shape.
- **Jittable application.**  ``apply_mutations(batch)`` plans host-side
  (slot allocation, FIFO delete resolution via :class:`graph.EdgeLedger`)
  and applies device-side through **one** compiled padded-scatter — batches
  of any composition up to ``mutation_capacity`` reuse the same trace.
- **Compaction.**  When the staleness signals trip (delta occupancy,
  tombstone fraction, outbox-slot pressure, degree-skew drift via
  ``BlockMetadata.span_histogram``), ``compact()`` folds the ledger into a
  fresh canonical partition — the one retrace-paying event, reported as a
  pause by the serving driver.  ``perf_model.should_resplit`` gates the
  hybrid backend's re-ranking the same way: recompute the degree split only
  when the drifted graph's predicted makespan beats the stale split by a
  threshold.

The engine side (``core/bsp.py``) consumes the device payload as *traced
arguments*, so mutation batches never retrace and compaction can never be
served from a stale compiled constant.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph, EdgeLedger, MutationBatch
from repro.runtime import chaos
from repro.core.partition import (EdgeArrays, build_block_metadata, partition,
                                  _round_up)


class CapacityError(RuntimeError):
    """A mutation batch exceeds the graph's fixed in-place headroom."""


@dataclasses.dataclass
class _DirState:
    """Host mirrors of one direction's mutable layout."""

    ea: EdgeArrays
    reverse: bool
    tomb: np.ndarray                    # [P, e_max] bool
    d_src: np.ndarray                   # [P, d_max] int32
    d_dst_ext: np.ndarray               # [P, d_max] int32 (sink = v_max)
    d_w: Optional[np.ndarray]           # [P, d_max] f32 or None
    d_cnt: np.ndarray                   # [P] high-water occupancy
    d_free: List[List[int]]             # reusable cleared slots per partition
    obox_dst: np.ndarray                # [P, P, o_max] int32 (live copy)
    obox_used: np.ndarray               # [P, P] allocated slot counts
    obox_used0: np.ndarray              # [P, P] counts at bind time
    slot_of: Dict[Tuple[int, int], Dict[int, int]]  # (p, q) -> {global: slot}
    # instance locators: base iids resolve through two vectorized arrays
    # (built without per-edge Python work — compact() re-pays this at |E|
    # scale), delta iids through a small dict
    base_p: np.ndarray                  # [num_base] int32 partition
    base_pos: np.ndarray                # [num_base] int64 slot in [P, e_max]
    delta_loc: Dict[int, Tuple[int, int]]  # iid -> (p, delta slot)

    def delta_live(self, p: int) -> int:
        return int(self.d_cnt[p]) - len(self.d_free[p])


@jax.jit
def _scatter_payload(payload: dict, upd: dict) -> dict:
    """Apply one batch's padded writes: for each target array, set
    ``flat[idx] = val`` with out-of-bounds padding indices dropped.  One
    compiled scatter serves every batch (fixed key set + fixed pad shape =
    the zero-retrace contract of mutation application)."""
    out = dict(payload)
    for k, (idx, val) in upd.items():
        arr = payload[k]
        flat = arr.reshape(-1)
        out[k] = flat.at[idx].set(val, mode="drop").reshape(arr.shape)
    return out


class DynamicGraph:
    """A partitioned graph that accepts in-place edge mutation batches.

    Wraps :func:`partition.partition` output plus per-partition delta slots,
    tombstone masks, and live outbox maps.  Hand the *DynamicGraph* (not the
    inner ``pg``) to :class:`bsp.BSPEngine` / ``DistributedBSPEngine``; the
    engine reads the device payload as traced arguments each run, so
    ``apply_mutations`` between runs never retraces the superstep loop.

    ``delta_slots`` is the per-partition insert capacity between
    compactions; ``spare_outbox`` the per-peer-pair boundary headroom;
    ``mutation_capacity`` the max edges per batch (the padded scatter's
    fixed width).  When a batch does not fit the remaining headroom the
    graph auto-compacts first (the explicit pause), then applies it.
    """

    def __init__(self, g: CSRGraph, num_parts: int, strategy: str = "rand",
                 *, delta_slots: Optional[int] = None,
                 spare_outbox: Optional[int] = None,
                 mutation_capacity: int = 1024,
                 include_reverse: bool = False,
                 cpu_edge_fraction: Optional[float] = None,
                 seed: int = 0, align: int = 8):
        self.mutation_capacity = int(mutation_capacity)
        if delta_slots is None:
            delta_slots = _round_up(4 * self.mutation_capacity, align)
        if spare_outbox is None:
            spare_outbox = self.mutation_capacity
        self.delta_slots = max(_round_up(int(delta_slots), align), align)
        self._part_kwargs = dict(
            num_parts=num_parts, strategy=strategy,
            cpu_edge_fraction=cpu_edge_fraction, seed=seed,
            include_reverse=include_reverse, align=align,
            spare_outbox=int(spare_outbox))
        self.pg = partition(g, **self._part_kwargs)
        self.weighted = g.weights is not None
        self.version = 0             # bumped by every compaction
        self.num_batches = 0         # global batch counter (never reset)
        self.batches_in_version = 0
        self.compactions = 0
        self.last_compaction_ms = 0.0
        # Bounded history of recent batches (dirty srcs, monotone flag, the
        # batch itself for the hybrid reconcile).  log_floor is the highest
        # batch index already dropped: consumers holding an older mark must
        # fall back (cold recompute / split rebuild) — soundness never
        # depends on unbounded retention.
        self.log_retain = 256
        self._batch_log: List[dict] = []
        self.log_floor = 0
        self._base_skew: Optional[float] = None
        self._bind()

    # ------------------------------------------------------------------
    # construction / rebind
    # ------------------------------------------------------------------

    @property
    def directions(self) -> int:
        return 2 if self.pg.rev is not None else 1

    @property
    def num_edges(self) -> int:
        return len(self.ledger)

    def _bind(self) -> None:
        """(Re)initialize ledger, host mirrors, and device payloads from the
        current ``self.pg`` — construction and post-compaction both land
        here."""
        g = self.pg.source
        self.ledger = EdgeLedger(g)
        rev_of = np.argsort(g.col, kind="stable")  # rev edge j -> orig edge
        self._fwd = self._bind_dir(self.pg.fwd, None)
        self._rev = (self._bind_dir(self.pg.rev, rev_of)
                     if self.pg.rev is not None else None)
        self._payload = {False: self._device_payload(self._fwd)}
        if self._rev is not None:
            self._payload[True] = self._device_payload(self._rev)
        self.batches_in_version = 0

    def _bind_dir(self, ea: EdgeArrays, rev_of: Optional[np.ndarray]
                  ) -> _DirState:
        pg = self.pg
        P, e_max, o_max = pg.num_parts, ea.e_max, ea.o_max
        d_max = self.delta_slots
        asg = pg.assignment
        slot_of: Dict[Tuple[int, int], Dict[int, int]] = {}
        obox_used = np.zeros((P, P), dtype=np.int64)
        for p in range(P):
            for q in range(P):
                if p == q:
                    continue
                mask = ea.outbox_mask[p, q]
                k = int(mask.sum())
                obox_used[p, q] = k
                locs = ea.outbox_dst[p, q, :k]
                slot_of[(p, q)] = {
                    int(asg.l2g[q][loc]): s for s, loc in enumerate(locs)}
        num_base = int(ea.num_edges.sum())
        base_p = np.full(num_base, -1, dtype=np.int32)
        base_pos = np.full(num_base, -1, dtype=np.int64)
        for p in range(P):
            ids = ea.edge_id[p]
            pos = np.flatnonzero(ids >= 0)
            orig = ids[pos]
            if rev_of is not None:
                orig = rev_of[orig]
            base_p[orig] = p
            base_pos[orig] = pos
        return _DirState(
            ea=ea, reverse=rev_of is not None,
            tomb=np.zeros((P, e_max), dtype=bool),
            d_src=np.zeros((P, d_max), dtype=np.int32),
            d_dst_ext=np.full((P, d_max), pg.v_max, dtype=np.int32),
            d_w=(np.zeros((P, d_max), dtype=np.float32)
                 if ea.weight is not None else None),
            d_cnt=np.zeros(P, dtype=np.int64),
            d_free=[[] for _ in range(P)],
            obox_dst=ea.outbox_dst.copy(), obox_used=obox_used,
            obox_used0=obox_used.copy(), slot_of=slot_of,
            base_p=base_p, base_pos=base_pos, delta_loc={})

    def _device_payload(self, ds: _DirState) -> dict:
        pl = {"tomb": jnp.asarray(ds.tomb),
              "d_src": jnp.asarray(ds.d_src),
              "d_dst_ext": jnp.asarray(ds.d_dst_ext),
              "inbox_dst": jnp.asarray(
                  np.ascontiguousarray(ds.obox_dst.transpose(1, 0, 2)))}
        if ds.d_w is not None:
            pl["d_weight"] = jnp.asarray(ds.d_w)
        return pl

    def payload(self, use_reverse: bool = False) -> dict:
        """This direction's dynamic device arrays — the engine passes them
        as traced arguments into the compiled superstep loop."""
        if use_reverse and True not in self._payload:
            raise ValueError("dynamic graph built without include_reverse")
        return self._payload[bool(use_reverse) and True in self._payload]

    # ------------------------------------------------------------------
    # mutation application
    # ------------------------------------------------------------------

    def _dirs(self):
        out = [(self._fwd, False)]
        if self._rev is not None:
            out.append((self._rev, True))
        return out

    def _fits(self, batch: MutationBatch) -> bool:
        """Exact dry-run capacity check (no state is touched)."""
        asg = self.pg.assignment
        for ds, reverse in self._dirs():
            free = {p: self.delta_slots - ds.delta_live(p)
                    for p in range(self.pg.num_parts)}
            new_slots: Dict[Tuple[int, int], set] = {}
            for i in range(len(batch)):
                if not batch.insert[i]:
                    continue
                a, b = ((batch.dst[i], batch.src[i]) if reverse
                        else (batch.src[i], batch.dst[i]))
                p = int(asg.part_of[a])
                q = int(asg.part_of[b])
                free[p] -= 1
                if free[p] < 0:
                    return False
                if p != q and int(b) not in ds.slot_of[(p, q)]:
                    pend = new_slots.setdefault((p, q), set())
                    pend.add(int(b))
                    if (ds.obox_used[p, q] + len(pend)
                            > ds.ea.o_max):
                        return False
        return True

    def apply_mutations(self, batch: MutationBatch) -> dict:
        """Apply one batch in place; returns the application report.

        Host side resolves every operation to padded scatter writes (delta
        slots, tombstones, new outbox/inbox slots, degree updates); device
        side is one compiled scatter per direction.  Auto-compacts first
        when the batch does not fit the remaining headroom.  The report
        carries ``edges_per_sec`` (end-to-end apply throughput: host
        planning *and* device scatter, compaction pauses excluded —
        they're reported via ``compacted``/``last_compaction_ms``),
        ``dirty`` (global sources of inserted edges — the warm-start
        frontier seed), and ``monotone``.
        """
        chaos.visit("mutation.apply", index=self.num_batches)
        batch.validate(self.pg.num_vertices)
        if len(batch) > self.mutation_capacity:
            raise CapacityError(
                f"batch of {len(batch)} edges exceeds mutation_capacity="
                f"{self.mutation_capacity}")
        compacted = False
        if not self._fits(batch):
            self.compact()
            compacted = True
            if not self._fits(batch):
                raise CapacityError(
                    "mutation batch exceeds a freshly-compacted graph's "
                    "delta/outbox headroom; raise delta_slots/spare_outbox")

        t0 = time.perf_counter()
        asg = self.pg.assignment
        upds = {False: {}, True: {}}   # per direction: key -> {flat: val}

        def put(reverse, key, flat, val):
            upds[reverse].setdefault(key, {})[int(flat)] = val

        dirty = set()
        w_all = batch.weight
        for i in range(len(batch)):
            u, v = int(batch.src[i]), int(batch.dst[i])
            w = float(w_all[i]) if w_all is not None else None
            if batch.insert[i]:
                iid = self.ledger.insert(u, v, w)
                dirty.add(u)
                for ds, reverse in self._dirs():
                    a, b = (v, u) if reverse else (u, v)
                    self._insert_dir(ds, reverse, iid, a, b, w, put)
                self.pg.out_deg[asg.part_of[u], asg.local_id[u]] += 1.0
            else:
                iid, _ = self.ledger.delete(u, v)
                for ds, reverse in self._dirs():
                    rec = ds.delta_loc.pop(iid, None)
                    if rec is None:            # base instance: tombstone
                        p = int(ds.base_p[iid])
                        pos = int(ds.base_pos[iid])
                        ds.tomb[p, pos] = True
                        put(reverse, "tomb", p * ds.ea.e_max + pos, True)
                    else:                      # delta instance: clear slot
                        p, pos = rec
                        ds.d_dst_ext[p, pos] = self.pg.v_max
                        ds.d_free[p].append(pos)
                        put(reverse, "d_dst_ext",
                            p * self.delta_slots + pos, self.pg.v_max)
                self.pg.out_deg[asg.part_of[u], asg.local_id[u]] -= 1.0

        # mid-mutation-batch injection point: host planning done, device
        # scatter not yet issued — a fault here leaves the batch
        # unacknowledged and the host mirrors partially advanced, so
        # recovery MUST rebuild from base + replay the acknowledged log.
        chaos.visit("mutation.scatter", index=self.num_batches)
        for ds, reverse in self._dirs():
            self._payload[reverse] = self._apply_device(
                self._payload[reverse], upds[reverse])
        jax.block_until_ready([
            leaf for pl in self._payload.values()
            for leaf in jax.tree_util.tree_leaves(pl)])
        apply_s = time.perf_counter() - t0

        self.num_batches += 1
        self.batches_in_version += 1
        rec = dict(index=self.num_batches, batch=batch,
                   dirty=np.fromiter(dirty, dtype=np.int64,
                                     count=len(dirty)),
                   monotone=batch.monotone)
        self._batch_log.append(rec)
        while len(self._batch_log) > self.log_retain:
            self.log_floor = self._batch_log.pop(0)["index"]
        return dict(num_edges=len(batch), inserts=batch.num_inserts,
                    deletes=batch.num_deletes, monotone=batch.monotone,
                    apply_ms=apply_s * 1e3,
                    edges_per_sec=len(batch) / max(apply_s, 1e-9),
                    compacted=compacted,
                    dirty=rec["dirty"])

    def _insert_dir(self, ds: _DirState, reverse: bool, iid: int,
                    a: int, b: int, w: Optional[float], put) -> None:
        pg = self.pg
        asg = pg.assignment
        p = int(asg.part_of[a])
        q = int(asg.part_of[b])
        b_local = int(asg.local_id[b])
        if p == q:
            ext = b_local
        else:
            slots = ds.slot_of[(p, q)]
            s = slots.get(b)
            if s is None:
                s = int(ds.obox_used[p, q])
                ds.obox_used[p, q] += 1
                slots[b] = s
                ds.obox_dst[p, q, s] = b_local
                # symmetric inbox entry on the receiving side
                P, o_max = pg.num_parts, ds.ea.o_max
                put(reverse, "inbox_dst",
                    (q * P + p) * o_max + s, b_local)
            ext = pg.v_max + 1 + q * ds.ea.o_max + s
        slot = ds.d_free[p].pop() if ds.d_free[p] else int(ds.d_cnt[p])
        if slot == ds.d_cnt[p]:
            ds.d_cnt[p] += 1
        ds.delta_loc[iid] = (p, slot)
        a_local = int(asg.local_id[a])
        ds.d_src[p, slot] = a_local
        ds.d_dst_ext[p, slot] = ext
        flat = p * self.delta_slots + slot
        put(reverse, "d_src", flat, a_local)
        put(reverse, "d_dst_ext", flat, ext)
        if ds.d_w is not None:
            wv = float(w if w is not None else 1.0)
            ds.d_w[p, slot] = wv
            put(reverse, "d_weight", flat, wv)

    def _apply_device(self, payload: dict, writes: Dict[str, dict]) -> dict:
        """Pad each key's writes to ``mutation_capacity`` and run the one
        compiled scatter.  Every key is always present (empty keys carry
        all-dropped padding) so the trace is batch-composition-independent.
        """
        cap = self.mutation_capacity
        upd = {}
        for k, arr in payload.items():
            kw = writes.get(k, {})
            if len(kw) > cap:
                # one (u,v) op touches each key at most once per direction,
                # so len(kw) <= len(batch) <= cap always holds
                raise CapacityError(f"{len(kw)} writes for {k} exceed "
                                    f"mutation_capacity={cap}")
            idx = np.full(cap, arr.size, dtype=np.int64)   # drop sentinel
            val = np.zeros(cap, dtype=arr.dtype)
            if kw:
                idx[:len(kw)] = np.fromiter(kw.keys(), dtype=np.int64,
                                            count=len(kw))
                val[:len(kw)] = np.asarray(list(kw.values()),
                                           dtype=arr.dtype)
            upd[k] = (jnp.asarray(idx), jnp.asarray(val))
        return _scatter_payload(payload, upd)

    # ------------------------------------------------------------------
    # compaction / staleness
    # ------------------------------------------------------------------

    def mutated_csr(self) -> CSRGraph:
        """Canonical CSR of the current edge multiset — equals
        ``graph.apply_mutation_batches(base, batches)`` for the same
        history (the incremental contract's ground truth)."""
        return self.ledger.to_csr(self.pg.num_vertices)

    def compact(self) -> float:
        """Fold ledger + deltas into a fresh canonical partition (the one
        retrace-paying event).  Returns the pause in milliseconds."""
        t0 = time.perf_counter()
        g2 = self.mutated_csr()
        self.pg = partition(g2, **self._part_kwargs)
        self.version += 1
        self._base_skew = None
        self._bind()
        # the folded history is dealt with: engines rebuild on the version
        # bump, and any pre-compaction mark now falls below the floor
        self._batch_log.clear()
        self.log_floor = self.num_batches
        self.compactions += 1
        self.last_compaction_ms = (time.perf_counter() - t0) * 1e3
        return self.last_compaction_ms

    def staleness(self) -> dict:
        """The compaction-trigger signals (cheap counters only)."""
        delta_occ = 0.0
        slot_occ = 0.0
        tombs = 0
        base = 0
        for ds, _ in self._dirs():
            for p in range(self.pg.num_parts):
                delta_occ = max(delta_occ,
                                ds.delta_live(p) / self.delta_slots)
            # fraction of each pair's *spare* headroom consumed since bind
            spare0 = np.maximum(ds.ea.o_max - ds.obox_used0, 1)
            taken = ds.obox_used - ds.obox_used0
            frac = taken / spare0
            np.fill_diagonal(frac, 0.0)
            slot_occ = max(slot_occ, float(frac.max()))
            tombs += int(ds.tomb.sum())
            base += int(ds.ea.num_edges.sum())
        return dict(delta_occupancy=delta_occ,
                    tombstone_fraction=tombs / max(base, 1),
                    outbox_occupancy=slot_occ,
                    batches_in_version=self.batches_in_version)

    def should_compact(self, max_delta: float = 0.5,
                       max_tombstone: float = 0.25,
                       max_outbox: float = 0.9,
                       max_skew_drift: Optional[float] = None) -> bool:
        """True when any staleness signal trips.  The occupancy signals are
        O(P²) counter reads; ``max_skew_drift`` additionally evaluates the
        O(|E| log |E|) :meth:`skew_drift` span-histogram signal (pass it at
        compaction-check cadence — the serving driver does per round — not
        per mutation)."""
        s = self.staleness()
        if (s["delta_occupancy"] > max_delta
                or s["tombstone_fraction"] > max_tombstone
                or s["outbox_occupancy"] > max_outbox):
            return True
        return (max_skew_drift is not None
                and abs(self.skew_drift()) > max_skew_drift)

    def skew_drift(self, block_e: int = 256) -> float:
        """Degree-skew drift of the mutated layout vs the bound layout —
        the ``BlockMetadata.span_histogram`` signal, O(|E| log |E|) numpy
        (run at compaction-check cadence, not per batch)."""
        from repro.core.partition import _build_edge_arrays
        if self._base_skew is None:
            self._base_skew = build_block_metadata(
                self.pg.fwd, block_e=block_e).degree_skew()
        ea_now = _build_edge_arrays(self.mutated_csr(), self.pg.assignment,
                                    self.pg.v_max,
                                    self._part_kwargs["align"])
        now = build_block_metadata(ea_now, block_e=block_e).degree_skew()
        return float(now - self._base_skew)

    # ------------------------------------------------------------------
    # warm-start bookkeeping
    # ------------------------------------------------------------------

    def dirty_since(self, mark: int) -> Tuple[np.ndarray, bool]:
        """Union of inserted-edge sources since batch index ``mark`` (as a
        global [n] bool mask) and whether every batch since was monotone
        (insert-only) — the inputs to ``BSPEngine.run_incremental``'s
        warm-vs-cold decision.  A mark older than the bounded batch log
        (or predating a compaction, which folds and clears the history)
        conservatively reports non-monotone, sending the caller to a cold
        recompute."""
        if mark < self.log_floor:
            return np.ones(self.pg.num_vertices, dtype=bool), False
        dirty = np.zeros(self.pg.num_vertices, dtype=bool)
        monotone = True
        for rec in self._batch_log:
            if rec["index"] <= mark:
                continue
            dirty[rec["dirty"]] = True
            monotone = monotone and rec["monotone"]
        return dirty, monotone

    def mark(self) -> int:
        """Current batch clock, to pass back into :meth:`dirty_since`."""
        return self.num_batches

    # ------------------------------------------------------------------
    # checkpointing
    # ------------------------------------------------------------------

    def snapshot(self) -> Tuple[dict, dict]:
        """(tree, extra) for ``CheckpointManager.save_tree``.

        The tree holds the device mutation payload per direction (delta
        src/dst slots, tombstones, live inbox maps, delta weights); the
        extra carries the replay cursor (``num_batches`` — how many batches
        the snapshot has absorbed) plus version/log_floor.  Restore does
        NOT write the payload back: the host-side mirrors (slot maps, free
        lists, the ledger) are not in the snapshot, so recovery rebuilds
        the graph from base and replays the acknowledged mutation log up
        to the cursor — construction is deterministic, so the replayed
        payload is bitwise identical to the snapshotted one, which the
        serving driver asserts as its zero-lost-mutations check.
        """
        tree = {("rev" if reverse else "fwd"): dict(self._payload[reverse])
                for reverse in self._payload}
        extra = dict(cursor=self.num_batches, version=self.version,
                     log_floor=self.log_floor)
        return tree, extra

    def replay(self, batches: List[MutationBatch]) -> None:
        """Recovery path: apply acknowledged batches in log order onto a
        freshly-built instance."""
        for b in batches:
            self.apply_mutations(b)
