# The paper's primary contribution: the TOTEM hybrid graph engine in JAX —
# CSR partitioning, the hybrid performance model, and the BSP runtime.
from repro.core import graph, partition, perf_model
from repro.core.bsp import BSPEngine, DistributedBSPEngine, VertexProgram

__all__ = ["graph", "partition", "perf_model", "BSPEngine",
           "DistributedBSPEngine", "VertexProgram"]
