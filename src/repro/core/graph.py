"""Graph containers and synthetic workload generators.

The paper (§5.1) evaluates on real scale-free graphs (Twitter, UK-WEB) and
synthetic RMAT / uniform (Erdős–Rényi) graphs.  This module provides the CSR
container plus RMAT and uniform generators with the paper's parameters
((A,B,C) = (0.57, 0.19, 0.19), average degree 16).

Everything here is *preprocessing*: plain numpy, amortized cost, excluded from
timed regions — the same methodology as the paper (§5, "Time Measurements").
"""
from __future__ import annotations

import collections
import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

# The paper's RMAT parameters (Table 2).
RMAT_A, RMAT_B, RMAT_C = 0.57, 0.19, 0.19
DEFAULT_EDGE_FACTOR = 16


@dataclasses.dataclass
class CSRGraph:
    """Compressed Sparse Row graph (paper §4.3.1).

    ``row_ptr[v]:row_ptr[v+1]`` indexes ``col`` with the out-neighbours of
    ``v``.  ``weights`` is optional (SSSP).  Vertex ids are dense ``[0, n)``.
    """

    row_ptr: np.ndarray       # int64 [num_vertices + 1]
    col: np.ndarray           # int32/int64 [num_edges]
    weights: Optional[np.ndarray] = None  # float32 [num_edges] or None

    @property
    def num_vertices(self) -> int:
        return len(self.row_ptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.col)

    def out_degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.col, minlength=self.num_vertices)

    def edge_sources(self) -> np.ndarray:
        """Expand row_ptr into a per-edge source-vertex array."""
        return np.repeat(
            np.arange(self.num_vertices, dtype=self.col.dtype),
            self.out_degrees(),
        )

    def reverse(self) -> "CSRGraph":
        """Transpose (in-edges become out-edges); weights carried along."""
        src = self.edge_sources()
        order = np.argsort(self.col, kind="stable")
        rcol = src[order]
        rrow = np.zeros(self.num_vertices + 1, dtype=np.int64)
        np.add.at(rrow, self.col + 1, 1)
        rrow = np.cumsum(rrow)
        rw = self.weights[order] if self.weights is not None else None
        return CSRGraph(rrow, rcol.astype(self.col.dtype), rw)

    def with_uniform_weights(self, lo: float = 1.0, hi: float = 64.0,
                             seed: int = 0) -> "CSRGraph":
        rng = np.random.default_rng(seed)
        w = rng.uniform(lo, hi, size=self.num_edges).astype(np.float32)
        return CSRGraph(self.row_ptr, self.col, w)


def _validate_edge_list(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                        weights: Optional[np.ndarray], what: str):
    """Actionable errors for malformed edge input — without this, bad ids
    fail deep inside partitioning with an opaque shape/index error."""
    if len(src) != len(dst):
        raise ValueError(
            f"{what}: src/dst length mismatch — len(src)={len(src)} vs "
            f"len(dst)={len(dst)}; each edge needs one entry in both")
    if weights is not None and len(weights) != len(src):
        raise ValueError(
            f"{what}: weights length {len(weights)} != num edges "
            f"{len(src)}; pass one weight per edge or None")
    if len(src):
        lo = int(min(src.min(), dst.min()))
        hi = int(max(src.max(), dst.max()))
        if lo < 0 or hi >= num_vertices:
            raise ValueError(
                f"{what}: vertex ids must lie in [0, num_vertices="
                f"{num_vertices}); got min={lo}, max={hi} — negative ids "
                f"or ids >= num_vertices corrupt the CSR row pointer")
    if weights is not None:
        w = np.asarray(weights, dtype=np.float64)
        bad = np.flatnonzero(~np.isfinite(w))
        if len(bad):
            i = int(bad[0])
            raise ValueError(
                f"{what}: weights must be finite — weights[{i}] = {w[i]} "
                f"({len(bad)} non-finite entries); NaN/inf weights poison "
                f"every shortest-path query touching the edge")


def from_edge_list(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                   weights: Optional[np.ndarray] = None,
                   dedup: bool = False) -> CSRGraph:
    """Build CSR from a (src, dst) edge list.  Sorts by (src, dst)."""
    src, dst = np.asarray(src), np.asarray(dst)
    _validate_edge_list(src, dst, num_vertices, weights, "from_edge_list")
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    if weights is not None:
        weights = weights[order]
    if dedup:
        keep = np.ones(len(src), dtype=bool)
        keep[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
        src, dst = src[keep], dst[keep]
        if weights is not None:
            weights = weights[keep]
    row_ptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.add.at(row_ptr, src + 1, 1)
    row_ptr = np.cumsum(row_ptr)
    dtype = np.int32 if num_vertices < 2**31 else np.int64
    return CSRGraph(row_ptr, dst.astype(dtype), weights)


def rmat(scale: int, edge_factor: int = DEFAULT_EDGE_FACTOR,
         a: float = RMAT_A, b: float = RMAT_B, c: float = RMAT_C,
         seed: int = 1, dedup: bool = False) -> CSRGraph:
    """Recursive-MATrix generator [Chakrabarti et al. 2004], paper Table 2.

    Directed (the paper notes its graphs are directed, unlike Graph500).
    Vectorized bit-by-bit sampling: per edge, each of ``scale`` bits of
    (src, dst) picks one of the four quadrants with probs (a, b, c, d).
    """
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    a_frac = a / ab
    c_frac = c / (1.0 - ab)
    for _ in range(scale):
        src_bit = rng.random(m) > ab
        dst_thresh = np.where(src_bit, c_frac, a_frac)
        dst_bit = rng.random(m) > dst_thresh
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return from_edge_list(src, dst, n, dedup=dedup)


def uniform(scale: int, edge_factor: int = DEFAULT_EDGE_FACTOR,
            seed: int = 1) -> CSRGraph:
    """Erdős–Rényi-style uniform graph (paper's UNIFORM28 baseline)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    return from_edge_list(src, dst, n)


# ---------------------------------------------------------------------------
# Edge mutations (the dynamic-graph subsystem, core/dynamic.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MutationBatch:
    """One batch of edge mutations, applied atomically between supersteps.

    ``insert[i]`` selects the operation for edge ``(src[i], dst[i])``: True
    inserts a new instance, False deletes one *existing* instance (FIFO over
    parallel edges — see :class:`EdgeLedger`; deleting an absent edge is an
    error).  ``weight`` carries insert weights on weighted graphs and is
    ignored for deletes.  Vertex ids must stay inside the graph's fixed
    ``[0, n)`` id space: mutation is an edge-set axis, not a vertex axis.
    """

    src: np.ndarray                    # int64 [m]
    dst: np.ndarray                    # int64 [m]
    insert: np.ndarray                 # bool [m]
    weight: Optional[np.ndarray] = None  # float32 [m] or None

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int64).reshape(-1)
        self.dst = np.asarray(self.dst, dtype=np.int64).reshape(-1)
        self.insert = np.asarray(self.insert, dtype=bool).reshape(-1)
        if self.weight is not None:
            self.weight = np.asarray(self.weight,
                                     dtype=np.float32).reshape(-1)
        m = len(self.src)
        for name in ("dst", "insert"):
            arr = getattr(self, name)
            if len(arr) != m:
                raise ValueError(
                    f"MutationBatch: len({name})={len(arr)} != len(src)="
                    f"{m}; every edge needs one src, dst, and insert entry")
        if self.weight is not None:
            if len(self.weight) != m:
                raise ValueError(
                    f"MutationBatch: len(weight)={len(self.weight)} != "
                    f"len(src)={m}; pass one weight per edge or None")
            bad = np.flatnonzero(~np.isfinite(self.weight))
            if len(bad):
                i = int(bad[0])
                raise ValueError(
                    f"MutationBatch: weight[{i}] = {self.weight[i]} is not "
                    f"finite ({len(bad)} such entries); NaN/inf insert "
                    f"weights poison shortest-path state")
        if m and (int(self.src.min()) < 0 or int(self.dst.min()) < 0):
            raise ValueError(
                "MutationBatch: negative vertex ids — ids must lie in the "
                "graph's fixed [0, n) id space")

    def validate(self, num_vertices: int):
        """Range-check ids against a concrete graph (called on apply)."""
        if len(self) == 0:
            return
        hi = int(max(self.src.max(), self.dst.max()))
        if hi >= num_vertices:
            raise ValueError(
                f"MutationBatch: vertex id {hi} out of range for a graph "
                f"with num_vertices={num_vertices}; mutation is an edge-set "
                f"axis, not a vertex axis — grow the graph by rebuilding")

    def __len__(self) -> int:
        return len(self.src)

    @property
    def num_inserts(self) -> int:
        return int(self.insert.sum())

    @property
    def num_deletes(self) -> int:
        return len(self) - self.num_inserts

    @property
    def monotone(self) -> bool:
        """Insert-only batches preserve min/min-plus monotonicity (adding
        edges can only lower a least-fixpoint), so warm-starting from the
        previous solution stays exact; any delete breaks that."""
        return self.num_deletes == 0


class EdgeLedger:
    """The host-side multiset of live edge instances.

    The single source of truth for *which* instance a delete removes:
    parallel edges form a FIFO per ``(src, dst)`` pair (base instances in
    CSR order, inserts in arrival order), and a delete pops the oldest live
    instance.  Every consumer of mutation semantics — the dynamic graph's
    delta planner, the edge-stream generator, and the from-scratch rebuild
    oracle (:func:`apply_mutation_batches`) — shares this rule, so a
    mutated graph has exactly one canonical CSR.
    """

    def __init__(self, g: CSRGraph):
        src = g.edge_sources()
        self._src: List[int] = src.tolist()
        self._dst: List[int] = g.col.tolist()
        self._w: Optional[List[float]] = (
            g.weights.tolist() if g.weights is not None else None)
        self._alive = np.ones(len(self._src), dtype=bool)
        self._num_alive = len(self._src)
        self.num_base = len(self._src)
        # Vectorized FIFO build: group edge ids by (src, dst) with one
        # stable lexsort — per-*pair* Python work instead of per-edge
        # (construction and compact() both pay this at |E| scale).
        self._fifo = collections.defaultdict(collections.deque)
        if len(src):
            order = np.lexsort((g.col, src))     # stable: ids stay FIFO
            s_s, s_d = src[order], g.col[order]
            cuts = np.flatnonzero((s_s[1:] != s_s[:-1])
                                  | (s_d[1:] != s_d[:-1])) + 1
            for grp in np.split(order, cuts):
                self._fifo[(int(src[grp[0]]),
                            int(g.col[grp[0]]))] = collections.deque(
                    grp.tolist())

    def __len__(self) -> int:
        return self._num_alive

    def insert(self, u: int, v: int, w: Optional[float]) -> int:
        """Append a new instance; returns its instance id."""
        iid = len(self._src)
        self._src.append(int(u))
        self._dst.append(int(v))
        if self._w is not None:
            self._w.append(float(w if w is not None else 1.0))
        if iid >= len(self._alive):
            self._alive = np.concatenate(
                [self._alive, np.ones(max(len(self._alive), 64), dtype=bool)])
        self._alive[iid] = True
        self._num_alive += 1
        self._fifo[(int(u), int(v))].append(iid)
        return iid

    def delete(self, u: int, v: int) -> Tuple[int, Optional[float]]:
        """Remove the oldest live instance of ``(u, v)``; returns (iid, w)."""
        q = self._fifo.get((int(u), int(v)))
        if not q:
            raise KeyError(f"delete of absent edge ({u}, {v})")
        iid = q.popleft()
        self._alive[iid] = False
        self._num_alive -= 1
        w = self._w[iid] if self._w is not None else None
        return iid, w

    def apply(self, batch: "MutationBatch") -> None:
        """Replay one batch in order — THE mutation-semantics loop, shared
        by the rebuild oracle and the stream generator (the dynamic graph
        interleaves the same calls with its layout planning)."""
        w = batch.weight
        for i in range(len(batch)):
            if batch.insert[i]:
                self.insert(batch.src[i], batch.dst[i],
                            w[i] if w is not None else None)
            else:
                self.delete(batch.src[i], batch.dst[i])

    def alive_weights(self, u: int, v: int) -> List[float]:
        """⊗-relevant weights of the live instances of ``(u, v)``, FIFO
        order (1.0 each on unweighted graphs)."""
        ids = self._fifo.get((int(u), int(v)), ())
        if self._w is None:
            return [1.0] * len(ids)
        return [self._w[i] for i in ids]

    def alive_count(self, u: int, v: int) -> int:
        return len(self._fifo.get((int(u), int(v)), ()))

    def edge_list(self):
        """Live instances as (src, dst, weights-or-None) arrays, instance-id
        (base-then-arrival) order."""
        alive = self._alive[: len(self._src)]
        src = np.asarray(self._src, dtype=np.int64)[alive]
        dst = np.asarray(self._dst, dtype=np.int64)[alive]
        w = (np.asarray(self._w, dtype=np.float32)[alive]
             if self._w is not None else None)
        return src, dst, w

    def sample_alive(self, rng: np.random.Generator, k: int):
        """Sample ``k`` distinct live instances (for delete streams);
        returns (src, dst) arrays."""
        ids = np.flatnonzero(self._alive[: len(self._src)])
        pick = rng.choice(ids, size=min(k, len(ids)), replace=False)
        src = np.asarray(self._src, dtype=np.int64)[pick]
        dst = np.asarray(self._dst, dtype=np.int64)[pick]
        return src, dst

    def to_csr(self, num_vertices: int) -> CSRGraph:
        """Canonical CSR of the live multiset (``from_edge_list`` order)."""
        src, dst, w = self.edge_list()
        return from_edge_list(src, dst, num_vertices, weights=w)


def apply_mutation_batches(g: CSRGraph,
                           batches: Sequence[MutationBatch]) -> CSRGraph:
    """From-scratch rebuild oracle: replay ``batches`` over ``g`` through an
    :class:`EdgeLedger` and emit the canonical mutated CSR.  The dynamic
    graph's ``mutated_csr()`` must equal this for the same batches — the
    incremental contract's ground truth."""
    ledger = EdgeLedger(g)
    for batch in batches:
        ledger.apply(batch)
    return ledger.to_csr(g.num_vertices)


def to_dense(g: CSRGraph) -> np.ndarray:
    """Dense adjacency (testing only — small graphs)."""
    a = np.zeros((g.num_vertices, g.num_vertices), dtype=np.float32)
    src = g.edge_sources()
    vals = g.weights if g.weights is not None else np.ones(g.num_edges,
                                                           dtype=np.float32)
    # += semantics for multi-edges.
    np.add.at(a, (src, g.col), vals)
    return a
