"""Graph containers and synthetic workload generators.

The paper (§5.1) evaluates on real scale-free graphs (Twitter, UK-WEB) and
synthetic RMAT / uniform (Erdős–Rényi) graphs.  This module provides the CSR
container plus RMAT and uniform generators with the paper's parameters
((A,B,C) = (0.57, 0.19, 0.19), average degree 16).

Everything here is *preprocessing*: plain numpy, amortized cost, excluded from
timed regions — the same methodology as the paper (§5, "Time Measurements").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# The paper's RMAT parameters (Table 2).
RMAT_A, RMAT_B, RMAT_C = 0.57, 0.19, 0.19
DEFAULT_EDGE_FACTOR = 16


@dataclasses.dataclass
class CSRGraph:
    """Compressed Sparse Row graph (paper §4.3.1).

    ``row_ptr[v]:row_ptr[v+1]`` indexes ``col`` with the out-neighbours of
    ``v``.  ``weights`` is optional (SSSP).  Vertex ids are dense ``[0, n)``.
    """

    row_ptr: np.ndarray       # int64 [num_vertices + 1]
    col: np.ndarray           # int32/int64 [num_edges]
    weights: Optional[np.ndarray] = None  # float32 [num_edges] or None

    @property
    def num_vertices(self) -> int:
        return len(self.row_ptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.col)

    def out_degrees(self) -> np.ndarray:
        return np.diff(self.row_ptr)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.col, minlength=self.num_vertices)

    def edge_sources(self) -> np.ndarray:
        """Expand row_ptr into a per-edge source-vertex array."""
        return np.repeat(
            np.arange(self.num_vertices, dtype=self.col.dtype),
            self.out_degrees(),
        )

    def reverse(self) -> "CSRGraph":
        """Transpose (in-edges become out-edges); weights carried along."""
        src = self.edge_sources()
        order = np.argsort(self.col, kind="stable")
        rcol = src[order]
        rrow = np.zeros(self.num_vertices + 1, dtype=np.int64)
        np.add.at(rrow, self.col + 1, 1)
        rrow = np.cumsum(rrow)
        rw = self.weights[order] if self.weights is not None else None
        return CSRGraph(rrow, rcol.astype(self.col.dtype), rw)

    def with_uniform_weights(self, lo: float = 1.0, hi: float = 64.0,
                             seed: int = 0) -> "CSRGraph":
        rng = np.random.default_rng(seed)
        w = rng.uniform(lo, hi, size=self.num_edges).astype(np.float32)
        return CSRGraph(self.row_ptr, self.col, w)


def from_edge_list(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                   weights: Optional[np.ndarray] = None,
                   dedup: bool = False) -> CSRGraph:
    """Build CSR from a (src, dst) edge list.  Sorts by (src, dst)."""
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    if weights is not None:
        weights = weights[order]
    if dedup:
        keep = np.ones(len(src), dtype=bool)
        keep[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
        src, dst = src[keep], dst[keep]
        if weights is not None:
            weights = weights[keep]
    row_ptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.add.at(row_ptr, src + 1, 1)
    row_ptr = np.cumsum(row_ptr)
    dtype = np.int32 if num_vertices < 2**31 else np.int64
    return CSRGraph(row_ptr, dst.astype(dtype), weights)


def rmat(scale: int, edge_factor: int = DEFAULT_EDGE_FACTOR,
         a: float = RMAT_A, b: float = RMAT_B, c: float = RMAT_C,
         seed: int = 1, dedup: bool = False) -> CSRGraph:
    """Recursive-MATrix generator [Chakrabarti et al. 2004], paper Table 2.

    Directed (the paper notes its graphs are directed, unlike Graph500).
    Vectorized bit-by-bit sampling: per edge, each of ``scale`` bits of
    (src, dst) picks one of the four quadrants with probs (a, b, c, d).
    """
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    a_frac = a / ab
    c_frac = c / (1.0 - ab)
    for _ in range(scale):
        src_bit = rng.random(m) > ab
        dst_thresh = np.where(src_bit, c_frac, a_frac)
        dst_bit = rng.random(m) > dst_thresh
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return from_edge_list(src, dst, n, dedup=dedup)


def uniform(scale: int, edge_factor: int = DEFAULT_EDGE_FACTOR,
            seed: int = 1) -> CSRGraph:
    """Erdős–Rényi-style uniform graph (paper's UNIFORM28 baseline)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    return from_edge_list(src, dst, n)


def to_dense(g: CSRGraph) -> np.ndarray:
    """Dense adjacency (testing only — small graphs)."""
    a = np.zeros((g.num_vertices, g.num_vertices), dtype=np.float32)
    src = g.edge_sources()
    vals = g.weights if g.weights is not None else np.ones(g.num_edges,
                                                           dtype=np.float32)
    # += semantics for multi-edges.
    np.add.at(a, (src, g.col), vals)
    return a
