"""The hybrid two-engine step — TOTEM's CPU/GPU split, TPU-native.

The paper assigns the few high-degree vertices to the CPU (cache-friendly)
and the many low-degree vertices to the GPU (latency-hiding) — §6.2.  A TPU
chip is homogeneous silicon but has two *execution paths* with exactly the
same duality:

  - the **MXU** (systolic matmul): the high-degree block's adjacency is dense
    enough that SpMV-as-GEMM wins (kernels/dense_spmv);
  - the **VPU + HBM streaming** path: the low-degree remainder has a tight
    degree bound, ideal for ELLPACK row-block streaming (kernels/ell_spmv).

``degree_split`` plays the role of the paper's HIGH partitioning: vertices
are ranked by (in+out) degree, the top-K become the dense block H, and every
edge inside H×H moves to the dense engine; the rest stays sparse.

Generalized semirings (one per TOTEM reduction class, §3.4) make the split a
backend for *every* vertex program, not just SpMV-style PageRank:

  - ``plus_times`` — y[v] = Σ x[u]·w(u,v)        (PageRank, BC)
  - ``min_plus``   — y[v] = min x[u]+w(u,v)      (BFS, SSSP)
  - ``min``        — y[v] = min x[u]             (CC label propagation)

``auto_degree_split`` drives |H| from the performance model: candidate splits
are ranked by ``perf_model.hybrid_makespan_tpu`` (the role Eq. 4 plays in the
paper) and the argmin wins — which may be 0 (pure sparse) or the whole graph
(pure dense); ``HybridGraph.mode`` reports which engine(s) actually run.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph, from_edge_list
from repro.core import perf_model
from repro.kernels import ops as kops
from repro.kernels.ell_spmv import SEMIRINGS

PLUS_TIMES = "plus_times"
MIN_PLUS = "min_plus"
MIN_SR = "min"


def add_identity(semiring: str) -> float:
    """⊕-identity of a semiring (0 for sum, +inf for min)."""
    return SEMIRINGS[semiring][2]


@dataclasses.dataclass
class HybridGraph:
    """Degree-split graph: dense H×H block + ELL remainder (pull form)."""

    num_vertices: int
    num_edges: int
    k_dense: int                 # |H| (0 → pure sparse)
    perm: np.ndarray             # new id -> old id (degree-descending)
    inv_perm: np.ndarray         # old id -> new id
    dense_block: np.ndarray      # [K, K] f32 (⊗ values; ⊕-identity non-edges)
    ell_col: np.ndarray          # [V, kmax] int32 (pull: in-neighbours)
    ell_val: np.ndarray          # [V, kmax] f32
    out_deg: np.ndarray          # [V] f32 in new id space (true out-degree)
    dense_edges: int             # edges handled by the MXU path
    sparse_edges: int            # edges handled by the ELL path
    semiring: str = PLUS_TIMES
    model_table: Optional[List[dict]] = None  # perf-model ranking (auto split)

    @property
    def dense_density(self) -> float:
        return self.dense_edges / max(self.k_dense ** 2, 1)

    @property
    def dense_fraction(self) -> float:
        return self.dense_edges / max(self.num_edges, 1)

    @property
    def mode(self) -> str:
        """Which engine(s) this split runs: dense, sparse, or hybrid."""
        return perf_model.split_mode(self.k_dense, self.num_vertices,
                                     self.sparse_edges)

    def predicted_makespan(self, num_chips: int = 1) -> dict:
        return perf_model.hybrid_makespan_tpu(
            self.dense_edges, self.dense_density, self.sparse_edges,
            boundary_slots=0, num_chips=num_chips)


def _degree_perm(g: CSRGraph):
    """Degree-descending vertex ranking (new -> old) and its inverse."""
    total_deg = g.out_degrees() + g.in_degrees()
    perm = np.argsort(-total_deg, kind="stable")       # new -> old
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return perm, inv


def edge_max_ranks(g: CSRGraph) -> np.ndarray:
    """Per-edge max(rank(src), rank(dst)) under the degree ranking.

    ``e_dense(k) = #{edges with max rank < k}`` — the perf model's input for
    ranking candidate splits (symmetric under graph reversal, so one table
    serves both edge directions).
    """
    _, inv = _degree_perm(g)
    return np.maximum(inv[g.edge_sources()], inv[g.col])


def degree_split(g: CSRGraph, k_dense: int,
                 semiring: str = PLUS_TIMES) -> HybridGraph:
    """Split ``g``: top-``k_dense`` degree vertices → dense block.

    Edge ⊗ values follow the semiring (kernels/ops.csr_to_ell): weights where
    the graph has them, multiplicity counts (``plus_times``) or zero-cost
    hops (``min_plus``) otherwise.  Multi-edges accumulate with ⊕ in the
    dense block, matching the reference engine's per-edge reduction.
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}")
    perm, inv = _degree_perm(g)
    src = inv[g.edge_sources()]
    dst = inv[g.col]
    if semiring == PLUS_TIMES:
        w = (g.weights if g.weights is not None
             else np.ones(g.num_edges, dtype=np.float32))
    elif semiring == MIN_PLUS:
        w = (g.weights if g.weights is not None
             else np.zeros(g.num_edges, dtype=np.float32))
    else:  # pure min: edge values are irrelevant, hop cost 0
        w = np.zeros(g.num_edges, dtype=np.float32)

    in_h = (src < k_dense) & (dst < k_dense)
    dense = np.full((k_dense, k_dense), add_identity(semiring),
                    dtype=np.float32)
    if k_dense:
        if semiring == PLUS_TIMES:
            np.add.at(dense, (src[in_h], dst[in_h]), w[in_h])
        else:
            np.minimum.at(dense, (src[in_h], dst[in_h]), w[in_h])

    rest = ~in_h
    # Attach explicit per-edge values (w holds the per-semiring defaults) so
    # the ELL packing always matches the dense block, independent of
    # csr_to_ell's unweighted fallbacks; pure-min values are never read.
    rest_w = w[rest] if semiring != MIN_SR else None
    g_rest = from_edge_list(src[rest], dst[rest], g.num_vertices,
                            weights=rest_w)
    col, val, _ = kops.csr_to_ell(g_rest, semiring=semiring, transpose=True)

    deg = g.out_degrees().astype(np.float32)[perm]
    return HybridGraph(
        num_vertices=g.num_vertices, num_edges=g.num_edges, k_dense=k_dense,
        perm=perm, inv_perm=inv, dense_block=dense, ell_col=col, ell_val=val,
        out_deg=deg, dense_edges=int(in_h.sum()), sparse_edges=int(rest.sum()),
        semiring=semiring)


def auto_degree_split(g: CSRGraph, semiring: str = PLUS_TIMES,
                      candidates=None, skewed: bool = True,
                      num_chips: int = 1) -> HybridGraph:
    """Degree split with |H| chosen by the performance model (Eq. 4 role).

    Ranks ``candidates`` (default: ``perf_model.k_dense_candidates``; pass
    ``skewed=False`` when the partition block-span histograms show no
    high-degree concentration) by predicted makespan and splits at the
    argmin.  The ranking table rides on the result for introspection.
    """
    if candidates is None:
        candidates = perf_model.k_dense_candidates(g.num_vertices,
                                                   skewed=skewed)
    k, table = perf_model.choose_k_dense(edge_max_ranks(g), g.num_edges,
                                         candidates, num_chips=num_chips)
    hg = degree_split(g, k, semiring=semiring)
    hg.model_table = table
    return hg


def hybrid_spmv(dense: jax.Array, ell_col: jax.Array, ell_val: jax.Array,
                x: jax.Array, *, semiring: str, k_dense: int,
                interpret: Optional[bool] = None) -> jax.Array:
    """One generalized two-engine step: y[v] = ⊕ over in-edges x[u] ⊗ w.

    The dense H×H block runs on the MXU path (plus_times) or its tropical
    twin (min_plus/min); the remainder streams through the ELL kernel.  ``x``
    is the per-source value vector in hybrid (degree-ranked) id space.
    """
    ident = add_identity(semiring)
    xs = jnp.concatenate([x, jnp.full((1,), ident, x.dtype)])
    y = kops.ell_spmv_op(ell_col, ell_val, xs, semiring=semiring,
                         interpret=interpret)
    if k_dense:
        if semiring == PLUS_TIMES:
            yh = kops.dense_spmv_op(x[None, :k_dense], dense,
                                    interpret=interpret)[0]
            y = y.at[:k_dense].add(yh)
        else:
            yh = kops.dense_spmv_minplus_op(x[None, :k_dense], dense,
                                            interpret=interpret)[0]
            y = y.at[:k_dense].min(yh)
    return y


def hybrid_pagerank(hg: HybridGraph, num_iterations: int = 20,
                    damping: float = 0.85,
                    interpret: Optional[bool] = None) -> np.ndarray:
    """PageRank where H×H runs on the MXU path, the rest on the ELL path.

    Returns ranks in the *original* vertex id order.
    """
    if hg.semiring != PLUS_TIMES:
        raise ValueError("hybrid_pagerank needs a plus_times split")
    n = hg.num_vertices
    dense = jnp.asarray(hg.dense_block)
    col = jnp.asarray(hg.ell_col)
    val = jnp.asarray(hg.ell_val)
    inv_deg = jnp.asarray(np.where(hg.out_deg > 0,
                                   1.0 / np.maximum(hg.out_deg, 1.0), 0.0))
    delta = (1.0 - damping) / n

    @jax.jit
    def step(rank):
        contrib = rank * inv_deg
        y = hybrid_spmv(dense, col, val, contrib, semiring=PLUS_TIMES,
                        k_dense=hg.k_dense, interpret=interpret)
        return delta + damping * y

    rank = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    for _ in range(num_iterations):
        rank = step(rank)
    out = np.asarray(rank)
    result = np.empty_like(out)
    result[hg.perm] = out          # back to original id order
    return result
