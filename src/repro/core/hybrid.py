"""The hybrid two-engine step — TOTEM's CPU/GPU split, TPU-native.

The paper assigns the few high-degree vertices to the CPU (cache-friendly)
and the many low-degree vertices to the GPU (latency-hiding) — §6.2.  A TPU
chip is homogeneous silicon but has two *execution paths* with exactly the
same duality:

  - the **MXU** (systolic matmul): the high-degree block's adjacency is dense
    enough that SpMV-as-GEMM wins (kernels/dense_spmv);
  - the **VPU + HBM streaming** path: the low-degree remainder has a tight
    degree bound, ideal for ELLPACK row-block streaming (kernels/ell_spmv).

``degree_split`` plays the role of the paper's HIGH partitioning: vertices
are ranked by (in+out) degree, the top-K become the dense block H, and every
edge inside H×H moves to the dense engine; the rest stays sparse.

Generalized semirings (one per TOTEM reduction class, §3.4) make the split a
backend for *every* vertex program, not just SpMV-style PageRank:

  - ``plus_times`` — y[v] = Σ x[u]·w(u,v)        (PageRank, BC)
  - ``min_plus``   — y[v] = min x[u]+w(u,v)      (BFS, SSSP)
  - ``min``        — y[v] = min x[u]             (CC label propagation)

``auto_degree_split`` drives |H| from the performance model: candidate splits
are ranked by ``perf_model.hybrid_makespan_tpu`` (the role Eq. 4 plays in the
paper) and the argmin wins — which may be 0 (pure sparse) or the whole graph
(pure dense); ``HybridGraph.mode`` reports which engine(s) actually run.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph, from_edge_list
from repro.core import perf_model
from repro.core.partition import (EdgeArrays, PartitionedGraph,
                                  _round_up, boundary_edges,
                                  build_block_metadata)
from repro.kernels import ops as kops
from repro.kernels.ell_spmv import SEMIRINGS

PLUS_TIMES = "plus_times"
MIN_PLUS = "min_plus"
MIN_SR = "min"


def add_identity(semiring: str) -> float:
    """⊕-identity of a semiring (0 for sum, +inf for min)."""
    return SEMIRINGS[semiring][2]


@dataclasses.dataclass
class HybridGraph:
    """Degree-split graph: dense H×H block + ELL remainder (pull form)."""

    num_vertices: int
    num_edges: int
    k_dense: int                 # |H| (0 → pure sparse)
    perm: np.ndarray             # new id -> old id (degree-descending)
    inv_perm: np.ndarray         # old id -> new id
    dense_block: np.ndarray      # [K, K] f32 (⊗ values; ⊕-identity non-edges)
    ell_col: np.ndarray          # [V, kmax] int32 (pull: in-neighbours)
    ell_val: np.ndarray          # [V, kmax] f32
    out_deg: np.ndarray          # [V] f32 in new id space (true out-degree)
    dense_edges: int             # edges handled by the MXU path
    sparse_edges: int            # edges handled by the ELL path
    semiring: str = PLUS_TIMES
    model_table: Optional[List[dict]] = None  # perf-model ranking (auto split)

    @property
    def dense_density(self) -> float:
        return self.dense_edges / max(self.k_dense ** 2, 1)

    @property
    def dense_fraction(self) -> float:
        return self.dense_edges / max(self.num_edges, 1)

    @property
    def mode(self) -> str:
        """Which engine(s) this split runs: dense, sparse, or hybrid."""
        return perf_model.split_mode(self.k_dense, self.num_vertices,
                                     self.sparse_edges)

    def predicted_makespan(self, num_chips: int = 1) -> dict:
        return perf_model.hybrid_makespan_tpu(
            self.dense_edges, self.dense_density, self.sparse_edges,
            boundary_slots=0, num_chips=num_chips)


def _degree_perm(g: CSRGraph):
    """Degree-descending vertex ranking (new -> old) and its inverse."""
    total_deg = g.out_degrees() + g.in_degrees()
    perm = np.argsort(-total_deg, kind="stable")       # new -> old
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return perm, inv


def edge_max_ranks(g: CSRGraph) -> np.ndarray:
    """Per-edge max(rank(src), rank(dst)) under the degree ranking.

    ``e_dense(k) = #{edges with max rank < k}`` — the perf model's input for
    ranking candidate splits (symmetric under graph reversal, so one table
    serves both edge directions).
    """
    _, inv = _degree_perm(g)
    return np.maximum(inv[g.edge_sources()], inv[g.col])


def degree_split(g: CSRGraph, k_dense: int,
                 semiring: str = PLUS_TIMES) -> HybridGraph:
    """Split ``g``: top-``k_dense`` degree vertices → dense block.

    Edge ⊗ values follow the semiring (kernels/ops.csr_to_ell): weights where
    the graph has them, multiplicity counts (``plus_times``) or zero-cost
    hops (``min_plus``) otherwise.  Multi-edges accumulate with ⊕ in the
    dense block, matching the reference engine's per-edge reduction.
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}")
    perm, inv = _degree_perm(g)
    src = inv[g.edge_sources()]
    dst = inv[g.col]
    if semiring == PLUS_TIMES:
        w = (g.weights if g.weights is not None
             else np.ones(g.num_edges, dtype=np.float32))
    elif semiring == MIN_PLUS:
        w = (g.weights if g.weights is not None
             else np.zeros(g.num_edges, dtype=np.float32))
    else:  # pure min: edge values are irrelevant, hop cost 0
        w = np.zeros(g.num_edges, dtype=np.float32)

    in_h = (src < k_dense) & (dst < k_dense)
    dense = np.full((k_dense, k_dense), add_identity(semiring),
                    dtype=np.float32)
    if k_dense:
        if semiring == PLUS_TIMES:
            np.add.at(dense, (src[in_h], dst[in_h]), w[in_h])
        else:
            np.minimum.at(dense, (src[in_h], dst[in_h]), w[in_h])

    rest = ~in_h
    # Attach explicit per-edge values (w holds the per-semiring defaults) so
    # the ELL packing always matches the dense block, independent of
    # csr_to_ell's unweighted fallbacks; pure-min values are never read.
    rest_w = w[rest] if semiring != MIN_SR else None
    g_rest = from_edge_list(src[rest], dst[rest], g.num_vertices,
                            weights=rest_w)
    col, val, _ = kops.csr_to_ell(g_rest, semiring=semiring, transpose=True)

    deg = g.out_degrees().astype(np.float32)[perm]
    return HybridGraph(
        num_vertices=g.num_vertices, num_edges=g.num_edges, k_dense=k_dense,
        perm=perm, inv_perm=inv, dense_block=dense, ell_col=col, ell_val=val,
        out_deg=deg, dense_edges=int(in_h.sum()), sparse_edges=int(rest.sum()),
        semiring=semiring)


def auto_degree_split(g: CSRGraph, semiring: str = PLUS_TIMES,
                      candidates=None, skewed: bool = True,
                      num_chips: int = 1) -> HybridGraph:
    """Degree split with |H| chosen by the performance model (Eq. 4 role).

    Ranks ``candidates`` (default: ``perf_model.k_dense_candidates``; pass
    ``skewed=False`` when the partition block-span histograms show no
    high-degree concentration) by predicted makespan and splits at the
    argmin.  The ranking table rides on the result for introspection.
    """
    if candidates is None:
        candidates = perf_model.k_dense_candidates(g.num_vertices,
                                                   skewed=skewed)
    k, table = perf_model.choose_k_dense(edge_max_ranks(g), g.num_edges,
                                         candidates, num_chips=num_chips)
    hg = degree_split(g, k, semiring=semiring)
    hg.model_table = table
    return hg


def hybrid_spmv(dense: jax.Array, ell_col: jax.Array, ell_val: jax.Array,
                x: jax.Array, *, semiring: str, k_dense: int,
                interpret: Optional[bool] = None) -> jax.Array:
    """One generalized two-engine step: y[v] = ⊕ over in-edges x[u] ⊗ w.

    The dense H×H block runs on the MXU path (plus_times) or its tropical
    twin (min_plus/min); the remainder streams through the ELL kernel.  ``x``
    is the per-source value vector in hybrid (degree-ranked) id space — or a
    ``[Q, n]`` *query batch* of such vectors, in which case the batch rides
    the MXU's M axis (SpMV becomes SpMM: Q concurrent traversals amortize
    one pass over the resident adjacency) and the ELL kernel's leading grid
    axis; returns ``[Q, n]``.
    """
    ident = add_identity(semiring)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    q = x.shape[0]
    xs = jnp.concatenate([x, jnp.full((q, 1), ident, x.dtype)], axis=1)
    y = kops.ell_spmv_op(ell_col, ell_val, xs, semiring=semiring,
                         interpret=interpret)
    if k_dense:
        # The barriers pin the dense stage's rounding: interpret-mode
        # Pallas inlines the dot, and XLA's FMA-contraction choice for the
        # inlined reduction depends on the surrounding fusion context.  The
        # resident while_loop body and the out-of-core tiered jits (which
        # assemble y across jit boundaries) must round identically, so the
        # dense stage is compiled as the same isolated subgraph everywhere.
        xd = jax.lax.optimization_barrier(x[:, :k_dense])
        if semiring == PLUS_TIMES:
            yh = jax.lax.optimization_barrier(
                kops.dense_spmv_op(xd, dense, interpret=interpret))
            y = y.at[:, :k_dense].add(yh)
        else:
            yh = jax.lax.optimization_barrier(
                kops.dense_spmv_minplus_op(xd, dense, interpret=interpret))
            y = y.at[:, :k_dense].min(yh)
    return y[0] if squeeze else y


def hybrid_spmv_scan(dense: jax.Array, ell_col: jax.Array,
                     ell_val: Optional[jax.Array], x: jax.Array,
                     kreal: jax.Array, *, semiring: str, k_dense: int,
                     early_exit: bool = False,
                     skip: Optional[jax.Array] = None,
                     interpret: Optional[bool] = None):
    """``hybrid_spmv`` with the bottom-up scan kernel on the ELL path.

    Returns ``(y, scanned)`` where ``y`` is bitwise equal to
    ``hybrid_spmv``'s (the scan kernel's reduction is the same vectorized
    gather + row-min, and the dense MXU stage below is the identical
    barrier-pinned subgraph) and ``scanned [Q]`` sums the per-row
    early-exit work model (kernels/bottomup.py) — the slots a sequential
    bottom-up scan would examine.  ``kreal [n]`` is the per-row real slot
    count; ``skip`` [Q, n] marks rows whose value is already final under
    the uniform-frontier licence (they charge zero scanned slots — a
    sequential bottom-up visits only unvisited rows); min combines only.
    """
    ident = add_identity(semiring)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    q = x.shape[0]
    xs = jnp.concatenate([x, jnp.full((q, 1), ident, x.dtype)], axis=1)
    y, scanned = kops.bottomup_scan_op(
        ell_col, ell_val if semiring == MIN_PLUS else None, xs, kreal,
        semiring=semiring, early_exit=early_exit, skip=skip,
        interpret=interpret)
    if k_dense:
        # Same barrier discipline as hybrid_spmv — the two paths must round
        # identically so direction is purely a performance choice.
        xd = jax.lax.optimization_barrier(x[:, :k_dense])
        yh = jax.lax.optimization_barrier(
            kops.dense_spmv_minplus_op(xd, dense, interpret=interpret))
        y = y.at[:, :k_dense].min(yh)
    cnt = jnp.sum(scanned, axis=1)
    return (y[0], cnt[0]) if squeeze else (y, cnt)


# ---------------------------------------------------------------------------
# Per-shard degree split for the distributed hybrid engine (paper §4.3, §6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardHybridData:
    """One direction's per-shard degree-split + outbox data, stacked on a
    leading shard axis so it shards over the mesh under ``shard_map``.

    Each shard (device) owns ``parts_per_shard`` consecutive partitions and
    runs the two-engine step over its *intra-partition* edges in a
    shard-local degree-ranked id space (``slot``/``hid`` translate to/from
    the engine's ``[pl, v_max]`` layout).  Every inter-partition edge rides
    the outbox-slot segment space from ``partition.py`` instead: boundary
    messages are reduced into ``o_max`` slots at the source (§3.4) and only
    the *used* slots of each (shard, peer-shard) pair are exchanged —
    ``send_idx``/``recv_ids`` are the static gather/scatter maps of that
    compact ``all_to_all``, ``loc_idx``/``loc_ids`` the same-device pairs
    that never touch the wire.  All shapes are shard-uniform (padded to the
    max over shards); pad slots read/write dedicated identity sinks.
    """

    semiring: str
    num_shards: int
    parts_per_shard: int      # pl
    v_max: int
    num_parts: int            # P
    o_max: int
    k_dense: int              # uniform compiled dense-block size (max shard k)
    n_max: int                # padded per-shard hybrid vertex count
    num_slots: int            # pl * P * o_max flat outbox space per shard
    # --- stacked per-shard device data [S, ...] ---
    n_vert: np.ndarray        # [S] true hybrid vertex count per shard
    dense: np.ndarray         # [S, K, K] ⊗ values (⊕-identity non-edges)
    ell_col: np.ndarray       # [S, n_max, kmax] (sentinel = n_max)
    ell_val: np.ndarray       # [S, n_max, kmax]
    slot: np.ndarray          # [S, n_max] hybrid id -> p_local*v_max + local
    hid: np.ndarray           # [S, pl, v_max] slot -> hybrid id (pad = n_max)
    # --- boundary edges, sorted by flat outbox slot id ---
    b_src: np.ndarray         # [S, be_pad] hybrid source id (pad -> n_max)
    b_local: np.ndarray       # [S, be_pad] slot id − block base
    b_base: np.ndarray        # [S, nb] per-block base slot ids
    b_mask: np.ndarray        # [S, be_pad] 1 for real edges
    b_weight: Optional[np.ndarray]   # [S, be_pad] f32 or None
    b_span: int               # static span bound for the outbox kernel
    b_block: int              # outbox kernel block size
    # --- compact exchange maps ---
    send_idx: np.ndarray      # [S, S, w] flat outbox index (pad -> num_slots)
    recv_ids: np.ndarray      # [S, S, w] local scatter segment id
    loc_idx: np.ndarray       # [S, L] same-device flat outbox indices
    loc_ids: np.ndarray       # [S, L] same-device scatter segment ids
    wire_width: int           # w: packed slots per (shard, peer) pair
    wire_slots_used: int      # true cross-device slots summed over shards
    has_boundary: bool
    has_remote: bool
    has_local_slots: bool
    # --- push direction (min combines; None disables the switch) ---
    push_src: Optional[np.ndarray]   # [S, ei_pad] hybrid ids (pad -> n_max)
    push_dst: Optional[np.ndarray]   # [S, ei_pad]
    push_w: Optional[np.ndarray]     # [S, ei_pad] (min_plus) or None
    per_shard_k: List[int] = dataclasses.field(default_factory=list)

    @property
    def scatter_segments(self) -> int:
        """Local scatter segment space: pl*(v_max+1) reals + 1 pad sink."""
        return self.parts_per_shard * (self.v_max + 1)

    def wire_values_per_superstep(self) -> int:
        """Padded f32 buffer one shard puts on the wire each superstep (the
        SPMD all_to_all ships shard-uniform blocks; ``wire_slots_used`` is
        the aggregated payload inside them)."""
        if not self.has_remote:
            return 0
        return (self.num_shards - 1) * self.wire_width


def _shard_intra(pg: PartitionedGraph, num_shards: int, g: CSRGraph):
    """Per-shard intra-partition edge sets + degree-descending rankings.

    Ranks each shard's vertices by (in+out) degree over the *intra* edges
    only (the edges the shard's two-engine step will run); the ranking is
    direction-symmetric, so forward and reverse splits share it.  Returns
    per shard: (ranked global ids, global->hybrid inverse, intra src, intra
    dst, intra weights).
    """
    asg = pg.assignment
    pl = pg.num_parts // num_shards
    src_g, dst_g = g.edge_sources(), g.col
    sp = asg.part_of[src_g]
    intra = sp == asg.part_of[dst_g]
    shard_of_edge = sp // pl
    deg = np.zeros(pg.num_vertices, dtype=np.int64)
    np.add.at(deg, src_g[intra], 1)
    np.add.at(deg, dst_g[intra], 1)
    out = []
    for s in range(num_shards):
        verts = np.concatenate(
            [asg.l2g[p] for p in range(s * pl, (s + 1) * pl)])
        order = verts[np.argsort(-deg[verts], kind="stable")]
        inv = np.full(pg.num_vertices, -1, dtype=np.int64)
        inv[order] = np.arange(len(order))
        em = intra & (shard_of_edge == s)
        w = g.weights[em] if g.weights is not None else None
        out.append((order, inv, src_g[em], dst_g[em], w))
    return out


def shard_plan_inputs(pg: PartitionedGraph, num_shards: int, layouts=None):
    """Perf-model inputs for :func:`perf_model.plan_shards` (Eq. 1 per shard).

    Returns ``(ranks, edges, slots, nverts)``: per shard, the intra-edge
    ``max(rank(src), rank(dst))`` array, the intra edge count, the number of
    *cross-shard* outbox slots it ships per superstep (same-device peer
    slots never touch the interconnect), and its vertex count.  ``layouts``
    reuses a precomputed forward-direction ``_shard_intra`` result.
    """
    pl = pg.num_parts // num_shards
    om = pg.fwd.outbox_mask
    if layouts is None:
        layouts = _shard_intra(pg, num_shards, pg.source)
    ranks, edges, slots, nverts = [], [], [], []
    for s, (order, inv, es, ed, _) in enumerate(layouts):
        ranks.append(np.maximum(inv[es], inv[ed]))
        edges.append(len(es))
        rows = om[s * pl:(s + 1) * pl]
        slots.append(float(rows.sum() - rows[:, s * pl:(s + 1) * pl].sum()))
        nverts.append(len(order))
    return ranks, edges, slots, nverts


def _boundary_arrays(ea: EdgeArrays, asg, shard: int, pl: int, v_max: int,
                     inv: np.ndarray):
    """One shard's boundary edges as (hybrid src, flat slot id, weight);
    already sorted by flat slot id (partition.py sorts edges by ``dst_ext``
    and the flat id is p_local-major)."""
    P, o_max = ea.outbox_dst.shape[0], ea.o_max
    srcs, flats, ws = [], [], []
    for p_local in range(pl):
        p = shard * pl + p_local
        src, flat, w = boundary_edges(ea, p, v_max)
        srcs.append(inv[asg.l2g[p][src]])
        flats.append(p_local * (P * o_max) + flat)
        if w is not None:
            ws.append(w)
    return (np.concatenate(srcs), np.concatenate(flats),
            np.concatenate(ws) if ea.weight is not None else None)


def shard_degree_split(pg: PartitionedGraph, num_shards: int, semiring: str,
                       per_shard_k: Sequence[int], *,
                       use_reverse: bool = False, use_weights: bool = True,
                       direction_switch: bool = False, layouts=None,
                       block_e: int = 256, align: int = 8) -> ShardHybridData:
    """Build one direction's :class:`ShardHybridData` (numpy preprocessing).

    ``per_shard_k`` is each shard's chosen |H| (from
    :func:`perf_model.plan_shards`); the dense blocks are padded to the
    shard maximum K so the SPMD step compiles one shape, but shard ``s``
    only promotes its own top-``k_s`` edges to the MXU path — the rest stay
    in its ELL remainder, exactly its own split decision.

    ``use_weights=False`` packs the semiring defaults (multiplicity counts /
    zero-cost hops) even on a weighted graph — for programs whose
    EdgeMessage ignores the weight.  ``layouts`` reuses a precomputed
    ``_shard_intra`` result for this direction (only valid for
    ``use_reverse=False`` layouts computed on ``pg.source``).
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}")
    if pg.source is None:
        raise ValueError("per-shard split needs PartitionedGraph.source")
    asg = pg.assignment
    S, pl = num_shards, pg.num_parts // num_shards
    P, v_max = pg.num_parts, pg.v_max
    g = pg.source.reverse() if use_reverse else pg.source
    ea = pg.rev if use_reverse else pg.fwd
    if ea is None:
        raise ValueError(
            "distributed hybrid needs reverse edge/outbox arrays for "
            "use_reverse programs; partition with include_reverse=True")
    o_max = ea.o_max
    ident = add_identity(semiring)
    mul_ident = SEMIRINGS[semiring][3]
    if layouts is None or use_reverse:
        layouts = _shard_intra(pg, S, g)

    k_list = [int(k) for k in per_shard_k]
    K = max(k_list) if k_list else 0
    n_max = max(_round_up(max(len(o) for o, *_ in layouts), align), align, K)

    n_vert = np.array([len(o) for o, *_ in layouts], dtype=np.int32)
    dense = np.full((S, K, K), ident, dtype=np.float32)
    slot = np.zeros((S, n_max), dtype=np.int32)
    hid = np.full((S, pl, v_max), n_max, dtype=np.int32)
    ell_cols, ell_vals = [], []
    push = ([], [], []) if direction_switch else None

    for s, (order, inv, es, ed, ws) in enumerate(layouts):
        n_s, k_s = len(order), k_list[s]
        # slot/hid translation between [pl, v_max] layout and hybrid ids
        slot[s, :n_s] = ((asg.part_of[order] - s * pl) * v_max
                         + asg.local_id[order]).astype(np.int32)
        for p_local in range(pl):
            l2g = asg.l2g[s * pl + p_local]
            hid[s, p_local, : len(l2g)] = inv[l2g]
        # per-semiring ⊗ values (same policy as degree_split)
        hs, hd = inv[es], inv[ed]
        if not use_weights:
            ws = None
        if semiring == PLUS_TIMES:
            w = ws if ws is not None else np.ones(len(es), dtype=np.float32)
        elif semiring == MIN_PLUS:
            w = ws if ws is not None else np.zeros(len(es), dtype=np.float32)
        else:
            w = np.zeros(len(es), dtype=np.float32)
        in_h = (hs < k_s) & (hd < k_s)
        if k_s:
            if semiring == PLUS_TIMES:
                np.add.at(dense[s], (hs[in_h], hd[in_h]), w[in_h])
            else:
                np.minimum.at(dense[s], (hs[in_h], hd[in_h]), w[in_h])
        rest = ~in_h
        rest_w = w[rest] if semiring != MIN_SR else None
        g_rest = from_edge_list(hs[rest], hd[rest], n_max, weights=rest_w)
        col, val, _ = kops.csr_to_ell(g_rest, semiring=semiring,
                                      transpose=True)
        ell_cols.append(col)
        ell_vals.append(val)
        if push is not None:
            push[0].append(hs.astype(np.int32))
            push[1].append(hd.astype(np.int32))
            push[2].append(w.astype(np.float32))

    kmax = max(c.shape[1] for c in ell_cols)
    ell_col = np.stack([
        np.pad(c, ((0, 0), (0, kmax - c.shape[1])), constant_values=n_max)
        for c in ell_cols])
    ell_val = np.stack([
        np.pad(v, ((0, 0), (0, kmax - v.shape[1])),
               constant_values=mul_ident)
        for v in ell_vals])

    # ---- boundary edges → outbox-slot segment space ------------------------
    num_slots = pl * P * o_max
    bnd = [_boundary_arrays(ea, asg, s, pl, v_max, layouts[s][1])
           for s in range(S)]
    be_req = max(len(b[0]) for b in bnd)
    has_boundary = be_req > 0
    be_max = max(_round_up(be_req, align), align)
    b_src_rows = np.full((S, be_max), n_max, dtype=np.int32)
    b_flat = np.full((S, be_max), num_slots, dtype=np.int32)
    b_mask_rows = np.zeros((S, be_max), dtype=bool)
    b_w_rows = (np.zeros((S, be_max), dtype=np.float32)
                if ea.weight is not None else None)
    counts = np.zeros(S, dtype=np.int64)
    for s, (bs, bf, bw) in enumerate(bnd):
        k = len(bs)
        b_src_rows[s, :k] = bs
        b_flat[s, :k] = bf
        b_mask_rows[s, :k] = True
        if b_w_rows is not None and k:
            b_w_rows[s, :k] = bw
        counts[s] = k
    # Reuse the fused-path block preprocessing: rows sorted by "dst_ext"
    # (here: flat slot id) → per-block base/local/span for the outbox kernel.
    blk = build_block_metadata(
        EdgeArrays(src=b_src_rows, dst_ext=b_flat, weight=b_w_rows,
                   edge_mask=b_mask_rows,
                   outbox_dst=np.zeros((S, S, 1), np.int32),
                   outbox_mask=np.zeros((S, S, 1), bool),
                   inbox_dst=np.zeros((S, S, 1), np.int32),
                   num_edges=counts),
        block_e=block_e, lane=align)

    # ---- compact exchange maps --------------------------------------------
    pair_counts = np.zeros((S, S), dtype=np.int64)
    for u in range(S):
        for t in range(S):
            if t == u:
                continue
            rows = ea.outbox_mask[u * pl:(u + 1) * pl, t * pl:(t + 1) * pl]
            pair_counts[u, t] = int(rows.sum())
    w_req = int(pair_counts.max()) if S > 1 else 0
    has_remote = w_req > 0
    w_pad = max(_round_up(w_req, align), align)
    seg_sink = pl * (v_max + 1)
    send_idx = np.full((S, S, w_pad), num_slots, dtype=np.int32)
    recv_ids = np.full((S, S, w_pad), seg_sink, dtype=np.int32)
    loc_lists = [([], []) for _ in range(S)]
    for u in range(S):
        for t in range(S):
            j = 0
            for p_local in range(pl):
                p = u * pl + p_local
                for q in range(t * pl, (t + 1) * pl):
                    k = int(ea.outbox_mask[p, q].sum())
                    if k == 0:
                        continue
                    idx = p_local * (P * o_max) + q * o_max + np.arange(k)
                    ids = ((q - t * pl) * (v_max + 1)
                           + ea.outbox_dst[p, q, :k])
                    if t == u:
                        loc_lists[u][0].append(idx)
                        loc_lists[u][1].append(ids)
                    else:
                        send_idx[u, t, j: j + k] = idx
                        recv_ids[t, u, j: j + k] = ids
                        j += k
    l_req = max((sum(len(a) for a in ls[0]) for ls in loc_lists), default=0)
    has_local = l_req > 0
    l_pad = max(_round_up(l_req, align), align)
    loc_idx = np.full((S, l_pad), num_slots, dtype=np.int32)
    loc_ids = np.full((S, l_pad), seg_sink, dtype=np.int32)
    for s, (idxs, idss) in enumerate(loc_lists):
        if idxs:
            cat_i = np.concatenate(idxs)
            cat_d = np.concatenate(idss)
            loc_idx[s, : len(cat_i)] = cat_i
            loc_ids[s, : len(cat_d)] = cat_d

    push_src = push_dst = push_w = None
    if push is not None:
        ei_req = max(len(a) for a in push[0])
        ei_max = max(_round_up(ei_req, align), align)
        push_src = np.full((S, ei_max), n_max, dtype=np.int32)
        push_dst = np.full((S, ei_max), n_max, dtype=np.int32)
        for s in range(S):
            push_src[s, : len(push[0][s])] = push[0][s]
            push_dst[s, : len(push[1][s])] = push[1][s]
        if semiring == MIN_PLUS and use_weights and g.weights is not None:
            push_w = np.zeros((S, ei_max), dtype=np.float32)
            for s in range(S):
                push_w[s, : len(push[2][s])] = push[2][s]

    return ShardHybridData(
        semiring=semiring, num_shards=S, parts_per_shard=pl, v_max=v_max,
        num_parts=P, o_max=o_max, k_dense=K, n_max=n_max,
        num_slots=num_slots, n_vert=n_vert, dense=dense,
        ell_col=ell_col, ell_val=ell_val, slot=slot, hid=hid,
        b_src=blk.src, b_local=blk.local, b_base=blk.base,
        b_mask=blk.mask, b_weight=blk.weight, b_span=blk.span,
        b_block=block_e, send_idx=send_idx, recv_ids=recv_ids,
        loc_idx=loc_idx, loc_ids=loc_ids, wire_width=w_pad,
        wire_slots_used=int(pair_counts.sum()),
        has_boundary=has_boundary, has_remote=has_remote,
        has_local_slots=has_local, push_src=push_src, push_dst=push_dst,
        push_w=push_w, per_shard_k=k_list)


def hybrid_pagerank(hg: HybridGraph, num_iterations: int = 20,
                    damping: float = 0.85,
                    interpret: Optional[bool] = None) -> np.ndarray:
    """PageRank where H×H runs on the MXU path, the rest on the ELL path.

    Returns ranks in the *original* vertex id order.
    """
    if hg.semiring != PLUS_TIMES:
        raise ValueError("hybrid_pagerank needs a plus_times split")
    n = hg.num_vertices
    dense = jnp.asarray(hg.dense_block)
    col = jnp.asarray(hg.ell_col)
    val = jnp.asarray(hg.ell_val)
    inv_deg = jnp.asarray(np.where(hg.out_deg > 0,
                                   1.0 / np.maximum(hg.out_deg, 1.0), 0.0))
    delta = (1.0 - damping) / n

    @jax.jit
    def step(rank):
        contrib = rank * inv_deg
        y = hybrid_spmv(dense, col, val, contrib, semiring=PLUS_TIMES,
                        k_dense=hg.k_dense, interpret=interpret)
        return delta + damping * y

    rank = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    for _ in range(num_iterations):
        rank = step(rank)
    out = np.asarray(rank)
    result = np.empty_like(out)
    result[hg.perm] = out          # back to original id order
    return result
