"""The hybrid two-engine step — TOTEM's CPU/GPU split, TPU-native.

The paper assigns the few high-degree vertices to the CPU (cache-friendly)
and the many low-degree vertices to the GPU (latency-hiding) — §6.2.  A TPU
chip is homogeneous silicon but has two *execution paths* with exactly the
same duality:

  - the **MXU** (systolic matmul): the high-degree block's adjacency is dense
    enough that SpMV-as-GEMM wins (kernels/dense_spmv);
  - the **VPU + HBM streaming** path: the low-degree remainder has a tight
    degree bound, ideal for ELLPACK row-block streaming (kernels/ell_spmv).

``degree_split`` plays the role of the paper's HIGH partitioning: vertices
are ranked by (in+out) degree, the top-K become the dense block H, and every
edge inside H×H moves to the dense engine; the rest stays sparse.

The perf model (perf_model.hybrid_makespan_tpu) predicts when the split wins,
the same role Eq. 4 plays in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph, from_edge_list
from repro.core import perf_model
from repro.kernels import ops as kops


@dataclasses.dataclass
class HybridGraph:
    """Degree-split graph: dense H×H block + ELL remainder (pull form)."""

    num_vertices: int
    num_edges: int
    k_dense: int                 # |H| (0 → pure sparse)
    perm: np.ndarray             # new id -> old id (degree-descending)
    inv_perm: np.ndarray         # old id -> new id
    dense_block: np.ndarray      # [K, K] f32 adjacency (H×H edges)
    ell_col: np.ndarray          # [V, kmax] int32 (pull: in-neighbours)
    ell_val: np.ndarray          # [V, kmax] f32
    out_deg: np.ndarray          # [V] f32 in new id space (true out-degree)
    dense_edges: int             # edges handled by the MXU path
    sparse_edges: int            # edges handled by the ELL path

    @property
    def dense_density(self) -> float:
        return self.dense_edges / max(self.k_dense ** 2, 1)

    @property
    def dense_fraction(self) -> float:
        return self.dense_edges / max(self.num_edges, 1)

    def predicted_makespan(self, num_chips: int = 1) -> dict:
        return perf_model.hybrid_makespan_tpu(
            self.dense_edges, self.dense_density, self.sparse_edges,
            boundary_slots=0, num_chips=num_chips)


def degree_split(g: CSRGraph, k_dense: int) -> HybridGraph:
    """Split ``g``: top-``k_dense`` degree vertices → dense block."""
    total_deg = g.out_degrees() + g.in_degrees()
    perm = np.argsort(-total_deg, kind="stable")       # new -> old
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    src = inv[g.edge_sources()]
    dst = inv[g.col]

    in_h = (src < k_dense) & (dst < k_dense)
    dense = np.zeros((k_dense, k_dense), dtype=np.float32)
    if k_dense:
        np.add.at(dense, (src[in_h], dst[in_h]), 1.0)

    rest = ~in_h
    g_rest = from_edge_list(src[rest], dst[rest], g.num_vertices)
    col, val, _ = kops.csr_to_ell(g_rest, combine="sum", transpose=True)

    deg = g.out_degrees().astype(np.float32)[perm]
    return HybridGraph(
        num_vertices=g.num_vertices, num_edges=g.num_edges, k_dense=k_dense,
        perm=perm, inv_perm=inv, dense_block=dense, ell_col=col, ell_val=val,
        out_deg=deg, dense_edges=int(in_h.sum()), sparse_edges=int(rest.sum()))


def hybrid_pagerank(hg: HybridGraph, num_iterations: int = 20,
                    damping: float = 0.85,
                    interpret: Optional[bool] = None) -> np.ndarray:
    """PageRank where H×H runs on the MXU path, the rest on the ELL path.

    Returns ranks in the *original* vertex id order.
    """
    n = hg.num_vertices
    k = hg.k_dense
    dense = jnp.asarray(hg.dense_block)
    col = jnp.asarray(hg.ell_col)
    val = jnp.asarray(hg.ell_val)
    inv_deg = jnp.asarray(np.where(hg.out_deg > 0,
                                   1.0 / np.maximum(hg.out_deg, 1.0), 0.0))
    delta = (1.0 - damping) / n

    @jax.jit
    def step(rank):
        contrib = rank * inv_deg
        # sparse path: pull-reduce over the ELL remainder
        x = jnp.concatenate([contrib, jnp.zeros((1,), contrib.dtype)])
        y = kops.ell_spmv_op(col, val, x, combine="sum",
                             interpret=interpret)
        # dense path: MXU GEMM over the high-degree block
        if k:
            yh = kops.dense_spmv_op(contrib[None, :k], dense,
                                    interpret=interpret)[0]
            y = y.at[:k].add(yh)
        return delta + damping * y

    rank = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    for _ in range(num_iterations):
        rank = step(rank)
    out = np.asarray(rank)
    result = np.empty_like(out)
    result[hg.perm] = out          # back to original id order
    return result
