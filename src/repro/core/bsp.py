"""The TOTEM BSP engine in JAX (paper §4).

Each BSP superstep is exactly the paper's cycle:

  1. **compute**  — every partition runs the algorithm's edge kernel on its
     edges; messages to local destinations and to outbox slots are reduced in
     a single ``segment_min``/``segment_sum`` over the extended destination
     index (source-side message reduction, §3.4, is implicit here — multiple
     local edges to the same remote vertex share one outbox slot).
  2. **communicate** — outboxes are exchanged with the symmetric inboxes of
     the peer partitions (paper Fig. 6).  Locally this is a transpose;
     distributed it is an ``all_to_all`` over the mesh axis (ICI = the PCI-E
     analogue).
  3. **scatter** — the user combine (``alg_scatter``) folds inbox messages
     into local vertex state.
  4. **apply + vote** — per-vertex update; all partitions vote to finish
     (paper "Termination").

The same superstep body runs in two modes:
  - *local*: all P partitions stacked on one device (tests, small graphs);
  - *distributed*: partitions sharded over a mesh axis with ``shard_map``
    (one partition per device; this is the multi-pod scale-out path).

**Query batching.**  Every internal superstep path operates on state whose
leaves carry a leading *query axis* ``Q``: vertex leaves are
``[Q, Pl, v_max]``, per-partition scalars ``[Q, Pl]``.  The graph topology
(edge arrays, block metadata, outbox maps, degree splits) is shared across
the batch — only message values and state grow with Q — so a batch of Q
concurrent traversals (multi-source BFS/SSSP/BC, personalized PageRank)
amortizes one resident partitioned graph, one compiled ``lax.while_loop``,
and one kernel-launch sequence over all queries.  Each query votes finish
independently; converged queries are masked out of the apply step (their
state freezes bitwise) while the rest continue, and ``run_batched`` reports
per-query superstep counts.  The single-query ``run``/``run_fixed`` API is
preserved as a Q=1 wrapper.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map
from repro.runtime import chaos
from repro.core.partition import (BlockMetadata, EdgeArrays, PartitionedGraph,
                                  build_block_metadata)

Array = jax.Array
State = Any    # pytree of [Pl, v_max]-leading arrays + [Pl] scalars
BatchedState = Any  # same pytree with a leading query axis: [Q, Pl, ...]


def batch_state(state: State) -> BatchedState:
    """Add a Q=1 query axis to every leaf (single-query compatibility)."""
    return jax.tree.map(lambda x: jnp.asarray(x)[None], state)


def unbatch_state(state: BatchedState) -> State:
    """Strip the query axis of a Q=1 batched state."""
    return jax.tree.map(lambda x: x[0], state)


def num_queries(state: BatchedState) -> int:
    """Static query-batch extent Q of a batched state pytree."""
    return int(jax.tree_util.tree_leaves(state)[0].shape[0])

SUM = "sum"
MIN = "min"
_SEGMENT_OP = {SUM: jax.ops.segment_sum, MIN: jax.ops.segment_min}
_COMBINE = {SUM: jnp.add, MIN: jnp.minimum}


@dataclasses.dataclass(frozen=True)
class EdgeMessage:
    """Elementwise edge-message form of ``edge_fn`` the fused kernel inlines.

    ``fn(vals, weight, step, consts) -> msgs`` where ``vals`` maps each key
    in ``gather`` to that state array's value at the edge's *source* vertex,
    ``weight`` is the per-edge weight (present iff ``use_weight``), ``step``
    is the superstep as float32, and ``consts`` maps each key in ``consts``
    to a per-partition scalar state entry (e.g. BC's ``max_level``).  The
    function must be elementwise/broadcast-safe: the kernel calls it on
    [block_e]-shaped values, the fallback on [Pl, e_max]-shaped ones, and it
    must compute exactly what ``edge_fn`` computes per edge.

    ``weight_op`` declares how the weight enters the message, so SpMV-style
    backends can factor it out of the per-source part:
    ``fn(vals, w, ...) == fn(vals, ident, ...) ⊗ w`` with (⊗, ident) =
    ``("add", 0)`` for min-combines or ``("mul", 1)`` for sum-combines.
    Required (and only meaningful) when ``use_weight`` — it makes the program
    eligible for the hybrid degree-split backend, which runs the edge as a
    semiring SpMV (min_plus / plus_times) instead of per-edge messages.
    """

    gather: Tuple[str, ...]
    fn: Callable[..., Array]
    consts: Tuple[str, ...] = ()
    use_weight: bool = False
    weight_op: Optional[str] = None   # None | "add" | "mul"
    # True iff every non-identity message of one superstep carries the SAME
    # value (BFS: all frontier vertices send step+1).  Licenses the
    # bottom-up kernel's per-row early exit as *exact* — the first live
    # parent's value IS the row minimum.  Programs whose messages differ per
    # source (CC labels, SSSP distances) must leave this False; their pull
    # steps scan full rows.
    frontier_uniform: bool = False


@dataclasses.dataclass(frozen=True)
class IncrementalForm:
    """A program's warm-start form for incremental recomputation.

    ``program`` is the *relaxation* restatement of the algorithm — one whose
    fixpoint is reachable by descent from any over-approximation, not just
    from the cold initial state (e.g. BFS's level-synchronous frontier test
    becomes an active-set min-relaxation over levels).  ``seed(prev_state,
    dirty)`` rebuilds the warm initial state from a previous *fixpoint* and
    a ``[Pl, v_max]`` dirty-vertex mask (the sources of edges inserted since
    that fixpoint was computed).

    Valid only while mutations stay **monotone** for the program's semiring
    (insert-only for min/min-plus: new edges can only lower the least
    fixpoint, so the old solution is a sound over-approximation — and every
    old path survives, which is what makes the warm fixpoint *bitwise* equal
    to the cold one).  Deletions, and non-monotone programs (PageRank, BC),
    must fall back to cold recompute; ``BSPEngine.run_incremental`` returns
    None when no form exists and ``DynamicGraph.dirty_since`` reports
    whether the mutation window was monotone.
    """

    program: "VertexProgram"
    seed: Callable[[BatchedState, Array], BatchedState]


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    """An algorithm in TOTEM's callback form (paper Fig. 5).

    ``edge_fn(state, src, weight, step) -> msgs [Pl, e_max]`` — the per-edge
    part of ``alg_compute`` (messages for inactive sources must be the
    combine identity).
    ``apply_fn(state, acc, step) -> (new_state, finished)`` — the per-vertex
    part of ``alg_compute`` + ``alg_scatter``'s state update; ``acc`` is the
    fully-reduced [Pl, v_max] accumulator (local + remote contributions).
    ``finished`` is this shard's vote to terminate.
    ``edge_msg`` — optional :class:`EdgeMessage` equivalent of ``edge_fn``;
    programs that provide it are eligible for the fused superstep path.
    ``incremental`` — optional :class:`IncrementalForm` enabling
    ``BSPEngine.run_incremental`` warm starts after monotone mutations.
    """

    combine: str
    edge_fn: Callable[[State, Array, Optional[Array], Array], Array]
    apply_fn: Callable[[State, Array, Array], Tuple[State, Array]]
    max_steps: int = 1 << 30
    use_reverse: bool = False
    edge_msg: Optional[EdgeMessage] = None
    incremental: Optional[IncrementalForm] = None


def gather_src(x: Array, src: Array) -> Array:
    """Fetch per-edge source-vertex state: [Pl, v_max] × [Pl, e_max]."""
    return jnp.take_along_axis(x, src, axis=1)


@dataclasses.dataclass(frozen=True)
class _Dims:
    num_parts: int       # global partition count P
    v_max: int
    e_max: int
    o_max: int

    @property
    def seg(self) -> int:  # extended segment space per partition
        return self.v_max + 1 + self.num_parts * self.o_max


@dataclasses.dataclass(frozen=True)
class FusedConfig:
    """Static geometry of one direction's fused compute phase."""

    span: int            # lane-aligned block span bound (measured)
    block_e: int
    v_pad: int           # v_max rounded up to gather_chunk
    max_span: int = 4096
    gather_chunk: int = 256
    interpret: Optional[bool] = None


# ---------------------------------------------------------------------------
# Direction-optimized traversal (docs/traversal.md)
#
# For min-combine programs a superstep can run top-down ("push": every
# frontier vertex scatters along its out-edges) or bottom-up ("pull": every
# destination row scans its in-neighbours, with early exit when messages are
# uniform).  Both directions reduce the same value multiset per destination
# under a min ⊕ — rounding-free and order-independent — so direction is
# purely a performance choice and results stay bitwise identical.
#
# The decision state rides IN the traced carry as three [Q, P] int32 leaves
# (direction, edges-examined counter, switch counter), injected by
# ``BSPEngine.execute`` and stripped before the user sees the state.  Because
# the direction is a *value*, switching mid-run never retraces: one compiled
# superstep contains both branches under ``lax.cond``.  Under ``shard_map``
# each shard sees its local [Q, pl] slice and votes from its own frontier
# density — the per-shard switching of the issue — writing its counters into
# local column 0, so a global axis-1 sum aggregates per query.
# ---------------------------------------------------------------------------

_DOPT_KEYS = ("_dopt_dir", "_dopt_edges", "_dopt_switch")
_DIR_PUSH = 0
_DIR_PULL = 1


@dataclasses.dataclass(frozen=True)
class _DoptCfg:
    """Static direction config for the reference/fused superstep closure."""

    semiring: str                 # "min" | "min_plus"
    uniform: bool                 # EdgeMessage.frontier_uniform
    forced: Optional[int] = None  # None = auto, else _DIR_PUSH/_DIR_PULL
    interpret: Optional[bool] = None


def _dopt_strip(state: State):
    """Split the dopt leaves out of the carry before user code sees it."""
    if _DOPT_KEYS[0] not in state:
        return state, None
    user = {k: v for k, v in state.items() if k not in _DOPT_KEYS}
    return user, {k: state[k] for k in _DOPT_KEYS}


def _dopt_fold(dopt: dict, want: Array, cnt: Array) -> dict:
    """Fold one superstep's decisions into the carried dopt leaves.

    ``want [Q]`` is this superstep's direction, ``cnt [Q]`` the edges the
    chosen direction examined (the deterministic work model).  Writes land
    in local column 0 — per-shard columns of the global [Q, P] leaf under
    ``shard_map`` — and the direction broadcasts across local columns."""
    prev = dopt["_dopt_dir"][:, 0]
    sw = jnp.logical_and(prev >= 0, prev != want).astype(jnp.int32)
    return {
        "_dopt_dir": jnp.broadcast_to(want[:, None].astype(jnp.int32),
                                      dopt["_dopt_dir"].shape),
        "_dopt_edges": dopt["_dopt_edges"].at[:, 0].add(cnt),
        "_dopt_switch": dopt["_dopt_switch"].at[:, 0].add(sw),
    }


def _direction_select(want: Array, run_push, run_pull, x):
    """Run push/pull per the [Q] direction vector.

    Homogeneous batches take a single branch through nested ``lax.cond``;
    mixed batches compute both and select per query.  Branch fns map
    ``x -> (y, push_cnt [Q], pull_cnt [Q])`` with identical shapes."""
    def mixed(x):
        y_p, cp, _ = run_push(x)
        y_l, _, sl = run_pull(x)
        sel = (want == _DIR_PULL)
        shape = (-1,) + (1,) * (y_p.ndim - 1)
        zero = jnp.zeros_like(cp)
        return (jnp.where(sel.reshape(shape), y_l, y_p),
                jnp.where(sel, zero, cp), jnp.where(sel, sl, zero))

    return jax.lax.cond(
        jnp.all(want == _DIR_PUSH), run_push,
        lambda x: jax.lax.cond(jnp.all(want == _DIR_PULL),
                               run_pull, mixed, x),
        x)


def _dopt_want(forced: Optional[int], density: Array, unvisited: Array,
               threshold) -> Array:
    """Per-query direction vote — the α-style two-term crossover.

    Pull pays one scan per destination row, early-exiting at the first
    live parent, so it wins only when (a) the frontier is dense enough
    that rows exit after ~1/density slots (the fitted ``threshold`` —
    perf_model.fit_pull_threshold's sqrt(γ/deg) crossover) AND (b) the
    frontier outweighs the *unvisited* mass: rows whose value is still
    the ⊕-identity have no live parent yet, never early-exit, and pay
    their full in-degree every pull superstep — on directed graphs the
    unreachable tail would otherwise be rescanned forever (Beamer's
    m_f > m_u/α switch, degree-uniform proxy with α = 1).  Sum combines
    never reach this vote; for min combines both directions are bitwise
    so the vote is a pure perf choice.
    """
    if forced is not None:
        return jnp.full(density.shape, forced, jnp.int32)
    pull = jnp.logical_and(density >= threshold, density > unvisited)
    return jnp.where(pull, _DIR_PULL, _DIR_PUSH).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class _HybridCfg:
    """Static geometry of one hybrid degree-split direction.

    The array payload travels separately (an ``arrs`` dict with keys
    ``dense``/``ell_col``/``ell_val``/``slot``/``hid`` and optionally
    ``push_src``/``push_dst``/``push_w``): numpy in the static engine —
    per-trace constants — but **traced jit arguments** in the dynamic
    engine, so in-place edge mutations (core/dynamic.py) update the split
    without retracing and compaction can never be served from a stale
    compiled constant.
    """

    semiring: str
    k_dense: int
    num_vertices: int
    pull_threshold: float
    interpret: Optional[bool]
    # direction-optimization statics (docs/traversal.md): forced direction
    # (None = auto crossover), message uniformity (licenses the bottom-up
    # early exit), and the static dense-stage work charge k_dense².
    forced: Optional[int] = None
    uniform: bool = False
    e_dense: int = 0


def _superstep_hybrid(program: VertexProgram, cfg: _HybridCfg, arrs: dict,
                      all_finished: Callable[[Array], Array],
                      state: State, step: Array) -> Tuple[State, Array]:
    """One BSP superstep through the degree-split two-engine backend.

    The compute phase is a semiring SpMV over the *whole* graph in hybrid
    (degree-ranked) id space — dense H×H block on the MXU path, ELL remainder
    on the VPU path (core/hybrid.py).  There is no outbox/inbox: an on-chip
    split has no partition boundary to communicate across, exactly the
    paper's single-node hybrid setting (§6).  For min combines a
    frontier-density switch picks the push direction (gather + segment-min —
    cheap when few vertices send) or the pull direction (frontier-oblivious
    SpMV), the direction-optimized traversal of Sallinen et al.

    ``slot``/``hid`` in ``arrs`` translate between the engine's [P, v_max]
    partition layout and the split's degree-ranked global id space (sink =
    n for padding slots); ``push_*`` absent disables the direction switch
    (sum combines, or ``direction_switch=False``).  The dynamic engine
    carries spare sentinel slots in its push arenas so mutations ride the
    same extended-segment reduce without a reshape.
    """
    from repro.core.hybrid import add_identity, hybrid_spmv, hybrid_spmv_scan

    chaos.visit("kernel.hybrid", distributed=False)
    spec = program.edge_msg
    ident = add_identity(cfg.semiring)
    state, dopt = _dopt_strip(state)
    track = dopt is not None and "push_src" in arrs and "ell_kreal" in arrs
    q = state[spec.gather[0]].shape[0]
    n = cfg.num_vertices
    vals = {k: state[k].astype(jnp.float32).reshape(q, -1)[:, arrs["slot"]]
            for k in spec.gather}           # [Q, n] in hybrid id space
    # Per-partition scalar consts are replicated across partitions in the
    # single-device engines; the global compute reads partition 0's copy
    # (shaped [Q, 1] so they broadcast against the [Q, n] values).
    consts = {c: state[c][:, :1].astype(jnp.float32) for c in spec.consts}
    w_ident = None
    if spec.use_weight:
        w_ident = jnp.float32(0.0 if spec.weight_op == "add" else 1.0)
    x = spec.fn(vals, w_ident, step.astype(jnp.float32),
                consts).astype(jnp.float32)              # [Q, n]

    def pull(x):
        return hybrid_spmv(arrs["dense"], arrs["ell_col"], arrs["ell_val"],
                           x, semiring=cfg.semiring, k_dense=cfg.k_dense,
                           interpret=cfg.interpret)

    if "push_src" in arrs:
        def push_msgs(x):
            # Extended (n+1)-segment form: sentinel slots (src = dst = n,
            # e.g. the dynamic engine's spare push capacity) gather the
            # ⊕-identity sink and reduce into a discarded segment, so
            # padding is inert by construction.
            x_ext = jnp.concatenate(
                [x, jnp.full((q, 1), ident, x.dtype)], axis=1)
            msgs = x_ext[:, arrs["push_src"]]            # [Q, E]
            if "push_w" in arrs:
                msgs = msgs + arrs["push_w"]
            offs = (jnp.arange(q, dtype=jnp.int32) * (n + 1))[:, None]
            y = jax.ops.segment_min(msgs.ravel(),
                                    (arrs["push_dst"][None] + offs).ravel(),
                                    num_segments=q * (n + 1))
            return y.reshape(q, n + 1)[:, :n], msgs

        # Per-query frontier density vs the fitted crossover, guarded by
        # the unvisited mass (still-⊕-identity vertices never early-exit
        # a pull scan), picks the direction — a perf choice only; both
        # directions are exact for min combines, and each query votes for
        # itself (satellite 1).
        nf = jnp.float32(max(n, 1))
        density = jnp.sum((x != ident).astype(jnp.float32), axis=1) / nf
        unvisited = jnp.sum(
            (vals[spec.gather[0]] == ident).astype(jnp.float32), axis=1) / nf
        want = _dopt_want(cfg.forced if track else None, density, unvisited,
                          cfg.pull_threshold)

        if track:
            e_dense = jnp.full((q,), cfg.e_dense, jnp.int32)

            def run_push(x):
                y, msgs = push_msgs(x)
                cnt = jnp.sum((msgs != ident).astype(jnp.int32), axis=1)
                return y, cnt, jnp.zeros((q,), jnp.int32)

            # Under the uniform licence a row already holding a value is
            # final — a sequential bottom-up skips it (zero scanned slots).
            skip = ((vals[spec.gather[0]] != ident) if cfg.uniform
                    else None)

            def run_pull(x):
                y, scanned = hybrid_spmv_scan(
                    arrs["dense"], arrs["ell_col"], arrs["ell_val"], x,
                    arrs["ell_kreal"], semiring=cfg.semiring,
                    k_dense=cfg.k_dense, early_exit=cfg.uniform,
                    skip=skip, interpret=cfg.interpret)
                return y, jnp.zeros((q,), jnp.int32), scanned + e_dense

            y, cnt_push, cnt_pull = _direction_select(
                want, run_push, run_pull, x)
            dopt = _dopt_fold(dopt, want, cnt_push + cnt_pull)
        else:
            zero = jnp.zeros((q,), jnp.int32)
            y, _, _ = _direction_select(
                want,
                lambda x: (push_msgs(x)[0], zero, zero),
                lambda x: (pull(x), zero, zero), x)
    else:
        y = pull(x)

    y_ext = jnp.concatenate([y, jnp.full((q, 1), ident, y.dtype)], axis=1)
    acc = y_ext[:, arrs["hid"]]             # back to [Q, P, v_max] layout
    new_state, finished = jax.vmap(program.apply_fn,
                                   in_axes=(0, 0, None))(state, acc, step)
    if dopt is not None:
        new_state = dict(new_state, **dopt)
    return new_state, all_finished(finished)


def _superstep_hybrid_dist(program: VertexProgram, shd, arrs: dict,
                           axis: str, interpret: Optional[bool],
                           pull_threshold: float,
                           all_finished: Callable[[Array], Array],
                           state: State, step: Array, *,
                           guard=None,
                           n_shards: Optional[int] = None,
                           forced: Optional[int] = None,
                           uniform: bool = False,
                           e_dense: int = 0) -> Tuple[State, Array]:
    """One BSP superstep of the *distributed* degree-split backend.

    Runs inside ``shard_map``: ``state`` leaves are the local
    ``[Q, pl, v_max]`` shard of the query batch, ``arrs`` the shard's slice
    of :class:`hybrid.ShardHybridData` (leading mesh axis of extent 1),
    shared across the batch.  The paper's cycle, per shard:

      1. evaluate the EdgeMessage once per local vertex (⊗-identity weight),
         then run the two-engine semiring SpMV over the shard's
         *intra-partition* edges (dense H×H MXU block + ELL remainder, with
         the push/pull frontier switch for min combines);
      2. reduce boundary messages into the ``o_max`` outbox slots at the
         source (``ops.outbox_reduce_op`` — the §3.4 aggregation, so the
         wire carries β_with_reduction·|E| values, never per-edge messages);
      3. exchange only the *used* (shard, peer) slot blocks via a compact
         ``all_to_all`` (Fig. 6's outbox→inbox copy over ICI); same-device
         peer slots short-circuit through a local gather/scatter;
      4. scatter inbox values into the local accumulator, combine with the
         SpMV result, apply + vote (global AND via psum).
    """
    from repro.core.hybrid import add_identity, hybrid_spmv, hybrid_spmv_scan
    from repro.kernels.ops import outbox_reduce_op

    chaos.visit("kernel.hybrid", distributed=True)
    spec = program.edge_msg
    ident = add_identity(shd.semiring)
    pl = shd.parts_per_shard
    v_max = shd.v_max
    slot = arrs["slot"][0]
    state, dopt = _dopt_strip(state)
    track = dopt is not None and "push_src" in arrs and "ell_kreal" in arrs
    q = state[spec.gather[0]].shape[0]
    vals = {k: state[k].astype(jnp.float32).reshape(q, -1)[:, slot]
            for k in spec.gather}                       # [Q, n_max]
    consts = {c: state[c][:, :1].astype(jnp.float32) for c in spec.consts}
    w_ident = None
    if spec.use_weight:
        w_ident = jnp.float32(0.0 if spec.weight_op == "add" else 1.0)
    x = spec.fn(vals, w_ident, step.astype(jnp.float32),
                consts).astype(jnp.float32)             # [Q, n_max]
    n_vert = arrs["n_vert"][0]
    vmask = jnp.arange(shd.n_max, dtype=jnp.int32) < n_vert
    x = jnp.where(vmask[None], x, ident)  # pad hybrid ids never contribute

    def pull(xv):
        return hybrid_spmv(arrs["dense"][0], arrs["ell_col"][0],
                           arrs["ell_val"][0], xv, semiring=shd.semiring,
                           k_dense=shd.k_dense, interpret=interpret)

    if "push_src" in arrs:
        def push_msgs(xv):
            x_ext = jnp.concatenate(
                [xv, jnp.full((q, 1), ident, xv.dtype)], axis=1)
            msgs = x_ext[:, arrs["push_src"][0]]        # [Q, ei]
            if "push_w" in arrs:
                msgs = msgs + arrs["push_w"][0]
            offs = (jnp.arange(q, dtype=jnp.int32)
                    * (shd.n_max + 1))[:, None]
            y = jax.ops.segment_min(
                msgs.ravel(), (arrs["push_dst"][0][None] + offs).ravel(),
                num_segments=q * (shd.n_max + 1))
            return y.reshape(q, shd.n_max + 1)[:, : shd.n_max], msgs

        # Per-(query, shard) frontier density vs this shard's fitted
        # crossover, guarded by the shard's unvisited mass, picks the
        # direction — each query votes for itself from the shard's own
        # frontier slice (a perf choice only; both directions are exact
        # for min combines).
        thr = (arrs["pull_thr"][0][0, 0] if "pull_thr" in arrs
               else pull_threshold)
        nf = jnp.maximum(n_vert.astype(jnp.float32), 1.0)
        density = jnp.sum((x != ident).astype(jnp.float32), axis=1) / nf
        unvisited = jnp.sum(jnp.logical_and(
            vals[spec.gather[0]] == ident,
            vmask[None]).astype(jnp.float32), axis=1) / nf
        want = _dopt_want(forced if track else None, density, unvisited, thr)

        if track:
            ed = (arrs["e_dense"][0][0] if "e_dense" in arrs
                  else jnp.int32(e_dense))
            e_dense_q = jnp.broadcast_to(ed.astype(jnp.int32), (q,))

            def run_push(xv):
                y, msgs = push_msgs(xv)
                cnt = jnp.sum((msgs != ident).astype(jnp.int32), axis=1)
                return y, cnt, jnp.zeros((q,), jnp.int32)

            # Uniform licence: rows already holding a value are final and
            # charge zero scanned slots (sequential bottom-up skips them).
            skip = ((vals[spec.gather[0]] != ident) if uniform else None)

            def run_pull(xv):
                y, scanned = hybrid_spmv_scan(
                    arrs["dense"][0], arrs["ell_col"][0], arrs["ell_val"][0],
                    xv, arrs["ell_kreal"][0], semiring=shd.semiring,
                    k_dense=shd.k_dense, early_exit=uniform,
                    skip=skip, interpret=interpret)
                return y, jnp.zeros((q,), jnp.int32), scanned + e_dense_q

            y, cnt_push, cnt_pull = _direction_select(
                want, run_push, run_pull, x)
            cnt = cnt_push + cnt_pull
            if shd.has_boundary:
                # Boundary edges always run the push-style outbox reduction
                # below, whichever way the intra step went — charge them in
                # both directions.
                x_ext = jnp.concatenate(
                    [x, jnp.full((q, 1), ident, x.dtype)], axis=1)
                live = (x_ext[:, arrs["b_src"][0]] != ident)
                live = jnp.logical_and(
                    live, (arrs["b_mask"][0] != 0)[None])
                cnt = cnt + jnp.sum(live.astype(jnp.int32), axis=1)
            dopt = _dopt_fold(dopt, want, cnt)
        else:
            zero = jnp.zeros((q,), jnp.int32)
            y, _, _ = _direction_select(
                want,
                lambda xv: (push_msgs(xv)[0], zero, zero),
                lambda xv: (pull(xv), zero, zero), x)
    else:
        y = pull(x)

    seg_op = _SEGMENT_OP[program.combine]
    seg = shd.scatter_segments
    racc = None
    if shd.has_boundary:
        x_ext = jnp.concatenate([x, jnp.full((q, 1), ident, x.dtype)],
                                axis=1)
        outbox = outbox_reduce_op(
            x_ext, arrs["b_src"][0], arrs["b_local"][0], arrs["b_mask"][0],
            arrs["b_base"][0], arrs.get("b_weight", [None])[0],
            num_slots=shd.num_slots, combine=program.combine,
            weight_op=spec.weight_op if spec.use_weight else None,
            span=shd.b_span, block_e=shd.b_block,
            interpret=interpret)                        # [Q, num_slots]
        obox_ext = jnp.concatenate(
            [outbox, jnp.full((q, 1), ident, outbox.dtype)], axis=1)
        rvals, rids = [], []
        if shd.has_remote:
            send = obox_ext[:, arrs["send_idx"][0]]     # [Q, S, w]
            if guard is not None and n_shards is not None and n_shards > 1:
                # Checksummed compact exchange: one reduction tag per
                # destination shard, shipped over its own tiled all_to_all;
                # the receiver re-tags its S/n_shards block per source.
                blk = send.shape[1] // n_shards
                tags = _payload_tag(
                    send.reshape(q, n_shards, blk, -1), (0, 2, 3))
                send = jnp.where(guard.poison > 0, _flip_wire(send), send)
                want = jax.lax.all_to_all(
                    tags.reshape(n_shards, 1), axis, split_axis=0,
                    concat_axis=0, tiled=True).reshape(n_shards)
                recv = jax.lax.all_to_all(send, axis, split_axis=1,
                                          concat_axis=1, tiled=True)
                got = _payload_tag(
                    recv.reshape(q, n_shards, blk, -1), (0, 2, 3))
                guard.add(jnp.sum((got != want).astype(jnp.int32)))
            else:
                recv = jax.lax.all_to_all(send, axis, split_axis=1,
                                          concat_axis=1, tiled=True)
            rvals.append(recv.reshape(q, -1))
            rids.append(arrs["recv_ids"][0].reshape(-1))
        if shd.has_local_slots:
            rvals.append(obox_ext[:, arrs["loc_idx"][0]])
            rids.append(arrs["loc_ids"][0])
        if rvals:
            ids = jnp.concatenate(rids)                 # [L], shared over Q
            offs = (jnp.arange(q, dtype=jnp.int32) * (seg + 1))[:, None]
            racc = seg_op(jnp.concatenate(rvals, axis=1).ravel(),
                          (ids[None] + offs).ravel(),
                          num_segments=q * (seg + 1))
            racc = racc.reshape(q, seg + 1)[:, :seg]
            racc = racc.reshape(q, pl, v_max + 1)[:, :, :v_max]

    y_ext = jnp.concatenate([y, jnp.full((q, 1), ident, y.dtype)], axis=1)
    acc = y_ext[:, arrs["hid"][0]]                      # [Q, pl, v_max]
    if racc is not None:
        acc = _COMBINE[program.combine](acc, racc)
    new_state, finished = jax.vmap(program.apply_fn,
                                   in_axes=(0, 0, None))(state, acc, step)
    if dopt is not None:
        new_state = dict(new_state, **dopt)
    return new_state, all_finished(finished)


def _compute_reference(dims: _Dims, program: VertexProgram, edges: dict,
                       state: BatchedState, step: Array) -> Array:
    """Reference compute: gather → [Q, Pl, e_max] messages → scatter-reduce.

    ``edge_fn`` is written against unbatched [Pl, ...] state; vmap over the
    query axis runs it once per query against the *shared* edge arrays."""
    pl = edges["src"].shape[0]
    src, weight = edges["src"], edges.get("weight")
    msgs = jax.vmap(
        lambda st: program.edge_fn(st, src, weight, step))(state)
    q = msgs.shape[0]
    offs = (jnp.arange(q * pl, dtype=jnp.int32)
            * dims.seg).reshape(q, pl, 1)
    ids = (edges["dst_ext"][None] + offs).ravel()
    acc = _SEGMENT_OP[program.combine](msgs.ravel(), ids,
                                       num_segments=q * pl * dims.seg)
    return acc.reshape(q, pl, dims.seg)


def _compute_fused(dims: _Dims, program: VertexProgram, edges: dict,
                   cfg: FusedConfig, state: BatchedState,
                   step: Array) -> Array:
    """Fused compute: one Pallas pass per (query, edge block), no
    [Q, Pl, e_max] HBM message array (kernels/fused_superstep.py)."""
    from repro.kernels.ops import fused_superstep_op

    # trace-time injection seam: a raise here aborts the compile, leaves no
    # jit-cache entry, and surfaces to the dispatching host as a kernel
    # fault — the degradation ladder's retry re-traces (and may re-fire)
    chaos.visit("kernel.fused", block_e=cfg.block_e)

    spec = program.edge_msg
    pl = edges["src"].shape[0]
    vstate = jnp.stack([state[k].astype(jnp.float32) for k in spec.gather],
                       axis=2)                            # [Q, Pl, K, v_max]
    pad = cfg.v_pad - vstate.shape[3]
    if pad:
        vstate = jnp.pad(vstate, ((0, 0), (0, 0), (0, 0), (0, pad)))
    q = vstate.shape[0]
    cols = [jnp.broadcast_to(step.astype(jnp.float32), (q, pl))]
    cols += [state[k].astype(jnp.float32) for k in spec.consts]
    scal = jnp.stack(cols, axis=2)                        # [Q, Pl, 1+consts]

    def msg_fn(vals, weight, scals):
        vals_d = dict(zip(spec.gather, vals))
        consts_d = dict(zip(spec.consts, scals[1:]))
        return spec.fn(vals_d, weight, scals[0], consts_d)

    weight = edges.get("weight_blk") if spec.use_weight else None
    return fused_superstep_op(
        msg_fn, vstate, weight, scal, edges["blk_src"], edges["blk_local"],
        edges["blk_mask"], edges["blk_base"], edges["dst_ext"],
        num_segments=dims.seg, combine=program.combine, span=cfg.span,
        block_e=cfg.block_e, max_span=cfg.max_span,
        gather_chunk=cfg.gather_chunk, interpret=cfg.interpret)


def _superstep(dims: _Dims, program: VertexProgram, edges: dict,
               exchange: Callable[[Array], Array],
               all_finished: Callable[[Array], Array],
               fused_cfg: Optional[FusedConfig],
               state: BatchedState, step: Array,
               dyn: Optional[dict] = None,
               dopt_cfg: Optional[_DoptCfg] = None
               ) -> Tuple[BatchedState, Array]:
    """One BSP superstep of the whole query batch over the local shard.

    ``dyn`` (a ``DynamicGraph.payload`` dict, sharded alongside ``edges``)
    folds in-place mutations into the same superstep: tombstoned base edges
    are redirected to the segment sink (reference path) / masked out of
    their block (fused path), the masked **delta-slot tail** runs one extra
    reference-style reduction over the same extended segment space — so its
    boundary messages share the outbox slots and the exchange for free —
    and the live ``inbox_dst`` map carries slots assigned after partition
    time.  All shapes are mutation-independent; only values change.
    """
    combine = program.combine
    seg_op = _SEGMENT_OP[combine]
    pl = edges["src"].shape[0]  # local partition count
    state, dopt = _dopt_strip(state)
    spec = program.edge_msg
    track = (dopt is not None and dyn is None and spec is not None
             and dopt_cfg is not None and "t_col" in edges)

    if dyn is not None:
        edges = dict(edges)
        tomb = dyn["tomb"]
        edges["dst_ext"] = jnp.where(tomb, dims.v_max, edges["dst_ext"])
        edges["inbox_dst"] = dyn["inbox_dst"]
        if "blk_mask" in edges:
            pad = edges["blk_mask"].shape[1] - tomb.shape[1]
            alive = jnp.pad(jnp.logical_not(tomb), ((0, 0), (0, pad)))
            edges["blk_mask"] = edges["blk_mask"] * alive.astype(
                edges["blk_mask"].dtype)

    # -- compute: per-edge messages, reduced over extended destinations -----
    def compute_push(state, step):
        if fused_cfg is not None and program.edge_msg is not None:
            return _compute_fused(dims, program, edges, fused_cfg, state,
                                  step)
        return _compute_reference(dims, program, edges, state, step)

    if track:
        from repro.kernels import ops as kops

        # Min combines only: both directions reduce the same per-destination
        # value multiset, so direction is a pure perf choice (bitwise).
        ident = jnp.float32(jnp.inf)
        v_max = dims.v_max
        q = state[spec.gather[0]].shape[0]
        # Per-vertex messages; the push direction's per-edge messages are
        # gathers of exactly these values (the reference↔fused bitwise
        # parity already leans on edge_fn ≡ gather∘edge_msg.fn).
        vvals = {k: state[k].astype(jnp.float32) for k in spec.gather}
        vconsts = {c: state[c][:, :, None].astype(jnp.float32)
                   for c in spec.consts}
        w_ident = None
        if spec.use_weight:
            w_ident = jnp.float32(0.0 if spec.weight_op == "add" else 1.0)
        xv = spec.fn(vvals, w_ident, step.astype(jnp.float32),
                     vconsts).astype(jnp.float32)        # [Q, Pl, v_max]
        vmask = edges["t_vmask"]
        act = jnp.logical_and(xv != ident,
                              vmask[None]).astype(jnp.float32)
        nreal = jnp.maximum(jnp.sum(vmask.astype(jnp.float32)), 1.0)
        density = jnp.sum(act, axis=(1, 2)) / nreal
        unvisited = jnp.sum(jnp.logical_and(
            vvals[spec.gather[0]] == ident,
            vmask[None]).astype(jnp.float32), axis=(1, 2)) / nreal
        deg = edges["t_deg"].astype(jnp.float32)
        bnd = edges["t_bnd"].astype(jnp.float32)
        # One direction serves every partition in this trace, so the vote
        # threshold is the edge-mass-weighted blend of the per-partition
        # fitted crossovers — exactly the shard's own fit when shard_map
        # hands this trace a single partition.
        emass = jnp.sum(deg, axis=1)
        thr = (jnp.sum(edges["t_thr"][:, 0] * emass)
               / jnp.maximum(jnp.sum(emass), 1.0))
        want = _dopt_want(dopt_cfg.forced, density, unvisited, thr)
        # Push examines every out-edge of a live vertex; the boundary leg
        # always pushes (its messages ride the outbox/exchange either way),
        # so pull is charged the boundary out-edges on top of its scans.
        cnt_push = jnp.sum(act * deg[None], axis=(1, 2)).astype(jnp.int32)
        cnt_bnd = jnp.sum(act * bnd[None], axis=(1, 2)).astype(jnp.int32)
        zero = jnp.zeros((q,), jnp.int32)

        def run_push(opd):
            st, step = opd
            return compute_push(st, step), cnt_push, zero

        def run_pull(opd):
            st, step = opd
            # Boundary-only reference pass: intra destinations redirect to
            # the segment sink, leaving outbox slots bitwise identical to
            # the full compute's — the local region comes from the
            # bottom-up kernel instead.
            e_bnd = dict(edges)
            e_bnd["dst_ext"] = jnp.where(edges["dst_ext"] < v_max, v_max,
                                         edges["dst_ext"])
            acc_b = _compute_reference(dims, program, e_bnd, st, step)
            offs = (jnp.arange(pl, dtype=jnp.int32)
                    * (v_max + 1))[:, None, None]
            colf = (edges["t_col"] + offs).reshape(pl * v_max, -1)
            xf = jnp.concatenate(
                [xv, jnp.full((q, pl, 1), ident, xv.dtype)],
                axis=2).reshape(q, pl * (v_max + 1))
            valf = None
            if dopt_cfg.semiring == "min_plus":
                valf = edges["t_val"].reshape(pl * v_max, -1)
            # Uniform licence: already-written rows are final — a
            # sequential bottom-up visits only unvisited rows, so they
            # charge zero scanned slots in the work model.
            skip = None
            if dopt_cfg.uniform:
                skip = (vvals[spec.gather[0]] != ident).reshape(
                    q, pl * v_max)
            y, scanned = kops.bottomup_scan_op(
                colf, valf, xf, edges["t_kreal"].reshape(pl * v_max),
                semiring=dopt_cfg.semiring, early_exit=dopt_cfg.uniform,
                skip=skip, interpret=dopt_cfg.interpret)
            acc = acc_b.at[:, :, :v_max].min(y.reshape(q, pl, v_max))
            cnt = jnp.sum(scanned, axis=1).astype(jnp.int32) + cnt_bnd
            return acc, zero, cnt

        acc, cp, cl = _direction_select(want, run_push, run_pull,
                                        (state, step))
        dopt = _dopt_fold(dopt, want, cp + cl)
    else:
        acc = compute_push(state, step)

    if dyn is not None:
        # Delta-slot tail: inserted edges, reduced over the same segment
        # space (sink-pointing slots are unoccupied and vanish in the ⊕).
        d_edges = dict(src=dyn["d_src"], dst_ext=dyn["d_dst_ext"])
        if "d_weight" in dyn:
            d_edges["weight"] = dyn["d_weight"]
        d_dims = _Dims(dims.num_parts, dims.v_max,
                       dyn["d_src"].shape[1], dims.o_max)
        d_acc = _compute_reference(d_dims, program, d_edges, state, step)
        acc = _COMBINE[combine](acc, d_acc)
    q = acc.shape[0]
    local_acc = acc[:, :, : dims.v_max]
    outbox = acc[:, :, dims.v_max + 1:].reshape(q, pl, dims.num_parts,
                                                dims.o_max)

    # -- communicate: outbox -> symmetric inbox (paper Fig. 6); the wire
    # ships Q slot blocks per pair — topology maps are never duplicated ----
    inbox = exchange(outbox)  # [Q, pl, P, o_max]

    # -- scatter: combine inbox messages into local vertex accumulator ------
    offs = (jnp.arange(q * pl, dtype=jnp.int32)
            * (dims.v_max + 1)).reshape(q, pl, 1, 1)
    in_ids = edges["inbox_dst"][None] + offs
    racc = seg_op(inbox.ravel(), in_ids.ravel(),
                  num_segments=q * pl * (dims.v_max + 1))
    racc = racc.reshape(q, pl, dims.v_max + 1)[:, :, : dims.v_max]
    total = _COMBINE[combine](local_acc, racc)

    # -- apply + vote (per query) -------------------------------------------
    new_state, finished = jax.vmap(program.apply_fn,
                                   in_axes=(0, 0, None))(state, total, step)
    if dopt is not None:
        new_state = dict(new_state, **dopt)
    return new_state, all_finished(finished)


def _edges_dict(ea: EdgeArrays, blk: Optional[BlockMetadata] = None) -> dict:
    d = dict(src=jnp.asarray(ea.src), dst_ext=jnp.asarray(ea.dst_ext),
             inbox_dst=jnp.asarray(ea.inbox_dst))
    if ea.weight is not None:
        d["weight"] = jnp.asarray(ea.weight)
    if blk is not None:
        # Block metadata rides in the edges dict so it shards with the
        # partition axis under the distributed engine.
        d["blk_src"] = jnp.asarray(blk.src)
        d["blk_local"] = jnp.asarray(blk.local)
        d["blk_mask"] = jnp.asarray(blk.mask)
        d["blk_base"] = jnp.asarray(blk.base)
        if blk.weight is not None:
            d["weight_blk"] = jnp.asarray(blk.weight)
    return d


def _run_batched_loop(step_fn: Callable, max_steps: int,
                      state: BatchedState,
                      q: int) -> Tuple[BatchedState, Array]:
    """One ``lax.while_loop`` advancing all Q queries together.

    ``step_fn(state, step) -> (state, finished[Q])`` is any superstep
    closure; queries vote finish independently.  A converged query is
    masked out of the apply step — its state leaves freeze bitwise via a
    per-query ``where`` — while unfinished queries continue, so a batch
    reproduces each query's sequential trajectory exactly.  Returns the
    final state and per-query executed superstep counts ``steps[Q]``
    (identical to the sequential engine's ``steps`` for each query).
    """
    def freeze(fin, new, old):
        return jnp.where(fin.reshape(fin.shape + (1,) * (new.ndim - 1)),
                         old, new)

    def body(carry):
        st, step, fin, steps_q = carry
        new_st, vote = step_fn(st, step)
        new_st = jax.tree.map(functools.partial(freeze, fin), new_st, st)
        steps_q = steps_q + jnp.logical_not(fin).astype(jnp.int32)
        return new_st, step + 1, jnp.logical_or(fin, vote), steps_q

    def cond(carry):
        _, step, fin, _ = carry
        return jnp.logical_and(~jnp.all(fin), step < max_steps)

    init = (state, jnp.int32(0), jnp.zeros((q,), jnp.bool_),
            jnp.zeros((q,), jnp.int32))
    state, _, _, steps_q = jax.lax.while_loop(cond, body, init)
    return state, steps_q


def _run_chunked_loop(step_fn: Callable, chunk: int, max_steps: int,
                      state: BatchedState, step0: Array, fin0: Array,
                      steps_q0: Array):
    """A bounded window of ``_run_batched_loop``: advance at most ``chunk``
    supersteps from a mid-run carry.

    Identical body (freeze-masked apply, per-query vote and step
    accounting); the cond additionally stops at ``step0 + chunk``.  Because
    ``step0`` is a **traced** operand, one compiled trace serves every
    window, and chaining windows end to end executes the exact same
    superstep sequence as the single resident loop — the carry that escapes
    to host between windows (state, step, finished votes, per-query step
    counters) is the checkpointable snapshot.  Returns the full carry.
    """
    def freeze(fin, new, old):
        return jnp.where(fin.reshape(fin.shape + (1,) * (new.ndim - 1)),
                         old, new)

    def body(carry):
        st, step, fin, steps_q = carry
        new_st, vote = step_fn(st, step)
        new_st = jax.tree.map(functools.partial(freeze, fin), new_st, st)
        steps_q = steps_q + jnp.logical_not(fin).astype(jnp.int32)
        return new_st, step + 1, jnp.logical_or(fin, vote), steps_q

    def cond(carry):
        _, step, fin, _ = carry
        return jnp.logical_and(
            ~jnp.all(fin),
            jnp.logical_and(step < max_steps, step < step0 + chunk))

    return jax.lax.while_loop(cond, body, (state, step0, fin0, steps_q0))


# ---------------------------------------------------------------------------
# checksummed exchange (silent-corruption defense, docs/robustness.md)
# ---------------------------------------------------------------------------


def _payload_tag(x: Array, axes) -> Array:
    """Order-independent int32 reduction tag over ``axes`` of a payload.

    Bitcast-to-int32 then wrapping integer sum: deterministic under any
    reduction order (unlike float sums), and any single-element change moves
    the sum by a nonzero delta mod 2^32 — a one-bit wire flip always
    mismatches."""
    if x.dtype.itemsize == 4:
        words = jax.lax.bitcast_convert_type(x, jnp.int32)
    else:
        words = x.astype(jnp.int32)
    return jnp.sum(words, axis=axes, dtype=jnp.int32)


def _flip_wire(x: Array) -> Array:
    """Flip one mantissa bit of a payload's first element (the
    ``exchange.payload`` chaos drill's trace-level corruption)."""
    if x.dtype.itemsize != 4:
        return x
    flat = x.reshape(-1)
    words = jax.lax.bitcast_convert_type(flat, jnp.int32)
    words = words.at[0].set(words[0] ^ jnp.int32(1 << 20))
    return jax.lax.bitcast_convert_type(words, x.dtype).reshape(x.shape)


def _flip_state_bit(state: BatchedState, bit: int = 20) -> BatchedState:
    """Host-side single-bit corruption of every float32 state leaf (the
    ``state.corrupt`` chaos site).  Runs between compiled windows, so it
    models a DRAM/transfer bit-flip without perturbing the jit cache."""
    def flip(leaf):
        arr = np.array(leaf)
        if arr.dtype != np.float32 or arr.size == 0:
            return leaf
        arr.reshape(-1).view(np.int32)[0] ^= np.int32(1 << bit)
        return jnp.asarray(arr)
    return jax.tree.map(flip, state)


class _ExchangeGuard:
    """Per-engine box threading exchange-checksum state through a trace.

    Stored as an engine attribute so the jitted chunk methods (whose
    ``self`` is a static argument) see one stable closure identity;
    ``arm``/``reset``/``add``/``read`` are trace-time operations — the
    armed ``poison`` operand and the accumulated mismatch count are traced
    values referenced positionally by the compiled window, so cache hits
    behave identically to the first trace."""

    def __init__(self):
        self.poison = jnp.float32(0.0)
        self._bad = jnp.int32(0)

    def arm(self, poison: Array) -> None:
        self.poison = poison

    def reset(self) -> None:
        self._bad = jnp.int32(0)

    def add(self, n: Array) -> None:
        self._bad = self._bad + jnp.asarray(n, jnp.int32)

    def read(self) -> Array:
        return self._bad


def _checked_exchange(guard: _ExchangeGuard) -> Callable[[Array], Array]:
    """Single-device exchange with per-(partition, peer) reduction tags.

    Send-side tags are computed on the outbox slot blocks *before* the wire
    (where the ``exchange.payload`` drill corrupts under the armed poison
    operand); the inbox side re-derives them and any mismatch lands in the
    guard — the host converts a nonzero window count into an
    ``ExchangeCorruption`` and replays the window."""
    def exchange(outbox: Array) -> Array:
        send_tags = _payload_tag(outbox, (0, 3))            # [pl, P]
        wire = jnp.where(guard.poison > 0, _flip_wire(outbox), outbox)
        inbox = wire.transpose(0, 2, 1, 3)                  # [Q, P, pl, o]
        recv_tags = _payload_tag(inbox, (0, 3))             # [P, pl]
        guard.add(jnp.sum((recv_tags != send_tags.T).astype(jnp.int32)))
        return inbox
    return exchange


def _run_chunked_loop_guarded(step_fn: Callable, guard: _ExchangeGuard,
                              chunk: int, max_steps: int,
                              state: BatchedState, step0: Array, fin0: Array,
                              steps_q0: Array):
    """:func:`_run_chunked_loop` with the exchange guard in the carry.

    Identical superstep semantics (the extra carry element never feeds back
    into the state); per superstep the guard is reset, the step function's
    checked exchanges accumulate mismatches into it, and the count joins
    the loop carry — read *inside* the body trace, so no tracer leaks.
    Returns ``(state, step, fin, steps_q, bad)``."""
    def freeze(fin, new, old):
        return jnp.where(fin.reshape(fin.shape + (1,) * (new.ndim - 1)),
                         old, new)

    def body(carry):
        st, step, fin, steps_q, bad = carry
        guard.reset()
        new_st, vote = step_fn(st, step)
        new_st = jax.tree.map(functools.partial(freeze, fin), new_st, st)
        steps_q = steps_q + jnp.logical_not(fin).astype(jnp.int32)
        return (new_st, step + 1, jnp.logical_or(fin, vote), steps_q,
                bad + guard.read())

    def cond(carry):
        _, step, fin, _, _ = carry
        return jnp.logical_and(
            ~jnp.all(fin),
            jnp.logical_and(step < max_steps, step < step0 + chunk))

    return jax.lax.while_loop(
        cond, body, (state, step0, fin0, steps_q0, jnp.int32(0)))


@jax.jit
def _slot_swap(state: BatchedState, new_rows: BatchedState, admit: Array,
               fin: Array, steps_q: Array):
    """Static-shape slot refill for continuous batching.

    ``admit`` is a ``[Q]`` bool mask of slots taking a new tenant: their
    state leaves are replaced wholesale by ``new_rows``' (a full-Q pytree
    whose non-admitted rows are ignored), their finished votes cleared, and
    their superstep counters zeroed — everything else passes through
    **bitwise** unchanged.  Q is static and the carry shapes never change,
    so one compiled trace serves every refill of a serving session; the
    same trace serves ``DistributedBSPEngine`` (the query axis is
    replicated — a per-slot swap needs no communication, and the next
    chunk window re-shards the carry on entry).
    """
    def swap(new, old):
        return jnp.where(admit.reshape(admit.shape + (1,) * (old.ndim - 1)),
                         new, old)

    state = jax.tree.map(swap, new_rows, state)
    fin = jnp.where(admit, jnp.bool_(False), fin)
    steps_q = jnp.where(admit, jnp.int32(0), steps_q)
    return state, fin, steps_q


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _run_dyn_jit(dims: _Dims, program: VertexProgram,
                 fused_cfg: Optional[FusedConfig], max_steps: int,
                 fixed_steps: Optional[int], edges: dict, dyn: dict,
                 state: BatchedState):
    """Dynamic-graph batched runner (reference/fused backends).

    Unlike the static ``BSPEngine.run_batched`` — whose closed-over edge
    arrays become compiled constants — every array here (base edges AND the
    mutation payload) is a **traced argument**: mutation batches between
    runs reuse one trace (shapes never change), and a compaction can never
    be served stale values from the jit cache (a shape change retraces, a
    shape-preserving rebuild just passes new operands).
    """
    step_fn = functools.partial(_superstep, dims, program, edges,
                                BSPEngine._exchange,
                                BSPEngine._all_finished, fused_cfg, dyn=dyn)
    if fixed_steps is not None:
        def body(i, st):
            st, _ = step_fn(st, i)
            return st
        return jax.lax.fori_loop(0, fixed_steps, body, state)
    return _run_batched_loop(step_fn, max_steps, state, num_queries(state))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _run_dyn_chunk_jit(dims: _Dims, program: VertexProgram,
                       fused_cfg: Optional[FusedConfig], max_steps: int,
                       chunk: int, edges: dict, dyn: dict,
                       state: BatchedState, step: Array, fin: Array,
                       steps_q: Array):
    """Chunked window of ``_run_dyn_jit`` (same traced-operand contract:
    mutation batches and engine rebuilds after a restart reuse one trace)."""
    step_fn = functools.partial(_superstep, dims, program, edges,
                                BSPEngine._exchange,
                                BSPEngine._all_finished, fused_cfg, dyn=dyn)
    return _run_chunked_loop(step_fn, chunk, max_steps, state, step, fin,
                             steps_q)


def _vote_never(apply_fn):
    def wrapped(state, acc, step):
        new_state, _ = apply_fn(state, acc, step)
        return new_state, jnp.bool_(False)
    return wrapped


@functools.lru_cache(maxsize=None)
def _fixed_step_program(program: VertexProgram,
                        num_steps: int) -> VertexProgram:
    """Fixed-iteration restatement of ``program``: never votes finish, so
    the while_loop path runs exactly ``num_steps`` supersteps — how the
    *distributed dynamic* engine serves ``run_fixed_batched`` through the
    same sharded machinery as ``run_batched``.  Memoized so repeated calls
    reuse one program identity (the jit caches key on it)."""
    return dataclasses.replace(program, max_steps=num_steps,
                               apply_fn=_vote_never(program.apply_fn))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _run_dyn_hybrid_jit(program: VertexProgram, cfg: _HybridCfg,
                        max_steps: int, fixed_steps: Optional[int],
                        arrs: dict, state: BatchedState):
    """Dynamic-graph batched runner, hybrid degree-split backend: the
    dense block / ELL arrays arrive as traced arguments so in-place
    mutation writes (and post-compaction rebuilds) never hit a stale
    compiled constant."""
    step_fn = functools.partial(_superstep_hybrid, program, cfg, arrs,
                                BSPEngine._all_finished)
    if fixed_steps is not None:
        def body(i, st):
            st, _ = step_fn(st, i)
            return st
        return jax.lax.fori_loop(0, fixed_steps, body, state)
    return _run_batched_loop(step_fn, max_steps, state, num_queries(state))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _run_dyn_hybrid_chunk_jit(program: VertexProgram, cfg: _HybridCfg,
                              max_steps: int, chunk: int, arrs: dict,
                              state: BatchedState, step: Array, fin: Array,
                              steps_q: Array):
    """Chunked window of ``_run_dyn_hybrid_jit``."""
    step_fn = functools.partial(_superstep_hybrid, program, cfg, arrs,
                                BSPEngine._all_finished)
    return _run_chunked_loop(step_fn, chunk, max_steps, state, step, fin,
                             steps_q)


# ---------------------------------------------------------------------------
# Tiered (out-of-core) execution: host-resident cold partitions streamed
# through the superstep in double-buffered clean-cut windows (docs/memory.md)
# ---------------------------------------------------------------------------

def _cache_entries_of(fn) -> int:
    getter = getattr(fn, "_cache_size", None)
    return int(getter()) if getter is not None else 0


def _ident_of(combine: str):
    return jnp.float32(jnp.inf) if combine == MIN else jnp.float32(0.0)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _tiered_hot_jit(dims: _Dims, program: VertexProgram,
                    fused_cfg: Optional[FusedConfig], hot_idx, edges_hot,
                    dyn_hot, state: BatchedState, step: Array) -> Array:
    """Identity-initialized [Q, P, seg] accumulator with the hot (resident)
    partitions' compute folded in.

    Per-(query, partition) segment offsets make the big resident reduce
    row-independent, so running the same compute on the hot row *subset* and
    scattering into the full accumulator reproduces those rows bitwise; cold
    rows stay at the reduction identity until their windows stream through.
    ``dyn_hot`` carries the hot rows' tombstone/delta overlay (the same
    folding ``_superstep`` applies, sliced to the resident rows).
    """
    q = num_queries(state)
    acc = jnp.full((q, dims.num_parts, dims.seg),
                   _ident_of(program.combine), jnp.float32)
    if edges_hot is None:
        return acc
    state_h = jax.tree.map(lambda x: x[:, hot_idx], state)
    edges = edges_hot
    if dyn_hot is not None:
        edges = dict(edges)
        tomb = dyn_hot["tomb"]
        edges["dst_ext"] = jnp.where(tomb, dims.v_max, edges["dst_ext"])
        if "blk_mask" in edges:
            pad = edges["blk_mask"].shape[1] - tomb.shape[1]
            alive = jnp.pad(jnp.logical_not(tomb), ((0, 0), (0, pad)))
            edges["blk_mask"] = edges["blk_mask"] * alive.astype(
                edges["blk_mask"].dtype)
    if fused_cfg is not None and program.edge_msg is not None:
        acc_h = _compute_fused(dims, program, edges, fused_cfg, state_h, step)
    else:
        acc_h = _compute_reference(dims, program, edges, state_h, step)
    if dyn_hot is not None:
        d_edges = dict(src=dyn_hot["d_src"], dst_ext=dyn_hot["d_dst_ext"])
        if "d_weight" in dyn_hot:
            d_edges["weight"] = dyn_hot["d_weight"]
        d_acc = _compute_reference(dims, program, d_edges, state_h, step)
        acc_h = _COMBINE[program.combine](acc_h, d_acc)
    return acc.at[:, hot_idx].set(acc_h)


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(4,))
def _tiered_window_jit(dims: _Dims, program: VertexProgram,
                       fused_cfg: Optional[FusedConfig], p: Array,
                       acc: Array, win: dict, state: BatchedState,
                       step: Array) -> Array:
    """Fold one streamed cold-partition window into accumulator row ``p``.

    ``p`` is a *traced* scalar and every window of a schedule has the same
    fixed shapes (short windows arrive sink-padded), so one compiled trace
    serves the whole stream — the steady state never retraces.  ``acc`` is
    donated: the in-flight double buffer is the only extra device memory.
    Clean-cut windows mean each segment's real edges live in exactly one
    window; every other window contributes the reduction identity, which
    the cross-window combine absorbs bitwise.
    """
    state_p = jax.tree.map(
        lambda x: jax.lax.dynamic_slice_in_dim(x, p, 1, axis=1), state)
    edges = {k: v[None] for k, v in win.items() if k != "tomb"}
    if "tomb" in win:
        tomb = win["tomb"][None]
        edges["dst_ext"] = jnp.where(tomb, dims.v_max, edges["dst_ext"])
        if "blk_mask" in edges:
            edges["blk_mask"] = edges["blk_mask"] * jnp.logical_not(
                tomb).astype(edges["blk_mask"].dtype)
    if fused_cfg is not None and program.edge_msg is not None:
        acc_w = _compute_fused(dims, program, edges, fused_cfg, state_p, step)
    else:
        acc_w = _compute_reference(dims, program, edges, state_p, step)
    row = jax.lax.dynamic_slice_in_dim(acc, p, 1, axis=1)
    row = _COMBINE[program.combine](row, acc_w)
    return jax.lax.dynamic_update_slice_in_dim(acc, row, p, axis=1)


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(2,))
def _tiered_apply_jit(dims: _Dims, program: VertexProgram, acc: Array,
                      inbox_dst: Array, state: BatchedState, step: Array,
                      fin: Array, steps_q: Array):
    """Exchange + scatter + apply on a fully-assembled accumulator: the tail
    of ``_superstep`` plus ``_run_batched_loop``'s freeze/vote body, so one
    host-driven tiered superstep is carry-for-carry identical to one
    resident loop iteration."""
    combine = program.combine
    seg_op = _SEGMENT_OP[combine]
    q, pl = acc.shape[0], dims.num_parts
    local_acc = acc[:, :, : dims.v_max]
    outbox = acc[:, :, dims.v_max + 1:].reshape(q, pl, dims.num_parts,
                                                dims.o_max)
    inbox = BSPEngine._exchange(outbox)
    offs = (jnp.arange(q * pl, dtype=jnp.int32)
            * (dims.v_max + 1)).reshape(q, pl, 1, 1)
    in_ids = inbox_dst[None] + offs
    racc = seg_op(inbox.ravel(), in_ids.ravel(),
                  num_segments=q * pl * (dims.v_max + 1))
    racc = racc.reshape(q, pl, dims.v_max + 1)[:, :, : dims.v_max]
    total = _COMBINE[combine](local_acc, racc)
    new_state, vote = jax.vmap(program.apply_fn,
                               in_axes=(0, 0, None))(state, total, step)

    def freeze(new, old):
        return jnp.where(fin.reshape(fin.shape + (1,) * (new.ndim - 1)),
                         old, new)

    new_state = jax.tree.map(freeze, new_state, state)
    steps_q = steps_q + jnp.logical_not(fin).astype(jnp.int32)
    fin = jnp.logical_or(fin, vote)
    return new_state, fin, steps_q


@functools.partial(jax.jit, static_argnums=(0, 1))
def _tiered_hyb_hot_jit(program: VertexProgram, cfg: _HybridCfg, slot,
                        col_hot, val_hot, rows_hot, state: BatchedState,
                        step: Array):
    """Hybrid flavor: message vector + identity-initialized per-row ELL
    accumulator with the resident (hot-partition) rows' reductions
    scattered in.  A whole ELL row is atomic — its kmax-entry reduce runs
    wherever the row lives — so row-level tiering needs no clean-cut
    analysis; the dense MXU block always stays resident."""
    from repro.core.hybrid import add_identity
    from repro.kernels.ops import ell_spmv_op

    spec = program.edge_msg
    ident = add_identity(cfg.semiring)
    q = state[spec.gather[0]].shape[0]
    vals = {k: state[k].astype(jnp.float32).reshape(q, -1)[:, slot]
            for k in spec.gather}
    consts = {c: state[c][:, :1].astype(jnp.float32) for c in spec.consts}
    w_ident = None
    if spec.use_weight:
        w_ident = jnp.float32(0.0 if spec.weight_op == "add" else 1.0)
    x = spec.fn(vals, w_ident, step.astype(jnp.float32),
                consts).astype(jnp.float32)
    xs = jnp.concatenate([x, jnp.full((q, 1), ident, x.dtype)], axis=1)
    y = jnp.full((q, cfg.num_vertices + 1), ident, jnp.float32)
    if col_hot is not None:
        y_hot = ell_spmv_op(col_hot, val_hot, xs, semiring=cfg.semiring,
                            interpret=cfg.interpret)
        y = y.at[:, rows_hot].set(y_hot)
    return xs, y


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _tiered_hyb_win_jit(cfg: _HybridCfg, y: Array, col_w, val_w, rows_w,
                        xs: Array) -> Array:
    """One streamed ELL row window: reduce the window's rows, scatter-set
    them into the per-row accumulator (pad rows land on the sink column)."""
    from repro.kernels.ops import ell_spmv_op

    y_w = ell_spmv_op(col_w, val_w, xs, semiring=cfg.semiring,
                      interpret=cfg.interpret)
    return y.at[:, rows_w].set(y_w)


def _make_tiered_hyb_acc(cfg: _HybridCfg, dense, hid):
    """Build the dense-block-combine + layout-gather jit for one tiered
    hybrid binding: mirrors ``hybrid_spmv``'s ELL-then-dense order and
    returns the [Q, P, v_max] accumulator.

    ``dense``/``hid`` are deliberately *closed over as numpy* so they enter
    the trace as constants, exactly as the resident ``_superstep_hybrid``
    trace sees them: a constant adjacency operand lets XLA pick the same
    gemm layout (and hence the same accumulation order) in both
    compilations — passed as device parameters instead, the dot rounds
    1 ulp differently and streamed-vs-resident bitwise parity breaks."""
    from repro.core.hybrid import add_identity
    from repro.kernels import ops as kops

    ident = add_identity(cfg.semiring)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def acc_fn(y: Array, xs: Array) -> Array:
        q = y.shape[0]
        yv = y[:, : cfg.num_vertices]
        if cfg.k_dense:
            # Barriers matched with ``hybrid_spmv``'s dense stage: both
            # paths compile the dot as the same isolated subgraph.
            x = jax.lax.optimization_barrier(xs[:, : cfg.k_dense])
            if cfg.semiring == "plus_times":
                yh = jax.lax.optimization_barrier(
                    kops.dense_spmv_op(x, dense, interpret=cfg.interpret))
                yv = yv.at[:, : cfg.k_dense].add(yh)
            else:
                yh = jax.lax.optimization_barrier(
                    kops.dense_spmv_minplus_op(x, dense,
                                               interpret=cfg.interpret))
                yv = yv.at[:, : cfg.k_dense].min(yh)
        y_ext = jnp.concatenate([yv, jnp.full((q, 1), ident, yv.dtype)],
                                axis=1)
        return y_ext[:, hid]

    return acc_fn


@functools.partial(jax.jit, static_argnums=(0,))
def _tiered_hyb_apply_jit(program: VertexProgram, acc: Array,
                          state: BatchedState, step: Array, fin: Array,
                          steps_q: Array):
    """Apply + the batched loop's freeze/vote body.

    ``acc`` arrives as a jit *parameter* on purpose: when the accumulator
    assembly shares a graph with ``apply_fn``, XLA's FMA-contraction choice
    for expressions like ``delta + damping * acc`` can differ from the
    resident compilation's by 1 ulp — the parameter boundary pins the
    rounding the resident path exhibits."""
    new_state, vote = jax.vmap(program.apply_fn,
                               in_axes=(0, 0, None))(state, acc, step)

    def freeze(new, old):
        return jnp.where(fin.reshape(fin.shape + (1,) * (new.ndim - 1)),
                         old, new)

    new_state = jax.tree.map(freeze, new_state, state)
    steps_q = steps_q + jnp.logical_not(fin).astype(jnp.int32)
    fin = jnp.logical_or(fin, vote)
    return new_state, fin, steps_q


_TIERED_JITS = (_tiered_hot_jit, _tiered_window_jit, _tiered_apply_jit,
                _tiered_hyb_hot_jit, _tiered_hyb_win_jit,
                _tiered_hyb_apply_jit)


def tiered_cache_entries() -> int:
    """Total compile-cache entries across the tiered-path jits (the
    zero-steady-state-retrace gates diff this across supersteps)."""
    return sum(_cache_entries_of(f) for f in _TIERED_JITS)


# ---------------------------------------------------------------------------
# one-shot DeprecationWarnings for the pre-execute() aliases
# ---------------------------------------------------------------------------

REFERENCE = "reference"
FUSED = "fused"
HYBRID = "hybrid"
BACKENDS = (REFERENCE, FUSED, HYBRID)


class BSPEngine:
    """Single-device engine: all P partitions stacked on axis 0.

    Three selectable execution backends for the compute phase:

    - ``backend="reference"`` — gather → [Pl, e_max] messages →
      segment-reduce (always available; the correctness oracle).
    - ``backend="fused"`` — the fused Pallas superstep kernel for programs
      that carry an :class:`EdgeMessage` form; falls back to reference
      whenever a direction's measured block span exceeds ``max_span``
      (degree-skewed / gappy destination data — see
      ``BlockMetadata.span_histogram``).  ``fused=True`` is the back-compat
      spelling.
    - ``backend="hybrid"`` — the degree-split two-engine step (dense H×H MXU
      block + ELL remainder, core/hybrid.py) run as a whole-graph semiring
      SpMV; ``hybrid_k_dense=None`` lets the performance model pick the
      split (argmin predicted makespan — the paper's Eq. 4 role), and for
      min combines a frontier-density ``pull_threshold`` switches push/pull
      direction per superstep.  Requires ``pg.source``; programs without an
      eligible EdgeMessage run the reference path.
    """

    def __init__(self, pg, *, backend: Optional[str] = None,
                 fused: bool = False, block_e: int = 1024,
                 max_span: int = 4096, gather_chunk: int = 256,
                 interpret: Optional[bool] = None,
                 hybrid_k_dense: Optional[int] = None,
                 pull_threshold: Optional[float] = None,
                 direction: str = "auto",
                 direction_switch: bool = True,
                 dynamic_ell_spare: int = 8,
                 tiered=None, win_blocks: int = 8):
        from repro.core.dynamic import DynamicGraph

        if backend is None:
            backend = FUSED if fused else REFERENCE
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; pick one of "
                             f"{BACKENDS}")
        if direction not in ("auto", "push", "pull"):
            raise ValueError(f"direction must be 'auto', 'push' or 'pull', "
                             f"got {direction!r}")
        self.backend = backend
        self.fused = backend == FUSED
        self.interpret = interpret
        self._block_e = block_e
        self._max_span = max_span
        self._gather_chunk = gather_chunk
        self._hybrid_k_dense = hybrid_k_dense
        # None → fit the push/pull crossover from the perf model
        # (perf_model.fit_pull_threshold, per backend / per shard); a float
        # forces that density threshold everywhere.
        self._pull_threshold_req = pull_threshold
        self._pull_threshold = (0.05 if pull_threshold is None
                                else pull_threshold)
        self.direction = direction
        self._dopt_forced = {"auto": None, "push": _DIR_PUSH,
                             "pull": _DIR_PULL}[direction]
        self._direction_switch = direction_switch
        # Per-query direction decisions of the last execute() on an
        # eligible min-combine program: {"direction" [Q, P] (-1 = never
        # decided), "edges_examined" [Q], "switches" [Q]}.
        self.last_direction_stats: Optional[dict] = None
        self._dyn_ell_spare = dynamic_ell_spare
        # Out-of-core tiering: ``tiered`` is an HBM byte budget (int) or a
        # prebuilt partition.TierPlan; None keeps everything resident.
        self._tiered_req = tiered
        self._win_blocks = win_blocks
        self.tier_plan = None
        # One guard per engine: jitted chunk windows arm it with the traced
        # poison operand and accumulate exchange-checksum mismatches.
        self._guard = _ExchangeGuard()

        # Dynamic graphs hand the engine a mutable layout: the engine reads
        # the mutation payload as traced jit arguments each run (never as
        # compiled constants) and rebinds itself after a compaction.
        self.dg: Optional[DynamicGraph] = None
        self.dynamic_rebinds = 0
        # dynamic-hybrid split rebuilds (spare-ELL overflow / batch log no
        # longer reaching the cursor): legitimate shape-changing recompiles
        # the retrace gates must discount, like compaction rebinds
        self.hybrid_dyn_rebuilds = 0
        if isinstance(pg, DynamicGraph):
            self.dg = pg
            self._dyn_version = pg.version
            pg = pg.pg
        if (self._tiered_req is not None and self.backend == HYBRID
                and self.dg is not None):
            raise ValueError(
                "tiered= with backend='hybrid' does not support dynamic "
                "graphs: delta slots stream with their base edge blocks, "
                "which the row-tiered ELL split has no blocks for; use "
                "backend='reference' or 'fused' for tiered dynamic runs")
        self._bind(pg)
        if self.dg is not None:
            # Instance-level dispatch: the class attributes stay the jitted
            # static-path methods (their compile-cache introspection is part
            # of the serving contract); a dynamic engine shadows them.
            self._run_batched = self._run_batched_dyn
            self._run_fixed_batched = self._run_fixed_batched_dyn
        if self.tier_plan is not None:
            # Tiered shadows go on *after* the dynamic ones so tiered
            # dispatch wins; the tiered loop folds the dynamic payload in
            # itself (hot rows sliced on device, cold tombstones/deltas
            # streamed with their partitions' windows).
            self._run_batched = self._run_batched_tiered
            self._run_fixed_batched = self._run_fixed_batched_tiered

    @property
    def pg(self) -> PartitionedGraph:
        """The current partitioned layout.  On a dynamic engine this first
        syncs with the DynamicGraph (rebinds after compaction — and, on the
        distributed hybrid, folds pending mutations), so state constructed
        from ``engine.pg`` always matches the layout the next run uses."""
        if self.dg is not None:
            self._sync_dynamic()
        return self._pg

    def _bind(self, pg: PartitionedGraph) -> None:
        """Derive every pg-shaped structure (edge dicts, block metadata,
        hybrid plan/caches).  Construction and post-compaction rebinds both
        land here."""
        self._pg = pg
        block_e, gather_chunk = self._block_e, self._gather_chunk
        self.dims = _Dims(pg.num_parts, pg.v_max, pg.fwd.e_max, pg.fwd.o_max)
        self._fwd_blk = self._rev_blk = None
        if self.fused:
            self._fwd_blk = build_block_metadata(pg.fwd, block_e=block_e)
            if pg.rev is not None:
                self._rev_blk = build_block_metadata(pg.rev, block_e=block_e)
        self._fwd = _edges_dict(pg.fwd, self._fwd_blk)
        self._rev = (_edges_dict(pg.rev, self._rev_blk)
                     if pg.rev is not None else None)

        def _cfg(blk):
            if blk is None:
                return None
            v_pad = -(-pg.v_max // gather_chunk) * gather_chunk
            return FusedConfig(span=blk.span, block_e=blk.block_e,
                               v_pad=v_pad, max_span=self._max_span,
                               gather_chunk=gather_chunk,
                               interpret=self.interpret)

        self._fwd_cfg = _cfg(self._fwd_blk)
        self._rev_cfg = _cfg(self._rev_blk)
        self.out_deg = jnp.asarray(pg.out_deg)
        self.vertex_mask = jnp.asarray(pg.vertex_mask)

        self._hybrid_cache: dict = {}
        self._hybrid_dyn_cache: dict = {}
        self._chunk_jits: dict = {}
        self._hybrid_plan: Optional[dict] = None
        if self.backend == HYBRID:
            if pg.source is None:
                raise ValueError(
                    "hybrid backend needs PartitionedGraph.source; "
                    "re-partition with core.partition.partition()")
            self._hybrid_plan = self._plan_hybrid(self._hybrid_k_dense,
                                                  block_e)
        self._bind_tiered(pg)

    def _bind_tiered(self, pg: PartitionedGraph) -> None:
        """Out-of-core residency: split partitions across the HBM/host tiers
        and stage the cold ones as host window arenas.

        Hot partitions' edge (and block) arrays go on device once, exactly
        like the resident dicts; each cold partition's edges become a list
        of clean-cut windows — fixed-shape numpy dicts the run loop
        ``jax.device_put``s through a double buffer.  Window padding is the
        per-row segment sink (reference) / masked-out blocks with a sink
        base (fused), so a short window reduces to exactly its real edges.
        The hybrid backend tiers at ELL-row granularity instead and keeps
        its normal binding (built lazily per program in
        ``_hybrid_tiered_for``)."""
        from repro.core.partition import TierPlan, build_tier_plan

        self.tier_plan = None
        self._hyb_tier_cache: dict = {}
        if self._tiered_req is None:
            return
        if isinstance(self._tiered_req, TierPlan):
            self.tier_plan = self._tiered_req
        else:
            self.tier_plan = build_tier_plan(
                pg, int(self._tiered_req), block_e=self._block_e,
                win_blocks=self._win_blocks,
                fused=(self.backend != REFERENCE), dynamic=self.dg)
        plan = self.tier_plan
        hot = np.asarray(plan.hot, dtype=np.int64)
        self._tier_hot_idx = jnp.asarray(hot.astype(np.int32))
        self._tier_dev: dict = {}
        self._tier_arena: dict = {}
        self._tier_dims: dict = {}
        self._tier_inbox: dict = {}
        for use_rev, ea, blk, sched in (
                (False, pg.fwd, self._fwd_blk, plan.fwd),
                (True, pg.rev, self._rev_blk, plan.rev)):
            if ea is None or sched is None:
                continue
            dims = _Dims(pg.num_parts, pg.v_max, ea.e_max, ea.o_max)
            self._tier_dims[use_rev] = dims
            self._tier_inbox[use_rev] = jnp.asarray(ea.inbox_dst)

            d = None
            if len(hot):
                d = dict(src=jnp.asarray(ea.src[hot]),
                         dst_ext=jnp.asarray(ea.dst_ext[hot]))
                if ea.weight is not None:
                    d["weight"] = jnp.asarray(ea.weight[hot])
                if blk is not None:
                    d["blk_src"] = jnp.asarray(blk.src[hot])
                    d["blk_local"] = jnp.asarray(blk.local[hot])
                    d["blk_mask"] = jnp.asarray(blk.mask[hot])
                    d["blk_base"] = jnp.asarray(blk.base[hot])
                    if blk.weight is not None:
                        d["weight_blk"] = jnp.asarray(blk.weight[hot])
            self._tier_dev[use_rev] = d

            win_e = sched.win_e
            arena = []
            for p, st, cnt in zip(sched.part, sched.start, sched.count):
                p, st, cnt = int(p), int(st), int(cnt)
                src = np.zeros(win_e, np.int32)
                src[:cnt] = ea.src[p, st:st + cnt]
                dst = np.full(win_e, pg.v_max, np.int32)
                dst[:cnt] = ea.dst_ext[p, st:st + cnt]
                w = dict(src=src, dst_ext=dst)
                if ea.weight is not None:
                    wt = np.zeros(win_e, np.float32)
                    wt[:cnt] = ea.weight[p, st:st + cnt]
                    w["weight"] = wt
                if blk is not None:
                    # Slices past this window's real blocks would alias the
                    # *next* window's real edges (the flat block arrays are
                    # contiguous per partition) — pad with masked-out zeros
                    # and sink bases instead of slicing blindly.
                    for key, arr in (("blk_src", blk.src),
                                     ("blk_local", blk.local),
                                     ("blk_mask", blk.mask)):
                        a = np.zeros(win_e, np.int32)
                        a[:cnt] = arr[p, st:st + cnt]
                        w[key] = a
                    nb = -(-cnt // sched.block_e)
                    b0 = st // sched.block_e
                    base = np.full(sched.win_blocks, dims.seg, np.int32)
                    base[:nb] = blk.base[p, b0:b0 + nb]
                    w["blk_base"] = base
                    if blk.weight is not None:
                        a = np.zeros(win_e, np.float32)
                        a[:cnt] = blk.weight[p, st:st + cnt]
                        w["weight_blk"] = a
                arena.append((p, w))
            self._tier_arena[use_rev] = arena
        if self.backend != HYBRID:
            # Cold edges have no resident dict; edges_for raises the fix.
            # (The hybrid backend keeps its binding — its eligible programs
            # tier at ELL-row granularity, and ineligible ones stream the
            # reference-flavor arenas built above.)
            self._fwd = self._rev = None

    # ---------------------- hybrid backend plumbing ------------------------

    def _plan_hybrid(self, k_dense: Optional[int], block_e: int) -> dict:
        """Pick |H| from the perf model (paper Eq. 4 role), or honour an
        explicit ``hybrid_k_dense``; candidates come from the block-span
        histograms' degree-skew signal."""
        from repro.core import perf_model
        from repro.core.hybrid import edge_max_ranks

        g = self.pg.source
        blk = self._fwd_blk or build_block_metadata(self.pg.fwd,
                                                    block_e=block_e)
        skew = blk.degree_skew()
        candidates = perf_model.k_dense_candidates(g.num_vertices,
                                                   skewed=skew > 0.0)
        ranks = edge_max_ranks(g)
        if k_dense is None:
            k_dense, table = perf_model.choose_k_dense(ranks, g.num_edges,
                                                       candidates)
        else:
            table = perf_model.rank_k_dense(
                ranks, g.num_edges, sorted(set(candidates) | {k_dense}))
        chosen = next(r for r in table if r["k_dense"] == k_dense)
        return dict(k_dense=k_dense, candidates=list(candidates), skew=skew,
                    mode=perf_model.split_mode(k_dense, g.num_vertices,
                                               chosen["e_sparse"]),
                    table=table)

    def hybrid_plan(self) -> Optional[dict]:
        """The perf-model split decision (k_dense, mode, ranked table), or
        None when the engine is not the hybrid backend."""
        return self._hybrid_plan

    def _hybrid_semiring(self, program: VertexProgram) -> Optional[str]:
        """Semiring the hybrid backend would run ``program`` under, or None
        when the program is ineligible (no EdgeMessage, or the weight enters
        the message non-separably)."""
        spec = program.edge_msg
        if spec is None:
            return None
        if spec.use_weight:
            if program.combine == MIN and spec.weight_op == "add":
                return "min_plus"
            if program.combine == SUM and spec.weight_op == "mul":
                return "plus_times"
            return None
        return "plus_times" if program.combine == SUM else "min"

    def _uses_hybrid(self, program: VertexProgram) -> bool:
        return (self.backend == HYBRID
                and self._hybrid_semiring(program) is not None)

    def provides_reverse(self, program: VertexProgram) -> bool:
        """True when the engine serves a ``use_reverse`` program without
        ``pg.rev`` (the single-device hybrid degree-splits its own reverse
        graph; the distributed hybrid cannot — boundary edges route through
        the reverse outbox maps, which only ``include_reverse=True``
        partitioning builds)."""
        return self._uses_hybrid(program)

    def _hybrid_key(self, program: VertexProgram):
        # use_weight in the key: a weighted and a weightless program can map
        # to the same semiring (plus_times) but need different ⊗ values
        # (edge weights vs multiplicity counts).  frontier_uniform too: it
        # is baked into the static cfg (bottom-up early-exit licence), and
        # programs sharing a semiring can disagree on it (BFS vs CC).
        return (self._hybrid_semiring(program), program.use_reverse,
                program.edge_msg.use_weight,
                program.edge_msg.frontier_uniform)

    def _build_hybrid(self, program: VertexProgram, g,
                      with_push: bool) -> Tuple[_HybridCfg, dict, Any]:
        """One direction's degree split of ``g``: (static cfg, numpy array
        dict, the HybridGraph) — shared by the static cache and the dynamic
        rebuild path."""
        from repro.core.graph import CSRGraph
        from repro.core.hybrid import degree_split

        semiring = self._hybrid_semiring(program)
        if program.use_reverse:
            g = g.reverse()
        if not program.edge_msg.use_weight and g.weights is not None:
            # The program ignores weights; strip them so the semiring packs
            # multiplicity counts / zero-cost hops instead.
            g = CSRGraph(g.row_ptr, g.col, None)
        hg = degree_split(g, self._hybrid_plan["k_dense"], semiring=semiring)

        asg = self.pg.assignment
        n = g.num_vertices
        slot = (asg.part_of[hg.perm].astype(np.int64) * self.pg.v_max
                + asg.local_id[hg.perm]).astype(np.int32)
        hid = np.full((self.pg.num_parts, self.pg.v_max), n, dtype=np.int32)
        for p, l2g in enumerate(asg.l2g):
            hid[p, : len(l2g)] = hg.inv_perm[l2g]

        arrs = dict(dense=hg.dense_block, ell_col=hg.ell_col,
                    ell_val=hg.ell_val, slot=slot, hid=hid)
        if with_push and program.combine == MIN and self._direction_switch:
            arrs["push_src"] = hg.inv_perm[g.edge_sources()].astype(np.int32)
            arrs["push_dst"] = hg.inv_perm[g.col].astype(np.int32)
            if semiring == "min_plus" and g.weights is not None:
                arrs["push_w"] = g.weights.astype(np.float32)
            # real (non-sentinel) in-neighbour slots per ELL row — the
            # bottom-up scan kernel's per-row work bound
            arrs["ell_kreal"] = (hg.ell_col != n).sum(axis=1).astype(
                np.int32)

        thr = self._pull_threshold_req
        if thr is None:
            from repro.core import perf_model
            thr = perf_model.fit_pull_threshold(
                g.num_edges / max(n, 1), hg.ell_col.shape[1],
                backend="hybrid")
        cfg = _HybridCfg(semiring=semiring, k_dense=hg.k_dense,
                         num_vertices=n,
                         pull_threshold=float(thr),
                         interpret=self.interpret,
                         forced=self._dopt_forced,
                         uniform=program.edge_msg.frontier_uniform,
                         e_dense=int(hg.k_dense) ** 2)
        return cfg, arrs, hg

    def _hybrid_for(self, program: VertexProgram) -> Tuple[_HybridCfg, dict]:
        """Build (and cache) one direction's degree-split data.

        The cached arrays stay *numpy*: _superstep_hybrid runs at jit-trace
        time, and device arrays created inside one trace must not leak into
        the next (numpy operands become per-trace constants instead)."""
        key = self._hybrid_key(program)
        if key in self._hybrid_cache:
            return self._hybrid_cache[key]
        cfg, arrs, _ = self._build_hybrid(program, self.pg.source,
                                          with_push=True)
        self._hybrid_cache[key] = (cfg, arrs)
        return cfg, arrs

    # Local exchange: outbox[q, p, r] -> inbox[q, r, p] is a transpose over
    # the partition axes (the query axis rides along).
    @staticmethod
    def _exchange(outbox: Array) -> Array:
        return outbox.transpose(0, 2, 1, 3)

    # Single device: each query's apply vote is already its global vote.
    @staticmethod
    def _all_finished(fin: Array) -> Array:
        return fin

    def edges_for(self, program: VertexProgram) -> dict:
        if self.tier_plan is not None and self.backend != HYBRID:
            raise ValueError(
                "engine is tiered (out-of-core): cold partitions' edges "
                "live in host window arenas, not one resident edges dict; "
                "run through execute()/run_batched (the streaming path) or "
                "rebuild the engine without tiered=")
        if program.use_reverse:
            if self._rev is None:
                raise ValueError("program needs reverse edges; partition with "
                                 "include_reverse=True")
            rev = dict(self._rev)
            # reverse direction may have different e/o_max; dims adjust below
            return rev
        return self._fwd

    def fused_cfg_for(self, program: VertexProgram) -> Optional[FusedConfig]:
        """Static fused-path config, or None → reference compute."""
        if not self.fused or program.edge_msg is None:
            return None
        return self._rev_cfg if program.use_reverse else self._fwd_cfg

    def dims_for(self, edges: dict) -> _Dims:
        return _Dims(self.dims.num_parts, self.dims.v_max,
                     edges["src"].shape[1], edges["inbox_dst"].shape[2])

    # ------------------ direction-optimized traversal ----------------------

    def _dopt_semiring(self, program: VertexProgram) -> Optional[str]:
        """Min semiring the reference/fused direction machinery would run
        ``program`` under, or None when ineligible."""
        spec = program.edge_msg
        if spec is None or program.combine != MIN:
            return None
        if spec.use_weight:
            return "min_plus" if spec.weight_op == "add" else None
        return "min"

    def _dopt_cfg_for(self, program: VertexProgram) -> Optional[_DoptCfg]:
        semiring = self._dopt_semiring(program)
        if semiring is None:
            return None
        return _DoptCfg(semiring=semiring,
                        uniform=program.edge_msg.frontier_uniform,
                        forced=self._dopt_forced, interpret=self.interpret)

    def _direction_enabled(self, program: VertexProgram) -> bool:
        """Can ``execute`` thread the direction carry through ``program``?

        Min combines with an EdgeMessage only (direction is a bitwise no-op
        there).  The hybrid backend switches on its push arenas (static and
        dynamic); reference/fused need the transposed layout, which does
        not track mutations — dynamic graphs and tiered engines stay
        push-only, as do ``use_reverse`` programs (their traversal direction
        is already the reverse graph's)."""
        if not self._direction_switch or self.tier_plan is not None:
            return False
        if program.combine != MIN or program.edge_msg is None:
            return False
        if self._uses_hybrid(program):
            return True
        if self.dg is not None or program.use_reverse:
            return False
        if self._dopt_semiring(program) is None:
            return False
        if (self._dopt_semiring(program) == "min_plus"
                and self._pg.fwd.weight is None):
            return False
        return self._fwd is not None

    def _ensure_direction_edges(self) -> None:
        """Lazily grow the forward edges dict with the transposed-ELL
        arrays the pull direction needs (built once per binding; rebinds
        drop them with the dict).  Keys ride the edges dict so they shard
        over the partition axis as ordinary shard_map operands."""
        if self._fwd is None or "t_col" in self._fwd:
            return
        from repro.core import perf_model
        from repro.core.partition import build_transposed_ell

        pg = self._pg
        tell = build_transposed_ell(pg.fwd, pg.v_max)
        vmask = np.asarray(pg.vertex_mask, dtype=bool)
        nreal = np.maximum(vmask.sum(axis=1), 1).astype(np.float64)
        avg = tell.deg_out.sum(axis=1) / nreal
        if self._pull_threshold_req is not None:
            thr = np.full((pg.num_parts, 1), self._pull_threshold_req,
                          np.float32)
        else:
            thr = perf_model.fit_shard_pull_thresholds(
                avg, [tell.kmax] * pg.num_parts,
                backend=self.backend).reshape(-1, 1)
        self._fwd.update(
            t_col=jnp.asarray(tell.col),
            t_kreal=jnp.asarray(tell.kreal),
            t_deg=jnp.asarray(tell.deg_out),
            t_bnd=jnp.asarray(tell.deg_bnd),
            t_vmask=jnp.asarray(vmask),
            t_thr=jnp.asarray(thr.astype(np.float32)))
        if tell.val is not None:
            self._fwd["t_val"] = jnp.asarray(tell.val)

    def _step_fn(self, program: VertexProgram, edges: Optional[dict],
                 exchange: Callable, all_finished: Callable) -> Callable:
        if self._uses_hybrid(program):
            cfg, arrs = self._hybrid_for(program)
            return functools.partial(_superstep_hybrid, program, cfg, arrs,
                                     all_finished)
        return functools.partial(_superstep, self.dims_for(edges), program,
                                 edges, exchange, all_finished,
                                 self.fused_cfg_for(program),
                                 dopt_cfg=self._dopt_cfg_for(program))

    def _edges_or_none(self, program: VertexProgram) -> Optional[dict]:
        """Edge arrays for the program, or None when the hybrid backend
        serves it (hybrid builds its own reverse direction, so BC runs even
        without ``include_reverse`` partitioning)."""
        return None if self._uses_hybrid(program) else self.edges_for(program)

    def execute(self, program: VertexProgram, state: BatchedState, *,
                num_steps: Optional[int] = None,
                chunk: Optional[int] = None,
                on_chunk: Optional[Callable] = None,
                incremental=None,
                start_step: int = 0, fin=None, steps_q=None,
                max_chunks: Optional[int] = None,
                chaos_ctx: Optional[dict] = None,
                monitor=None):
        """THE engine entry point: one documented facade over every run
        mode.  ``state`` is a batched ``[Q, Pl, v_max]`` pytree
        (:func:`batch_state` lifts a single query).

        Dispatch, by keyword:

        - ``execute(program, state)`` — run-to-convergence: one resident
          ``lax.while_loop``, per-query finished votes, returns
          ``(state, steps_q [Q])``.
        - ``execute(program, state, num_steps=n)`` — fixed-iteration
          programs (PageRank): returns the final ``state``.
        - ``execute(program, state, chunk=k)`` — checkpointable /
          continuous mode: bounded ``k``-superstep windows whose
          boundaries surface the carry to ``on_chunk`` (snapshotting,
          quarantine kills, slot refills — see
          :meth:`run_batched_chunked` for the hook protocol and the
          ``start_step``/``fin``/``steps_q``/``max_chunks`` resume
          operands).  Returns ``(state, steps_q, info)``.
        - ``execute(program, prev_state, incremental=dirty)`` — warm
          start from a previous fixpoint over a ``[Pl, v_max]`` dirty
          mask; returns ``(state, steps_q)`` or ``None`` when the
          program has no :class:`IncrementalForm`.

        Eligible min-combine programs additionally run **direction
        optimized** (docs/traversal.md): execute() threads three [Q, P]
        int32 leaves through the carry (per-shard direction, deterministic
        edges-examined counter, switch counter), strips them from the
        returned state, and records per-query aggregates in
        ``engine.last_direction_stats``.  Chunked/continuous mode stays
        push-only — the slot-refill protocol swaps user state rows and
        must not see engine-internal leaves.

        This is the ONLY public run entry point (the historical
        ``run``/``run_batched``/``run_fixed*``/``run_incremental``/
        ``run_batched_chunked`` aliases are gone — see docs/serving.md for
        the migration table).  The jitted private methods behind each mode
        (``_run_batched``, ``_run_fixed_batched``) remain class attributes
        because their compile cache is the zero-retrace serving contract's
        retrace gate.  Incompatible keyword combinations raise with the
        fix spelled out.
        """
        modes = {"num_steps": num_steps is not None,
                 "chunk": chunk is not None,
                 "incremental": incremental is not None}
        picked = [k for k, v in modes.items() if v]
        if len(picked) > 1:
            raise ValueError(
                f"execute() got {' + '.join(picked)} — these select "
                f"mutually exclusive run modes; pass exactly one (or none "
                f"for run-to-convergence).  Fixed-step chunking is not a "
                f"mode: restate the program with a never-voting apply "
                f"(see _fixed_step_program) and pass chunk= alone.")
        if modes["chunk"] and self.tier_plan is not None:
            raise ValueError(
                "chunked/continuous mode is not supported on a tiered "
                "engine: chunk windows assume resident edge dicts; run "
                "tiered convergence (drop chunk=) or build the engine "
                "without tiered=")
        if not modes["chunk"]:
            chunked_only = [
                name for name, val in (("on_chunk", on_chunk),
                                       ("fin", fin), ("steps_q", steps_q),
                                       ("max_chunks", max_chunks),
                                       ("chaos_ctx", chaos_ctx),
                                       ("monitor", monitor))
                if val is not None] + (
                    ["start_step"] if start_step != 0 else [])
            if chunked_only:
                raise ValueError(
                    f"execute() got {', '.join(chunked_only)} without "
                    f"chunk= — boundary hooks and resume carries only "
                    f"exist in chunked mode; pass chunk=<supersteps per "
                    f"window> (e.g. chunk=2).")
        if modes["chunk"]:
            return self._run_batched_chunked(
                program, state, checkpoint_every=chunk,
                on_chunk=on_chunk, start_step=start_step, fin=fin,
                steps_q=steps_q, max_chunks=max_chunks,
                chaos_ctx=chaos_ctx, monitor=monitor)
        self.last_direction_stats = None
        if modes["incremental"]:
            inc = program.incremental
            if inc is None:
                return None
            # Seed here, then fall through to convergence dispatch so the
            # relaxation program runs direction-optimized too.
            state = inc.seed(state, jnp.asarray(incremental))
            program = inc.program
        use_dopt = isinstance(state, dict) and self._direction_enabled(
            program)
        if use_dopt:
            if not self._uses_hybrid(program):
                self._ensure_direction_edges()
            q = num_queries(state)
            parts = self._pg.num_parts
            state = dict(
                state,
                _dopt_dir=jnp.full((q, parts), -1, jnp.int32),
                _dopt_edges=jnp.zeros((q, parts), jnp.int32),
                _dopt_switch=jnp.zeros((q, parts), jnp.int32))
        if modes["num_steps"]:
            out = self._run_fixed_batched(program, num_steps, state)
            return self._dopt_finish(out) if use_dopt else out
        out_state, steps_run = self._run_batched(program, state)
        if use_dopt:
            out_state = self._dopt_finish(out_state)
        return out_state, steps_run

    def _dopt_finish(self, state: BatchedState) -> BatchedState:
        """Strip the direction carry and record per-query aggregates."""
        state = dict(state)
        d = np.asarray(state.pop(_DOPT_KEYS[0]))
        e = np.asarray(state.pop(_DOPT_KEYS[1]))
        s = np.asarray(state.pop(_DOPT_KEYS[2]))
        self.last_direction_stats = dict(
            direction=d,
            edges_examined=e.sum(axis=1).astype(np.int64),
            switches=s.sum(axis=1).astype(np.int64))
        return state

    @functools.partial(jax.jit, static_argnums=(0, 1))
    def _run_batched(self, program: VertexProgram,
                     state: BatchedState) -> Tuple[BatchedState, Array]:
        """Advance a [Q, Pl, ...] batch of queries through **one** compiled
        ``lax.while_loop`` until every query votes finish; returns the final
        batched state and per-query superstep counts [Q].  The compiled
        computation is cached on (program, state shape): batches of the same
        Q never retrace, whatever their sources.  Private: dispatch through
        ``execute(program, state)`` — this stays a jitted class attribute
        because its compile cache is the serving contract's retrace gate."""
        edges = self._edges_or_none(program)
        step_fn = self._step_fn(program, edges, self._exchange,
                                self._all_finished)
        return _run_batched_loop(step_fn, program.max_steps, state,
                                 num_queries(state))

    @functools.partial(jax.jit, static_argnums=(0, 1, 2))
    def _run_fixed_batched(self, program: VertexProgram, num_steps: int,
                           state: BatchedState) -> BatchedState:
        """Fixed-iteration algorithms (PageRank), batched over queries.
        Private: dispatch through ``execute(program, state,
        num_steps=n)``."""
        edges = self._edges_or_none(program)
        step_fn = self._step_fn(program, edges, self._exchange,
                                self._all_finished)

        def body(i, state):
            state, _ = step_fn(state, i)
            return state

        return jax.lax.fori_loop(0, num_steps, body, state)

    # ---------------------- checkpointable run mode ------------------------

    @functools.partial(jax.jit, static_argnums=(0, 1, 2))
    def _run_chunk(self, program: VertexProgram, chunk: int,
                   state: BatchedState, step: Array, fin: Array,
                   steps_q: Array, poison: Array):
        edges = self._edges_or_none(program)
        self._guard.arm(poison)
        # The checked exchange tags every (partition, peer) slot block; the
        # hybrid step ignores the exchange callable (no outbox on a single
        # device), so its windows report bad == 0 by construction.
        step_fn = self._step_fn(program, edges,
                                _checked_exchange(self._guard),
                                self._all_finished)
        return _run_chunked_loop_guarded(step_fn, self._guard, chunk,
                                         program.max_steps, state, step,
                                         fin, steps_q)

    def _chunk_call(self, program: VertexProgram, chunk: int,
                    state: BatchedState, step: Array, fin: Array,
                    steps_q: Array, poison=None):
        """Dispatch one chunk window; overridden by the distributed engine.
        Returns ``(state, step, fin, steps_q, bad)`` — ``bad`` counts
        exchange-checksum mismatches inside the window (0 on the unguarded
        dynamic paths, whose integrity net is the tombstone/certifier
        layer)."""
        if poison is None:
            poison = jnp.float32(0.0)
        if self.dg is not None:
            self._sync_dynamic()
            if self._uses_hybrid(program):
                cfg, arrs = self._hybrid_dyn_for(program)
                out = _run_dyn_hybrid_chunk_jit(
                    program, cfg, program.max_steps, chunk, arrs, state,
                    step, fin, steps_q)
                return out + (jnp.int32(0),)
            edges = self.edges_for(program)
            dyn = self.dg.payload(program.use_reverse)
            if chaos.visit("tombstone.flip", step=int(step)):
                # Value-level mask flip (a deleted edge resurrects): rides
                # the traced dyn operand, so the window never retraces.
                # Prefer a tombstoned non-self-loop slot — resurrecting a
                # self-loop is inert under every vertex program and would
                # make the corruption drill vacuous.
                tomb_h = np.asarray(dyn["tomb"])
                src_h = np.asarray(edges["src"])
                dst_h = np.asarray(edges["dst_ext"])
                cand = np.flatnonzero(tomb_h[0] & (src_h[0] != dst_h[0]))
                j = int(cand[0]) if cand.size else 0
                dyn = dict(dyn)
                dyn["tomb"] = dyn["tomb"].at[0, j].set(
                    jnp.logical_not(dyn["tomb"][0, j]))
            out = _run_dyn_chunk_jit(
                self.dims_for(edges), program, self.fused_cfg_for(program),
                program.max_steps, chunk, edges, dyn, state, step, fin,
                steps_q)
            return out + (jnp.int32(0),)
        return self._run_chunk(program, chunk, state, step, fin, steps_q,
                               poison)

    def _run_batched_chunked(self, program: VertexProgram,
                             state: BatchedState, *, checkpoint_every: int,
                             on_chunk: Optional[Callable] = None,
                             start_step: int = 0, fin=None, steps_q=None,
                             max_chunks: Optional[int] = None,
                             chaos_ctx: Optional[dict] = None,
                             monitor=None):
        """``_run_batched`` in bounded ``checkpoint_every``-superstep chunks.

        Chains :func:`_run_chunked_loop` windows, so the full superstep
        sequence — and every query's result and step count — is **bitwise
        identical** to the single resident while_loop; between windows the
        carry escapes to host.  ``on_chunk(snap)`` receives ``{"state",
        "step", "fin", "steps_q"}`` per chunk and may snapshot it
        (``CheckpointManager.save_tree``) and/or steer the carry:

        - return a ``[Q]`` bool mask → force-finish those queries
          (quarantine: masked queries freeze bitwise exactly like
          converged ones);
        - return a dict → the continuous-batching boundary protocol:
          ``{"kill": mask}`` as above, ``{"refill": (new_rows, admit)}``
          swaps admitted slots' state in via :func:`_slot_swap` (clearing
          their votes and zeroing their step counters — a refilled slot
          joins the resident loop as a fresh query), ``{"stop": True}``
          ends the run at this boundary.  Kills apply before refills, so a
          hook may quarantine a slot and hand it to a new tenant at the
          same boundary.

        The all-finished exit re-checks *after* the hook: a refill that
        clears votes keeps the loop resident, so one ``run_batched_chunked``
        call (and one compiled chunk trace) serves an unbounded query
        stream.  Resume a snapshot by passing its
        ``start_step``/``fin``/``steps_q``.  Returns ``(state, steps_q,
        info)`` with ``info = {"chunks", "final_step", "finished",
        "refilled", "monitors_fired"}``.

        Integrity (docs/robustness.md "Silent faults"): every static-path
        window runs the checksummed exchange — a tag mismatch raises
        :class:`repro.runtime.failures.ExchangeCorruption` *before* the
        corrupted carry replaces the live one, so the caller replays the
        window from its last checkpoint.  ``monitor`` (an object exposing
        ``observe(snap)`` / ``rebase(admit)``, e.g.
        :class:`repro.runtime.verify.InvariantMonitor`) is called once per
        window with the boundary snapshot; its record rides to ``on_chunk``
        under ``snap["monitor"]`` and fired windows are counted in
        ``info["monitors_fired"]``.  The ``state.corrupt`` /
        ``exchange.payload`` chaos sites inject here (host seam / traced
        poison operand — neither perturbs the jit cache).

        Private: dispatch through ``execute(program, state, chunk=k, ...)``.
        """
        if self.tier_plan is not None:
            raise ValueError(
                "chunked/continuous mode is not supported on a tiered "
                "engine: chunk windows assume resident edge dicts; run "
                "tiered convergence instead or build without tiered=")
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")
        q = num_queries(state)
        # restored snapshots arrive as numpy leaves; canonicalize so the
        # resume hits the same jit cache entry as the original run
        state = jax.tree.map(jnp.asarray, state)
        fin = (jnp.zeros((q,), jnp.bool_) if fin is None
               else jnp.asarray(fin, jnp.bool_).reshape(q))
        steps_q = (jnp.zeros((q,), jnp.int32) if steps_q is None
                   else jnp.asarray(steps_q, jnp.int32).reshape(q))
        step = jnp.int32(start_step)
        chunks = 0
        refilled = 0
        monitors_fired = 0
        stop = False
        while True:
            chaos.visit("superstep.chunk", step=int(step), chunk=chunks,
                        **(chaos_ctx or {}))
            if chaos.visit("state.corrupt", step=int(step),
                           **(chaos_ctx or {})):
                state = _flip_state_bit(state)
            poison = jnp.float32(
                1.0 if chaos.visit("exchange.payload", step=int(step),
                                   **(chaos_ctx or {})) else 0.0)
            new_state, new_step, new_fin, new_steps_q, bad = self._chunk_call(
                program, int(checkpoint_every), state, step, fin, steps_q,
                poison)
            n_bad = int(bad)
            if n_bad:
                # The corrupted window never replaces the live carry; the
                # caller's RestartPolicy replays it from the last checkpoint
                # (ExchangeCorruption subclasses WorkerFailure → retryable).
                from repro.runtime.failures import ExchangeCorruption
                raise ExchangeCorruption(
                    f"exchange checksum mismatch in window at superstep "
                    f"{int(step)} ({n_bad} tag(s)): a payload block was "
                    f"corrupted in flight; replay the window from the last "
                    f"checkpoint")
            state, step, fin, steps_q = (new_state, new_step, new_fin,
                                         new_steps_q)
            chunks += 1
            snap = dict(state=state, step=int(step), fin=np.asarray(fin),
                        steps_q=np.asarray(steps_q))
            if monitor is not None:
                rec = monitor.observe(dict(state=state, step=snap["step"],
                                           finished=snap["fin"],
                                           steps_q=snap["steps_q"]))
                monitors_fired += int(rec["violations"] > 0)
                snap["monitor"] = rec
            if on_chunk is not None:
                out = on_chunk(snap)
                if isinstance(out, dict):
                    kill = out.get("kill")
                    if kill is not None:
                        fin = jnp.logical_or(
                            fin, jnp.asarray(kill, jnp.bool_).reshape(q))
                    refill = out.get("refill")
                    if refill is not None:
                        new_rows, admit = refill
                        new_rows = jax.tree.map(jnp.asarray, new_rows)
                        admit = jnp.asarray(admit, jnp.bool_).reshape(q)
                        state, fin, steps_q = _slot_swap(
                            state, new_rows, admit, fin, steps_q)
                        refilled += int(np.asarray(admit).sum())
                        if monitor is not None:
                            monitor.rebase(np.asarray(admit))
                    stop = bool(out.get("stop"))
                elif out is not None:        # legacy bare kill mask
                    fin = jnp.logical_or(
                        fin, jnp.asarray(out, jnp.bool_).reshape(q))
            if stop or bool(jnp.all(fin)) or int(step) >= program.max_steps:
                break
            if max_chunks is not None and chunks >= max_chunks:
                break
        info = dict(chunks=chunks, final_step=int(step),
                    finished=np.asarray(fin), refilled=refilled,
                    monitors_fired=monitors_fired)
        return state, steps_q, info

    # ---------------------- dynamic-graph plumbing -------------------------

    def _sync_dynamic(self) -> None:
        """Rebind after a compaction (the one retrace-paying event); called
        on entry to every dynamic run and by the ``pg`` property."""
        if self.dg.version != self._dyn_version:
            # version first: _bind reads self.pg, whose property getter
            # re-enters this sync — the updated version makes it a no-op.
            self._dyn_version = self.dg.version
            self._bind(self.dg.pg)
            self.dynamic_rebinds += 1

    def _run_batched_dyn(self, program: VertexProgram,
                         state: BatchedState) -> Tuple[BatchedState, Array]:
        """Dynamic-graph ``_run_batched``: same contract, but every graph
        array rides as a traced argument so mutation batches never retrace
        (see ``_run_dyn_jit``)."""
        return self._dispatch_dyn(program, state, fixed_steps=None)

    def _run_fixed_batched_dyn(self, program: VertexProgram, num_steps: int,
                               state: BatchedState) -> BatchedState:
        return self._dispatch_dyn(program, state, fixed_steps=num_steps)

    def _dispatch_dyn(self, program: VertexProgram, state: BatchedState,
                      fixed_steps: Optional[int]):
        self._sync_dynamic()
        if self._uses_hybrid(program):
            cfg, arrs = self._hybrid_dyn_for(program)
            return _run_dyn_hybrid_jit(program, cfg, program.max_steps,
                                       fixed_steps, arrs, state)
        edges = self.edges_for(program)
        dyn = self.dg.payload(program.use_reverse)
        return _run_dyn_jit(self.dims_for(edges), program,
                            self.fused_cfg_for(program), program.max_steps,
                            fixed_steps, edges, dyn, state)

    # ---------------------- tiered (out-of-core) run path ------------------

    def _run_batched_tiered(self, program: VertexProgram,
                            state: BatchedState
                            ) -> Tuple[BatchedState, Array]:
        return self._tiered_run(program, state)

    def _run_fixed_batched_tiered(self, program: VertexProgram,
                                  num_steps: int,
                                  state: BatchedState) -> BatchedState:
        state, _ = self._tiered_run(_fixed_step_program(program, num_steps),
                                    state)
        return state

    def _tiered_run(self, program: VertexProgram, state: BatchedState
                    ) -> Tuple[BatchedState, Array]:
        """Host-driven tiered superstep loop (replaces the resident
        ``lax.while_loop``): hot compute → double-buffered window stream →
        exchange/scatter/apply, per superstep, until every query votes.

        The three jits restate exactly one resident superstep plus the
        batched loop's freeze/vote body, so the fixpoint is **bitwise**
        the resident one.  All shapes (window length, block count, delta
        tail) are static and the streamed partition id is traced — the
        steady state never retraces (``tiered_cache_entries`` is flat
        after the first superstep)."""
        if self.dg is not None:
            self._sync_dynamic()
        if self._uses_hybrid(program):
            return self._tiered_run_hybrid(program, state)
        use_rev = bool(program.use_reverse)
        if use_rev not in self._tier_dims:
            raise ValueError("program needs reverse edges; partition with "
                             "include_reverse=True")
        dims = self._tier_dims[use_rev]
        cfg = None
        if self.fused and program.edge_msg is not None:
            cfg = self._rev_cfg if use_rev else self._fwd_cfg
        hot_idx = self._tier_hot_idx
        edges_hot = self._tier_dev[use_rev]
        arena = self._tier_arena[use_rev]
        inbox_dst = self._tier_inbox[use_rev]

        dyn_hot = None
        stream = [(p, w, cfg) for p, w in arena]
        if self.dg is not None:
            dyn = self.dg.payload(use_rev)
            inbox_dst = dyn["inbox_dst"]
            hot_np = np.asarray(self.tier_plan.hot, np.int64)
            if edges_hot is not None:
                dyn_hot = dict(tomb=dyn["tomb"][hot_np],
                               d_src=dyn["d_src"][hot_np],
                               d_dst_ext=dyn["d_dst_ext"][hot_np])
                if "d_weight" in dyn:
                    dyn_hot["d_weight"] = dyn["d_weight"][hot_np]
            # Cold mutations stream with their partitions: tombstone slices
            # ride inside each base window; the inserted-edge delta slots
            # become one reference-flavor window per cold partition at the
            # end of the stream (per-segment order is base ⊕ delta — the
            # same order the resident dynamic superstep combines in).
            tomb_h = np.asarray(dyn["tomb"])
            sched = self.tier_plan.rev if use_rev else self.tier_plan.fwd
            stream = []
            for (p, w), st, cnt in zip(arena, sched.start, sched.count):
                st, cnt = int(st), int(cnt)
                t = np.zeros(w["src"].shape[0], bool)
                t[:cnt] = tomb_h[p, st:st + cnt]
                stream.append((p, dict(w, tomb=t), cfg))
            d_src = np.asarray(dyn["d_src"])
            d_dst = np.asarray(dyn["d_dst_ext"])
            d_w = np.asarray(dyn["d_weight"]) if "d_weight" in dyn else None
            for p in np.asarray(self.tier_plan.cold, np.int64):
                p = int(p)
                dwin = dict(src=d_src[p], dst_ext=d_dst[p])
                if d_w is not None:
                    dwin["weight"] = d_w[p]
                stream.append((p, dwin, None))

        q = num_queries(state)
        fin = jnp.zeros((q,), jnp.bool_)
        steps_q = jnp.zeros((q,), jnp.int32)
        step = 0
        while True:
            acc = _tiered_hot_jit(dims, program, cfg, hot_idx, edges_hot,
                                  dyn_hot, state, jnp.int32(step))
            # double buffer: block w+1's host→device put is in flight while
            # the compute consumes block w
            nxt = jax.device_put(stream[0][1]) if stream else None
            for i, (p, _, wcfg) in enumerate(stream):
                cur = nxt
                nxt = (jax.device_put(stream[i + 1][1])
                       if i + 1 < len(stream) else None)
                acc = _tiered_window_jit(dims, program, wcfg, p, acc, cur,
                                         state, jnp.int32(step))
            state, fin, steps_q = _tiered_apply_jit(
                dims, program, acc, inbox_dst, state, jnp.int32(step), fin,
                steps_q)
            step += 1
            if step >= program.max_steps or bool(jnp.all(fin)):
                break
        return state, steps_q

    def _hybrid_tiered_for(self, program: VertexProgram):
        """Row-tiered ELL split for one program: hot-partition rows stay a
        resident compacted ELL; cold rows are chunked into fixed-shape host
        windows (sentinel-padded).  Pull-only — min over the same value
        multiset is exact in either direction, so parity with the resident
        (possibly push-switching) hybrid holds bitwise."""
        from repro.kernels.ell_spmv import SEMIRINGS

        key = self._hybrid_key(program)
        if key in self._hyb_tier_cache:
            return self._hyb_tier_cache[key]
        cfg, arrs, _ = self._build_hybrid(program, self.pg.source,
                                          with_push=False)
        n = cfg.num_vertices
        mul_ident = SEMIRINGS[cfg.semiring][3]
        ell_col = np.asarray(arrs["ell_col"])
        ell_val = np.asarray(arrs["ell_val"])
        kmax = ell_col.shape[1]
        part_of_row = np.asarray(arrs["slot"]).astype(np.int64) \
            // self.pg.v_max
        cold = np.asarray(self.tier_plan.cold, np.int64)
        is_cold = np.isin(part_of_row, cold)
        rows = np.arange(n, dtype=np.int64)
        hot_rows, cold_rows = rows[~is_cold], rows[is_cold]
        if len(hot_rows):
            hot_dev = (jnp.asarray(ell_col[hot_rows]),
                       jnp.asarray(ell_val[hot_rows]),
                       jnp.asarray(hot_rows.astype(np.int32)))
        else:
            hot_dev = (None, None, jnp.zeros((0,), jnp.int32))
        wins = []
        if len(cold_rows):
            win_rows = max(8, min(len(cold_rows), self._block_e))
            for s in range(0, len(cold_rows), win_rows):
                sel = cold_rows[s:s + win_rows]
                m = len(sel)
                col = np.full((win_rows, kmax), n, ell_col.dtype)
                val = np.full((win_rows, kmax), mul_ident, ell_val.dtype)
                r = np.full((win_rows,), n, np.int32)  # pad rows → sink
                col[:m], val[:m], r[:m] = ell_col[sel], ell_val[sel], sel
                wins.append(dict(col=col, val=val, rows=r))
        acc_fn = _make_tiered_hyb_acc(cfg, np.asarray(arrs["dense"]),
                                      np.asarray(arrs["hid"]))
        ent = (cfg, jnp.asarray(arrs["slot"]), hot_dev, acc_fn, wins)
        self._hyb_tier_cache[key] = ent
        return ent

    def _tiered_run_hybrid(self, program: VertexProgram, state: BatchedState
                           ) -> Tuple[BatchedState, Array]:
        cfg, slot, hot_dev, acc_fn, wins = self._hybrid_tiered_for(
            program)
        col_hot, val_hot, rows_hot = hot_dev
        q = num_queries(state)
        fin = jnp.zeros((q,), jnp.bool_)
        steps_q = jnp.zeros((q,), jnp.int32)
        step = 0
        while True:
            xs, y = _tiered_hyb_hot_jit(program, cfg, slot, col_hot,
                                        val_hot, rows_hot, state,
                                        jnp.int32(step))
            nxt = jax.device_put(wins[0]) if wins else None
            for i in range(len(wins)):
                cur = nxt
                nxt = (jax.device_put(wins[i + 1])
                       if i + 1 < len(wins) else None)
                y = _tiered_hyb_win_jit(cfg, y, cur["col"], cur["val"],
                                        cur["rows"], xs)
            acc = acc_fn(y, xs)
            state, fin, steps_q = _tiered_hyb_apply_jit(
                program, acc, state, jnp.int32(step), fin, steps_q)
            step += 1
            if step >= program.max_steps or bool(jnp.all(fin)):
                break
        return state, steps_q

    def tiered_cache_entries(self) -> int:
        """Compile-cache entries across the tiered jits (module-level plus
        this engine's per-binding hybrid acc closures; the zero-retrace
        gates diff this between supersteps/runs)."""
        extra = sum(_cache_entries_of(ent[3])
                    for ent in getattr(self, "_hyb_tier_cache", {}).values())
        return tiered_cache_entries() + extra

    def residency_bytes(self, state_bytes: int = 4) -> dict:
        """``{"hbm_bytes", "host_bytes", "total_bytes"}`` for the bound
        layout under this engine's tier plan (all-resident without one);
        serving admission must charge only ``hbm_bytes`` against device
        capacity."""
        from repro.core.partition import memory_residency_bytes

        return memory_residency_bytes(self._pg, tier_plan=self.tier_plan,
                                      state_bytes=state_bytes,
                                      dynamic=self.dg)

    def tiered_stats(self) -> Optional[dict]:
        """Deterministic out-of-core counters for the bench/report column,
        or None on an all-resident engine."""
        if self.tier_plan is None:
            return None
        plan = self.tier_plan
        return dict(hbm_resident_bytes=int(plan.hbm_bytes),
                    host_bytes=int(plan.host_bytes),
                    streamed_bytes_per_superstep=int(
                        plan.streamed_bytes_per_superstep),
                    window_count=int(plan.window_count),
                    num_hot=len(plan.hot), num_cold=len(plan.cold))

    def should_resplit_hybrid(self, threshold: float = 0.10) -> bool:
        """The ``perf_model.should_resplit`` rule, applied to this engine's
        frozen dynamic-hybrid split: re-evaluate the candidate ladder on
        the *mutated* graph's degree ranks and vote to re-rank only when
        the predicted makespan improves by more than ``threshold``.  The
        serving driver calls this per round and consumes a True vote as a
        compaction (rebinding re-runs ``_plan_hybrid`` on the mutated
        graph).  False on non-hybrid/static engines; the distributed
        hybrid re-plans at its forced compactions anyway.
        """
        if self.dg is None or self.backend != HYBRID:
            return False
        from repro.core import perf_model
        from repro.core.hybrid import edge_max_ranks

        g = self.dg.mutated_csr()
        resplit, info = perf_model.should_resplit(
            edge_max_ranks(g), g.num_edges, self._hybrid_plan["candidates"],
            current_k=self._hybrid_plan["k_dense"], threshold=threshold)
        self.last_resplit_info = info
        return resplit

    def _hybrid_dyn_for(self, program: VertexProgram
                        ) -> Tuple[_HybridCfg, dict]:
        """The dynamic hybrid split: device arrays kept in sync with the
        mutation log.

        Deletions write ⊕-identity (or the post-delete combine of surviving
        parallel edges) into the dense block / clear ELL entries; insertions
        land in the dense block or in the **spare ELL columns** reserved at
        build time.  The degree *ranking* stays frozen between compactions
        (a stale split is a performance choice, never a correctness one —
        ``perf_model.should_resplit`` decides when re-ranking pays).  A row
        running out of spare columns triggers a full rebuild of this
        split from the mutated CSR.
        """
        key = self._hybrid_key(program)
        ent = self._hybrid_dyn_cache.get(key)
        if ent is not None and ent["cursor"] < self.dg.log_floor:
            # the bounded batch log no longer reaches back to this entry's
            # cursor: rebuild from the mutated CSR
            ent = None
            self.hybrid_dyn_rebuilds += 1
        if ent is None:
            ent = self._build_hybrid_dyn(program)
            self._hybrid_dyn_cache[key] = ent
        pending = [rec for rec in self.dg._batch_log
                   if rec["index"] > ent["cursor"]]
        if pending:
            pairs = set()
            for rec in pending:
                b = rec["batch"]
                pairs.update(zip(b.src.tolist(), b.dst.tolist()))
            try:
                self._reconcile_hybrid(ent, key, pairs)
            except _EllOverflow:
                ent = self._build_hybrid_dyn(program)
                self._hybrid_dyn_cache[key] = ent
                self.hybrid_dyn_rebuilds += 1
            ent["cursor"] = self.dg.num_batches
        return ent["cfg"], ent["arrs"]

    def _build_hybrid_dyn(self, program: VertexProgram) -> dict:
        from repro.kernels.ell_spmv import SEMIRINGS

        cfg, arrs, hg = self._build_hybrid(program, self.dg.mutated_csr(),
                                           with_push=True)
        n = cfg.num_vertices
        mul_ident = SEMIRINGS[cfg.semiring][3]
        spare = self._dyn_ell_spare
        ell_col = np.pad(hg.ell_col, ((0, 0), (0, spare)),
                         constant_values=n)
        ell_val = np.pad(hg.ell_val, ((0, 0), (0, spare)),
                         constant_values=mul_ident)
        arrs = dict(arrs, ell_col=ell_col, ell_val=ell_val)
        push_extra = dict(push_src=None, push_dst=None, push_w=None)
        if "push_src" in arrs:
            # Push arenas ride mutations too: spare sentinel slots
            # (src = dst = n, inert under the extended-segment reduce) take
            # inserts, deletes tombstone slots back to the sentinel, and the
            # capacity is pow2-rounded so a post-growth rebuild usually
            # lands on shapes the jit cache has already seen.
            e = int(arrs["push_src"].shape[0])
            need = e + max(4 * self.dg.mutation_capacity, 64)
            cap = 1 << (need - 1).bit_length()
            push_src = np.pad(arrs["push_src"], (0, cap - e),
                              constant_values=n)
            push_dst = np.pad(arrs["push_dst"], (0, cap - e),
                              constant_values=n)
            arrs = dict(arrs, push_src=push_src, push_dst=push_dst)
            if "push_w" in arrs:
                arrs["push_w"] = np.pad(arrs["push_w"], (0, cap - e),
                                        constant_values=0.0)
            # Reconcile fills spare ELL columns out of slot order, so the
            # bottom-up scan's per-row bound must cover the full (spared)
            # width — early exit still cuts the live-parent common case.
            arrs["ell_kreal"] = np.full(n, ell_col.shape[1], np.int32)
            pair_slots: dict = {}
            for j in range(e):
                pair_slots.setdefault(
                    (int(push_src[j]), int(push_dst[j])), []).append(j)
            push_extra = dict(
                push_src=push_src.copy(), push_dst=push_dst.copy(),
                push_w=(arrs["push_w"].copy() if "push_w" in arrs
                        else None),
                pair_slots=pair_slots, push_free=list(range(e, cap)))
        ent = dict(
            cfg=cfg,
            arrs={k: jnp.asarray(v) for k, v in arrs.items()},
            # host mirrors for entry location + free-slot scans
            dense=np.asarray(arrs["dense"]).copy(),
            ell_col=ell_col.copy(), ell_val=ell_val.copy(),
            inv_perm=hg.inv_perm, mul_ident=float(mul_ident),
            cursor=self.dg.num_batches)
        ent.update(push_extra)
        return ent

    def _reconcile_hybrid(self, ent: dict, key, pairs) -> None:
        """Reconcile the split's ⊗ values for every touched (u, v) pair
        against the ledger's current live multiset, then apply every write
        — dense block, ELL pull layout, *and* the push arenas — through the
        **one** compiled padded scatter the mutation path already uses
        (``dynamic._scatter_payload``): both traversal layouts stay in sync
        out of a single device dispatch, and the compiled superstep only
        ever sees the arrays as operands."""
        from repro.core.dynamic import _scatter_payload
        from repro.core.hybrid import add_identity

        semiring, use_reverse, use_weight = key[:3]
        cfg = ent["cfg"]
        inv, k = ent["inv_perm"], cfg.k_dense
        ident = add_identity(semiring)
        n = cfg.num_vertices
        writes = {m: {} for m in ("dense", "ell_col", "ell_val",
                                  "push_src", "push_dst", "push_w")}
        for (u, v) in pairs:
            a, b = (v, u) if use_reverse else (u, v)
            ha, hb = int(inv[a]), int(inv[b])
            weights = self.dg.ledger.alive_weights(u, v)
            if semiring == "plus_times":
                vals = [float(w) if use_weight else 1.0 for w in weights]
            elif semiring == "min_plus":
                vals = [float(w) if use_weight else 0.0 for w in weights]
            else:
                vals = [0.0] * len(weights)
            if k and ha < k and hb < k:
                if not vals:
                    cell = ident
                elif semiring == "plus_times":
                    acc = np.float32(0.0)   # f32 accumulation, like add.at
                    for x in vals:
                        acc = np.float32(acc + np.float32(x))
                    cell = float(acc)
                else:
                    cell = min(vals)
                writes["dense"][ha * k + hb] = cell
            else:
                self._reconcile_ell_row(ent, hb, ha, vals, n,
                                        writes["ell_col"],
                                        writes["ell_val"])
            if ent.get("push_src") is not None:
                self._reconcile_push(ent, ha, hb, vals, n, writes)
        for flat, val in writes["dense"].items():
            ent["dense"].reshape(-1)[flat] = val
        for mkey in ("ell_col", "ell_val"):
            mirror = ent[mkey].reshape(-1)
            for flat, val in writes[mkey].items():
                mirror[flat] = val
        # One compiled scatter over a fixed key set with pow2-padded write
        # widths: batches of any composition reuse the same trace.
        live = {m: w for m, w in writes.items() if m in ent["arrs"]}
        payload = {m: ent["arrs"][m] for m in live}
        upd = {}
        for m, w in live.items():
            arr = payload[m]
            width = 1 << (max(len(w), 1) - 1).bit_length()
            idx = np.full(width, arr.size, dtype=np.int64)  # drop sentinel
            val = np.zeros(width, dtype=arr.dtype)
            if w:
                idx[:len(w)] = np.fromiter(w.keys(), dtype=np.int64,
                                           count=len(w))
                val[:len(w)] = np.asarray(list(w.values()), dtype=arr.dtype)
            upd[m] = (jnp.asarray(idx), jnp.asarray(val))
        out = _scatter_payload(payload, upd)
        for m in live:
            ent["arrs"][m] = out[m]

    def _reconcile_push(self, ent: dict, ha: int, hb: int, vals,
                        sentinel: int, writes: dict) -> None:
        """Match the push arena's (ha → hb) slots to the live multiset:
        tombstone extras back to the sentinel, claim spare slots for new
        edges.  Weightless arenas match by count; min_plus by ⊗ value.
        Raises :class:`_EllOverflow` when the spare pool runs dry (the
        caller rebuilds from the mutated CSR)."""
        slots = ent["pair_slots"].setdefault((ha, hb), [])
        w = ent["push_w"]
        if w is None:
            keep, extras = slots[:len(vals)], slots[len(vals):]
            remaining = vals[len(slots):]
        else:
            remaining, keep, extras = list(vals), [], []
            for j in slots:
                x = float(w[j])
                if x in remaining:
                    remaining.remove(x)
                    keep.append(j)
                else:
                    extras.append(j)
        for j in extras:
            writes["push_src"][j] = sentinel
            writes["push_dst"][j] = sentinel
            ent["push_src"][j] = sentinel
            ent["push_dst"][j] = sentinel
            if w is not None:
                writes["push_w"][j] = 0.0
                w[j] = 0.0
            ent["push_free"].append(j)
        if remaining:
            free = ent["push_free"]
            if len(free) < len(remaining):
                raise _EllOverflow((ha, hb))
            for x in remaining:
                j = free.pop()
                writes["push_src"][j] = ha
                writes["push_dst"][j] = hb
                ent["push_src"][j] = ha
                ent["push_dst"][j] = hb
                if w is not None:
                    writes["push_w"][j] = float(x)
                    w[j] = float(x)
                keep.append(j)
        ent["pair_slots"][(ha, hb)] = keep

    def _reconcile_ell_row(self, ent: dict, row: int, col: int, want,
                           sentinel: int, col_w: dict, val_w: dict) -> None:
        """Match row ``row``'s entries with column ``col`` to the live
        multiset ``want`` (add into sentinel slots, clear extras)."""
        col_row = ent["ell_col"][row]
        val_row = ent["ell_val"][row]
        kmax = col_row.shape[0]
        have = [int(j) for j in np.flatnonzero(col_row == col)]
        remaining = list(want)
        keep = []
        for j in have:
            v = float(val_row[j])
            if v in remaining:
                remaining.remove(v)
                keep.append(j)
        extras = [j for j in have if j not in keep]
        for j in extras:
            flat = row * kmax + j
            col_w[flat] = sentinel
            val_w[flat] = ent["mul_ident"]
            col_row[j] = sentinel          # keep the free-slot scan honest
            val_row[j] = ent["mul_ident"]
        if remaining:
            free = [int(j) for j in np.flatnonzero(col_row == sentinel)]
            if len(free) < len(remaining):
                raise _EllOverflow(row)
            for j, v in zip(free, remaining):
                flat = row * kmax + j
                col_w[flat] = col
                val_w[flat] = v
                col_row[j] = col
                val_row[j] = v


class _EllOverflow(RuntimeError):
    """A dynamic hybrid ELL row ran out of spare columns (full rebuild)."""


class DistributedBSPEngine(BSPEngine):
    """Partitions sharded over a mesh axis with shard_map.

    One (or more) partition(s) per device; the exchange phase becomes an
    ``all_to_all`` over the mesh axis — the ICI analogue of the paper's PCI-E
    outbox/inbox copy.  The termination vote is a global AND (psum).

    ``backend="hybrid"`` runs the paper's actual headline configuration:
    every shard executes its own degree-split two-engine step over its
    intra-partition edges while boundary messages are aggregated into outbox
    slots at the source and exchanged through a *compact* ``all_to_all``
    that ships only the used (shard, peer) slot blocks.  The per-shard
    split sizes come from the comm-inclusive performance model
    (``perf_model.plan_shards``, Eq. 1–2); ``hybrid_plan()`` reports them.
    Unlike the single-device hybrid, ``use_reverse`` programs (BC) need
    ``include_reverse=True`` partitioning — the reverse boundary edges
    route through the reverse outbox maps.
    """

    def __init__(self, pg, mesh: Mesh, axis: str = "parts", **kwargs):
        from repro.core.dynamic import DynamicGraph

        if kwargs.get("tiered") is not None:
            raise ValueError(
                "tiered= is single-device only: the distributed engine's "
                "shard_map superstep has no host-streaming seam yet; drop "
                "tiered= or use BSPEngine")
        inner = pg.pg if isinstance(pg, DynamicGraph) else pg
        if inner.num_parts % mesh.shape[axis]:
            raise ValueError("num_parts must divide mesh axis size")
        self.mesh = mesh
        self.axis = axis
        super().__init__(pg, **kwargs)

    def _bind(self, pg: PartitionedGraph) -> None:
        self._hybrid_dist_cache: dict = {}
        super()._bind(pg)

    def _sync_dynamic(self) -> None:
        # The distributed hybrid's compact-exchange maps (send_idx/recv_ids)
        # are static used-slot sets: in-place deltas cannot extend them, so
        # pending mutations are consumed through compaction instead (the
        # in-place spare-slot exchange is future work — docs/dynamic.md).
        if self.backend == HYBRID and self.dg.batches_in_version:
            self.dg.compact()
        super()._sync_dynamic()

    def _run_batched_dyn(self, program: VertexProgram,
                         state: BatchedState) -> Tuple[BatchedState, Array]:
        self._sync_dynamic()
        # The sharded path is already stale-constant-safe: edge arrays and
        # the mutation payload travel as shard_map operands rebuilt from the
        # engine's current binding on every call (see _dist_step_parts).
        return DistributedBSPEngine._run_batched(self, program, state)

    def should_resplit_hybrid(self, threshold: float = 0.10) -> bool:
        # the distributed hybrid consumes mutations via forced compactions,
        # each of which already re-runs plan_shards on the mutated graph
        return False

    def _run_fixed_batched_dyn(self, program: VertexProgram, num_steps: int,
                               state: BatchedState) -> BatchedState:
        # Fixed-step programs must ride the *sharded* path too (the base
        # dynamic runner's local exchange/vote would silently unshard the
        # run): a never-finished program variant turns the distributed
        # while_loop into an exact num_steps round count.
        state, _ = self._run_batched_dyn(
            _fixed_step_program(program, num_steps), state)
        return state

    # ------------------- distributed hybrid plumbing -----------------------

    def provides_reverse(self, program: VertexProgram) -> bool:
        # The distributed hybrid routes reverse boundary edges through the
        # reverse outbox maps, so pg.rev is required even for the hybrid.
        return False

    def _plan_hybrid(self, k_dense: Optional[int], block_e: int) -> dict:
        """Per-shard split decision: each shard's |H| is the argmin of its
        own comm-inclusive predicted makespan (Eq. 1 with the §3.4 reduced
        boundary term); the system prediction is the max over shards
        (Eq. 2)."""
        from repro.core import perf_model
        from repro.core.hybrid import _shard_intra, shard_plan_inputs

        num_shards = self.mesh.shape[self.axis]
        # Forward-direction shard layouts are shared with the split builder
        # (_hybrid_dist_for) — the O(|E| + V log V) ranking runs once.
        self._shard_layouts = _shard_intra(self.pg, num_shards,
                                           self.pg.source)
        ranks, edges, slots, nverts = shard_plan_inputs(
            self.pg, num_shards, layouts=self._shard_layouts)
        blk = self._fwd_blk or build_block_metadata(self.pg.fwd,
                                                    block_e=block_e)
        skew = blk.degree_skew()
        candidates = [perf_model.k_dense_candidates(n, skewed=skew > 0.0)
                      for n in nverts]
        plan = perf_model.plan_shards(ranks, edges, slots, candidates,
                                      k_dense=k_dense)
        for rec, n in zip(plan["per_shard"], nverts):
            rec["mode"] = perf_model.split_mode(rec["k_dense"], n,
                                                rec["e_sparse"])
        plan.update(skew=skew, num_shards=num_shards, candidates=candidates)
        return plan

    def _hybrid_dist_for(self, program: VertexProgram):
        """Build (and cache) one direction's per-shard split: the static
        :class:`hybrid.ShardHybridData` plus its device arrays, sharded over
        the mesh axis."""
        from repro.core.hybrid import shard_degree_split

        semiring = self._hybrid_semiring(program)
        # use_weight in the key for the same reason as _hybrid_for: one
        # semiring can serve weighted and weightless programs, whose splits
        # pack different ⊗ values.
        key = (semiring, program.use_reverse, program.edge_msg.use_weight)
        if key in self._hybrid_dist_cache:
            return self._hybrid_dist_cache[key]

        shd = shard_degree_split(
            self.pg, self.mesh.shape[self.axis], semiring,
            [rec["k_dense"] for rec in self._hybrid_plan["per_shard"]],
            use_reverse=program.use_reverse,
            use_weights=program.edge_msg.use_weight,
            direction_switch=(program.combine == MIN
                              and self._direction_switch),
            layouts=self._shard_layouts)
        arrs = dict(n_vert=shd.n_vert, dense=shd.dense, ell_col=shd.ell_col,
                    ell_val=shd.ell_val, slot=shd.slot, hid=shd.hid,
                    b_src=shd.b_src, b_local=shd.b_local, b_base=shd.b_base,
                    b_mask=shd.b_mask, send_idx=shd.send_idx,
                    recv_ids=shd.recv_ids, loc_idx=shd.loc_idx,
                    loc_ids=shd.loc_ids)
        if shd.b_weight is not None:
            arrs["b_weight"] = shd.b_weight
        if shd.push_src is not None:
            arrs["push_src"] = shd.push_src
            arrs["push_dst"] = shd.push_dst
            if shd.push_w is not None:
                arrs["push_w"] = shd.push_w
            # direction-optimization operands, per shard: real ELL slot
            # counts (bottom-up scan bound), the perf-model-fitted
            # push/pull crossover, and the static dense-stage work charge
            arrs["ell_kreal"] = (shd.ell_col
                                 != shd.n_max).sum(axis=2).astype(np.int32)
            ks = [int(rec["k_dense"])
                  for rec in self._hybrid_plan["per_shard"]]
            arrs["e_dense"] = np.asarray(
                [[k * k] for k in ks], dtype=np.int32)
            if self._pull_threshold_req is not None:
                thr = np.full((shd.num_shards, 1, 1),
                              self._pull_threshold_req, np.float32)
            else:
                from repro.core import perf_model
                nv = np.maximum(np.asarray(shd.n_vert,
                                           np.float64).reshape(-1), 1.0)
                intra = (np.asarray(arrs["ell_kreal"], np.int64).sum(axis=1)
                         + np.asarray(ks, np.int64) ** 2)
                thr = perf_model.fit_shard_pull_thresholds(
                    intra / nv, [shd.ell_col.shape[2]] * shd.num_shards,
                    backend="hybrid").reshape(-1, 1, 1)
            arrs["pull_thr"] = thr.astype(np.float32)
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        arrs = {k: jax.device_put(jnp.asarray(v), sharding)
                for k, v in arrs.items()}
        self._hybrid_dist_cache[key] = (shd, arrs)
        return shd, arrs

    def _hybrid_step_fn(self, program: VertexProgram, shd, arrs,
                        guard=None) -> Callable:
        return functools.partial(_superstep_hybrid_dist, program, shd, arrs,
                                 self.axis, self.interpret,
                                 self._pull_threshold, self._dist_finished,
                                 guard=guard,
                                 n_shards=self.mesh.shape[self.axis],
                                 forced=self._dopt_forced,
                                 uniform=program.edge_msg.frontier_uniform)

    # ----------------------------- exchange --------------------------------

    def _dist_exchange(self, outbox: Array) -> Array:
        # outbox: [Q, pl, P, o_max] -> split peer axis across devices, concat
        # the received blocks on a device axis, then restore layout (a 3-D
        # input is treated as a single query).
        if outbox.ndim == 3:
            return self._dist_exchange(outbox[None])[0]
        chaos.visit("exchange", axis=self.axis)
        q, pl, peers, o = outbox.shape
        n_dev = self.mesh.shape[self.axis]
        if peers != n_dev * pl:
            raise ValueError(
                f"outbox shape {tuple(outbox.shape)} is inconsistent with "
                f"the mesh: peer axis ({peers}) must equal mesh axis size "
                f"({n_dev}) × local partitions ({pl}).  Every device must "
                f"host the same number of partitions — repartition so "
                f"num_parts == {n_dev} × pl")
        # regroup peer axis as (device, local_partition)
        ob = outbox.reshape(q, pl, n_dev, pl, o)
        recv = jax.lax.all_to_all(ob, self.axis, split_axis=2, concat_axis=0,
                                  tiled=False)
        # recv: [n_dev, Q, pl_src, pl_dst, o] — reorder to
        # inbox[Q, pl_local, P_global, o]
        recv = recv.transpose(1, 3, 0, 2, 4)  # [Q, pl_dst, n_dev, pl_src, o]
        return recv.reshape(q, pl, n_dev * pl, o)

    def _checked_dist_exchange(self, guard) -> Callable[[Array], Array]:
        """:meth:`_dist_exchange` with per-(shard, peer-partition) reduction
        tags: send-side tags ship over their own ``all_to_all`` and the
        inbox side re-derives them — a wire flip lands in the guard and the
        host replays the window (see ``_checked_exchange``)."""
        n_dev = self.mesh.shape[self.axis]
        axis = self.axis

        def exchange(outbox: Array) -> Array:
            if outbox.ndim == 3:
                return exchange(outbox[None])[0]
            chaos.visit("exchange", axis=axis)
            q, pl, peers, o = outbox.shape
            if peers != n_dev * pl:
                raise ValueError(
                    f"outbox shape {tuple(outbox.shape)} is inconsistent "
                    f"with the mesh: peer axis ({peers}) must equal mesh "
                    f"axis size ({n_dev}) × local partitions ({pl})")
            ob = outbox.reshape(q, pl, n_dev, pl, o)
            send_tags = _payload_tag(ob, (0, 4))  # [pl_src, n_dev, pl_dst]
            ob = jnp.where(guard.poison > 0, _flip_wire(ob), ob)
            recv = jax.lax.all_to_all(ob, axis, split_axis=2,
                                      concat_axis=0, tiled=False)
            want = jax.lax.all_to_all(send_tags, axis, split_axis=1,
                                      concat_axis=0, tiled=False)
            got = _payload_tag(recv, (1, 4))   # [n_dev_src, pl_src, pl_dst]
            guard.add(jnp.sum((got != want).astype(jnp.int32)))
            recv = recv.transpose(1, 3, 0, 2, 4)
            return recv.reshape(q, pl, n_dev * pl, o)

        return exchange

    def _dist_finished(self, fin: Array) -> Array:
        # fin: [Q] per-shard votes -> [Q] global AND over the mesh axis.
        not_done = jnp.logical_not(fin).astype(jnp.int32)
        return jax.lax.psum(not_done, self.axis) == 0

    def _validate_state(self, state: BatchedState) -> None:
        """Fail fast on mis-sharded inputs: every [Q, num_parts, ...] leaf
        must split evenly over the mesh axis (the exchange silently
        mis-routes otherwise)."""
        leaves = jax.tree_util.tree_leaves_with_path(state)
        for path, leaf in leaves:
            shape = getattr(leaf, "shape", ())
            if len(shape) >= 2 and shape[1] != self.pg.num_parts:
                raise ValueError(
                    f"state leaf {jax.tree_util.keystr(path)} has partition "
                    f"axis {shape[1]}, expected num_parts="
                    f"{self.pg.num_parts}: every device must host the same "
                    f"number of partitions")

    # ------------------------------- run -----------------------------------

    def _dist_step_parts(self, program: VertexProgram, guard=None):
        """Shared run()/superstep() dispatch: the sharded extra operands
        (hybrid shard arrays — already device_put — or edge arrays, plus the
        dynamic mutation payload when the graph mutates) and a factory
        building the per-shard step function from them.  With ``guard``,
        every exchange runs checksummed (chunked windows pass the engine
        guard; the unguarded ``run``/``superstep`` paths pass None)."""
        if self._uses_hybrid(program):
            shd, arrs = self._hybrid_dist_for(program)
            return arrs, (lambda extra:
                          self._hybrid_step_fn(program, shd, extra,
                                               guard=guard)), True
        exchange = (self._dist_exchange if guard is None
                    else self._checked_dist_exchange(guard))
        edges = self.edges_for(program)
        dims = self.dims_for(edges)

        if self.dg is not None:
            # tomb/delta/inbox arrays share the edges' partition axis, so
            # they shard under the same spec and slice per device.
            extra = {"edges": edges,
                     "dyn": self.dg.payload(program.use_reverse)}

            def make_dyn(ex):
                return functools.partial(_superstep, dims, program,
                                         ex["edges"], exchange,
                                         self._dist_finished,
                                         self.fused_cfg_for(program),
                                         dyn=ex["dyn"])

            return extra, make_dyn, False

        def make(extra):
            return functools.partial(_superstep, dims, program, extra,
                                     exchange,
                                     self._dist_finished,
                                     self.fused_cfg_for(program),
                                     dopt_cfg=self._dopt_cfg_for(program))

        return edges, make, False

    def _run_batched(self, program: VertexProgram,
                     state: BatchedState) -> Tuple[BatchedState, Array]:
        """Advance a [Q, P, ...] batch of queries through one sharded
        ``lax.while_loop``; the termination vote is a per-query global AND
        (psum over the mesh axis).  Returns (batched state, steps [Q]).
        Private: dispatch through ``execute(program, state)``."""
        self._validate_state(state)
        q = num_queries(state)
        # State shards on the *partition* axis (axis 1); the query axis is
        # replicated-free: every device holds all Q rows of its partitions.
        spec = P(None, self.axis)
        extra_spec = P(self.axis)
        sharding = jax.sharding.NamedSharding(self.mesh, spec)
        extra, make_step, hybrid = self._dist_step_parts(program)

        def local_fn(state, extra):
            return _run_batched_loop(make_step(extra), program.max_steps,
                                     state, q)

        sharded = shard_map(
            local_fn, mesh=self.mesh,
            in_specs=(jax.tree.map(lambda _: spec, state),
                      jax.tree.map(lambda _: extra_spec, extra)),
            out_specs=(jax.tree.map(lambda _: spec, state), P()),
            check_vma=False)
        state = jax.device_put(state, sharding)
        if not hybrid:
            ex_shard = jax.sharding.NamedSharding(self.mesh, extra_spec)
            extra = jax.tree.map(lambda x: jax.device_put(x, ex_shard),
                                 extra)
        return jax.jit(sharded)(state, extra)

    def _chunk_call(self, program: VertexProgram, chunk: int,
                    state: BatchedState, step: Array, fin: Array,
                    steps_q: Array, poison=None):
        """Sharded chunk window for ``run_batched_chunked``.

        The scalar step / replicated fin / steps_q / poison carry rides
        through ``P()`` specs; the jitted shard_map closure is cached per
        (program, chunk, shapes) — cleared on rebind — so chunks and
        restart-rebuilt engines reuse one compile.  Every exchange inside
        the window is checksummed (``_checked_dist_exchange`` / the tagged
        hybrid compact exchange); the psum'd mismatch count returns as the
        5th element.
        """
        if poison is None:
            poison = jnp.float32(0.0)
        if self.dg is not None:
            self._sync_dynamic()
        self._validate_state(state)
        chaos.visit(
            "worker.chunk", step=int(step),
            shards=tuple(range(self.mesh.shape[self.axis])))
        spec = P(None, self.axis)
        extra_spec = P(self.axis)
        sharding = jax.sharding.NamedSharding(self.mesh, spec)
        guard = self._guard
        extra, make_step, hybrid = self._dist_step_parts(program,
                                                         guard=guard)

        def sig(tree):
            return tuple(
                (jax.tree_util.keystr(p), tuple(x.shape))
                for p, x in jax.tree_util.tree_leaves_with_path(tree))

        key = (program, chunk, sig(state), sig(extra))
        jitted = self._chunk_jits.get(key)
        if jitted is None:
            mesh_axis = self.axis

            def local_fn(state, extra, step, fin, steps_q, poison):
                guard.arm(poison)
                st, stp, fn, sq, bad = _run_chunked_loop_guarded(
                    make_step(extra), guard, chunk, program.max_steps,
                    state, step, fin, steps_q)
                # Each shard only sees mismatches on payload it received;
                # psum so the replicated out-spec holds the global count.
                return st, stp, fn, sq, jax.lax.psum(bad, mesh_axis)

            sharded = shard_map(
                local_fn, mesh=self.mesh,
                in_specs=(jax.tree.map(lambda _: spec, state),
                          jax.tree.map(lambda _: extra_spec, extra),
                          P(), P(), P(), P()),
                out_specs=(jax.tree.map(lambda _: spec, state),
                           P(), P(), P(), P()),
                check_vma=False)
            jitted = jax.jit(sharded)
            self._chunk_jits[key] = jitted
        state = jax.device_put(state, sharding)
        if not hybrid:
            ex_shard = jax.sharding.NamedSharding(self.mesh, extra_spec)
            extra = jax.tree.map(lambda x: jax.device_put(x, ex_shard),
                                 extra)
        return jitted(state, extra, jnp.int32(step), fin, steps_q,
                      jnp.float32(poison))

    def superstep(self, program: VertexProgram) -> Callable:
        """One jitted distributed superstep ``f(state, step) -> (state,
        finished)`` — the benchmarking hook (state is device_put on entry;
        unbatched contract, runs as a Q=1 batch internally)."""
        if self.dg is not None:
            self._sync_dynamic()
        spec = P(None, self.axis)
        extra_spec = P(self.axis)
        sharding = jax.sharding.NamedSharding(self.mesh, spec)
        extra, make_step, hybrid = self._dist_step_parts(program)
        if not hybrid:
            ex_shard = jax.sharding.NamedSharding(self.mesh, extra_spec)
            extra = jax.tree.map(lambda x: jax.device_put(x, ex_shard),
                                 extra)

        def local_fn(state, extra, step):
            return make_step(extra)(state, step)

        jitted = {}

        def fn(state, step):
            state = batch_state(state)
            self._validate_state(state)
            key = jax.tree_util.tree_structure(state)
            if key not in jitted:
                sharded = shard_map(
                    local_fn, mesh=self.mesh,
                    in_specs=(jax.tree.map(lambda _: spec, state),
                              jax.tree.map(lambda _: extra_spec, extra),
                              P()),
                    out_specs=(jax.tree.map(lambda _: spec, state), P()),
                    check_vma=False)
                jitted[key] = jax.jit(sharded)
            state = jax.device_put(state, sharding)
            out, fin = jitted[key](state, extra, step)
            return unbatch_state(out), fin[0]

        return fn
