"""Per-query slot re-initialization for continuous batching.

A serving session refills a converged query's slot *mid-run*: the new
tenant joins the resident ``lax.while_loop`` at some global superstep
``step0 > 0``, but the vertex program only ever sees the loop's shared
step counter.  The trick is to express each algorithm's fresh state **in
the global step frame** instead of threading a per-slot counter through
every kernel:

- BFS is level-synchronous (its frontier test is ``level == step``), so a
  slot admitted at ``step0`` seeds its source at ``level = step0`` — the
  source fires at exactly the right global step, and every level the
  traversal writes is the true level **+ step0**.  Levels are small exact
  f32 integers, so the harvest's subtraction is bitwise-exact: a refilled
  slot's harvested result equals the same query's drain-batch
  ``run_batched`` result bit for bit (tests/test_continuous.py pins this
  per backend and per device count).
- SSSP's Bellman-Ford relaxation never reads the step, so its slot state
  is the ordinary ``{dist, active}`` seed and the harvest is the identity.

Programs whose step dependence is not a pure translation (BC's backward
walk arithmetic, fixed-iteration PageRank) have no continuous form — the
serving layer must drain-batch them, and :func:`continuous_form` says so.

Construction reuses :func:`multi_source_state` (whose ``value=`` takes a
per-query vector) and :func:`gather_batch` — no new scatter machinery, and
nothing here traces: slot states are host numpy handed to the engine's
jitted static-shape swap (``core.bsp._slot_swap``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import numpy as np

from repro.algorithms.bfs import (BFS_PROGRAM, gather_batch,
                                  multi_source_state)
from repro.algorithms.sssp import SSSP_PROGRAM
from repro.core.bsp import VertexProgram


@dataclasses.dataclass(frozen=True)
class ContinuousForm:
    """An algorithm's step-translated slot protocol.

    ``make_slot_state(pg, sources, step0)`` builds a full-Q batched state
    pytree (host numpy ``[Q, P, v_max]`` leaves) whose row ``i`` is query
    ``sources[i]``'s fresh state translated to global superstep
    ``step0[i]``; ``harvest(pg, state, step0)`` collects a batched state
    into ``[Q, n]`` global results translated *back* to the step-0 frame.
    Non-admitted rows of either are junk by contract — the caller masks.
    """
    program: VertexProgram
    make_slot_state: Callable
    harvest: Callable


def _bfs_slot_state(pg, sources: Sequence[int],
                    step0: np.ndarray) -> dict:
    value = np.asarray(step0, np.float32).reshape(-1)
    return {"level": multi_source_state(pg, sources, value=value)}


def _bfs_harvest(pg, state, step0: np.ndarray) -> np.ndarray:
    levels = gather_batch(pg, state["level"])
    # inf - step0 == inf: unreached vertices survive the frame shift.  NaN
    # rows (quarantined slots frozen mid-poison) pass through unchanged —
    # silence the invalid-op warning, the values are the point.
    with np.errstate(invalid="ignore"):
        return (levels - np.asarray(step0, np.float32)[:, None]).astype(
            np.float32)


def _sssp_slot_state(pg, sources: Sequence[int],
                     step0: np.ndarray) -> dict:
    del step0                      # relaxation is step-invariant
    dist = multi_source_state(pg, sources)
    return {"dist": dist, "active": np.isfinite(dist)}


def _sssp_harvest(pg, state, step0: np.ndarray) -> np.ndarray:
    del step0
    return gather_batch(pg, state["dist"])


CONTINUOUS_FORMS: Dict[str, ContinuousForm] = {
    "bfs": ContinuousForm(BFS_PROGRAM, _bfs_slot_state, _bfs_harvest),
    "sssp": ContinuousForm(SSSP_PROGRAM, _sssp_slot_state, _sssp_harvest),
}


def continuous_form(alg: str) -> ContinuousForm:
    """The continuous-batching form of ``alg``, or an actionable error."""
    form = CONTINUOUS_FORMS.get(alg)
    if form is None:
        raise ValueError(
            f"{alg!r} has no continuous form: slot refill needs a "
            f"step-translatable program (supported: "
            f"{sorted(CONTINUOUS_FORMS)}).  Serve {alg!r} through the "
            f"drain-batch driver (engine.execute / run_batched) instead.")
    return form


def result_key(alg: str) -> Tuple[str, ...]:
    """State leaves a continuous result is read from (docs/debugging)."""
    return ("level",) if alg == "bfs" else ("dist",)
