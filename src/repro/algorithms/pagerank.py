"""PageRank (paper Fig. 14) as a TOTEM vertex program.

The paper uses a *pull* kernel (each vertex sums its in-neighbours' ranks);
algebraically identical is the *push* form used here — each vertex pushes
``rank / out_degree`` along its out-edges and the engine sum-reduces — which
shares the outbox machinery with the other algorithms and is how the paper's
own boundary-edge communication works for PR (the rank sum is reducible,
§3.4).  Damping and termination follow the paper: a fixed number of rounds.

Distribution note: per-vertex constants (inverse out-degree, vertex mask)
ride in the state pytree so they shard with the partitions — closures over
global arrays would silently replicate under shard_map.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsp import (SUM, BSPEngine, EdgeMessage, VertexProgram,
                            batch_state, gather_src, unbatch_state)
from repro.kernels import ops as kops

DAMPING = 0.85


def _edge_fn(state, src, weight, step):
    del weight, step
    return gather_src(state["rank"] * state["inv_deg"], src)


def _edge_msg_fn(vals, weight, step, consts):
    del weight, step, consts
    return vals["rank"] * vals["inv_deg"]


@functools.lru_cache(maxsize=None)
def make_pagerank_program(num_vertices: int, damping: float = DAMPING,
                          max_steps: int = 1 << 30) -> VertexProgram:
    delta = (1.0 - damping) / num_vertices

    def apply_fn(state, acc, step):
        # The barrier pins mul-then-add rounding: XLA is otherwise free to
        # contract ``delta + damping * acc`` into an FMA, and it decides
        # per fusion context — the resident while_loop body and the
        # out-of-core streamed superstep would then disagree by 1 ulp.
        rank = delta + kops.pin(damping * acc)
        rank = jnp.where(state["mask"], rank, 0.0)
        return dict(state, rank=rank), jnp.bool_(True)

    # Weightless sum combine → the hybrid backend runs PR under plus_times:
    # the dense block's multi-edge counts ride in the adjacency values.  The
    # distributed hybrid sum-reduces boundary contributions into outbox
    # slots at the source — the paper's §3.4 "rank sum is reducible" case.
    return VertexProgram(combine=SUM, edge_fn=_edge_fn, apply_fn=apply_fn,
                         max_steps=max_steps,
                         edge_msg=EdgeMessage(gather=("rank", "inv_deg"),
                                              fn=_edge_msg_fn))


def initial_state(pg, damping: float = DAMPING) -> dict:
    out_deg = pg.out_deg
    inv = np.where(out_deg > 0, 1.0 / np.maximum(out_deg, 1.0), 0.0)
    rank0 = np.where(pg.vertex_mask, 1.0 / pg.num_vertices, 0.0)
    return {"rank": jnp.asarray(rank0, jnp.float32),
            "inv_deg": jnp.asarray(inv, jnp.float32),
            "mask": jnp.asarray(pg.vertex_mask)}


def pagerank(engine: BSPEngine, num_iterations: int = 20,
             damping: float = DAMPING) -> np.ndarray:
    pg = engine.pg
    program = make_pagerank_program(pg.num_vertices, damping)
    state = unbatch_state(engine.execute(program,
                                         batch_state(initial_state(pg)),
                                         num_steps=num_iterations))
    return pg.gather_global(np.asarray(state["rank"]))


def make_personalized_pagerank_program(damping: float = DAMPING,
                                       max_steps: int = 1 << 30
                                       ) -> VertexProgram:
    """PPR: the uniform teleport ``(1-d)/n`` becomes a per-query restart
    distribution carried in ``state["reset"]`` — the query axis is what
    makes one engine run serve Q personalizations at once."""
    def apply_fn(state, acc, step):
        rank = (1.0 - damping) * state["reset"] + damping * acc
        rank = jnp.where(state["mask"], rank, 0.0)
        return dict(state, rank=rank), jnp.bool_(True)

    return VertexProgram(combine=SUM, edge_fn=_edge_fn, apply_fn=apply_fn,
                         max_steps=max_steps,
                         edge_msg=EdgeMessage(gather=("rank", "inv_deg"),
                                              fn=_edge_msg_fn))


@functools.lru_cache(maxsize=None)
def _ppr_program(damping: float, num_iterations: int) -> VertexProgram:
    """Memoized so repeated serving batches reuse one compiled loop (the
    engine's jit cache keys on program identity)."""
    program = make_personalized_pagerank_program(damping,
                                                 max_steps=num_iterations)
    return dataclasses.replace(program,
                               apply_fn=_never_finished(program.apply_fn))


def personalized_pagerank(engine: BSPEngine, reset,
                          num_iterations: int = 20,
                          damping: float = DAMPING) -> np.ndarray:
    """Batched personalized PageRank: one run, Q restart distributions.

    ``reset`` is either [Q, n] per-query restart distributions (each row a
    probability vector over global vertex ids) or a length-Q sequence of
    seed vertex ids (one-hot teleport).  Iteration count is fixed (paper
    Fig. 14 termination); ranks start *at* the reset distribution.  Works on
    both the single-device and the distributed engine (the fixed round
    count rides ``max_steps`` with a never-finished vote, the same device
    as ``pagerank_distributed``).  Returns ranks [Q, n].
    """
    from repro.algorithms.bfs import gather_batch

    pg = engine.pg
    reset = np.asarray(reset)
    if reset.ndim == 1:                      # seed vertex ids → one-hot
        seeds = reset.astype(np.int64)
        reset = np.zeros((len(seeds), pg.num_vertices), dtype=np.float32)
        reset[np.arange(len(seeds)), seeds] = 1.0
    q = reset.shape[0]
    base = initial_state(pg, damping)
    reset_p = np.stack([pg.scatter_global(row.astype(np.float32), 0.0)
                        for row in reset])
    state = {
        "rank": jnp.asarray(reset_p),
        "reset": jnp.asarray(reset_p),
        # query-independent constants, broadcast along the query axis
        "inv_deg": jnp.broadcast_to(base["inv_deg"],
                                    (q,) + base["inv_deg"].shape),
        "mask": jnp.broadcast_to(base["mask"], (q,) + base["mask"].shape),
    }
    out, _ = engine.execute(_ppr_program(damping, num_iterations), state)
    return gather_batch(pg, out["rank"])


def personalized_pagerank_reference(g, reset, num_iterations: int = 20,
                                    damping: float = DAMPING) -> np.ndarray:
    """Pure-numpy batched PPR oracle (same push semantics as the engine)."""
    n = g.num_vertices
    reset = np.asarray(reset, dtype=np.float64)
    q = reset.shape[0]
    deg = g.out_degrees().astype(np.float64)
    src = g.edge_sources()
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    rank = reset.copy()
    rows = np.arange(q)[:, None]
    for _ in range(num_iterations):
        contrib = (rank * inv)[:, src]
        acc = np.zeros((q, n))
        np.add.at(acc, (rows, g.col[None, :]), contrib)
        rank = (1.0 - damping) * reset + damping * acc
    return rank.astype(np.float32)


def pagerank_distributed(engine, num_iterations: int = 20,
                         damping: float = DAMPING) -> np.ndarray:
    """PageRank on a DistributedBSPEngine (fixed-round via max_steps)."""
    pg = engine.pg
    program = make_pagerank_program(pg.num_vertices, damping,
                                    max_steps=num_iterations)
    # run() terminates early only if a program votes finish with False
    # improvement; PR always votes True, so force the round count:
    program = dataclasses.replace(
        program,
        apply_fn=_never_finished(program.apply_fn))
    state_b, _ = engine.execute(program, batch_state(initial_state(pg)))
    return pg.gather_global(np.asarray(unbatch_state(state_b)["rank"]))


def _never_finished(apply_fn):
    def wrapped(state, acc, step):
        new_state, _ = apply_fn(state, acc, step)
        return new_state, jnp.bool_(False)
    return wrapped


def pagerank_reference(g, num_iterations: int = 20,
                       damping: float = DAMPING) -> np.ndarray:
    """Pure-numpy push PageRank oracle (same semantics, incl. dangling)."""
    n = g.num_vertices
    deg = g.out_degrees().astype(np.float64)
    src = g.edge_sources()
    rank = np.full(n, 1.0 / n)
    delta = (1.0 - damping) / n
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1.0), 0.0)
    for _ in range(num_iterations):
        contrib = rank * inv
        acc = np.zeros(n)
        np.add.at(acc, g.col, contrib[src])
        rank = delta + damping * acc
    return rank.astype(np.float32)
