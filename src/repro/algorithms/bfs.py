"""Level-synchronous BFS (paper Fig. 11) as a TOTEM vertex program.

Push formulation with min-reduction: every vertex at the current level sends
``level + 1`` along its out-edges; the reduction keeps the minimum, and
unvisited vertices adopt it.  Identical to the paper's kernel where the
"visited" test is the ``level == INF`` check (the cache-resident bitmap is a
CPU-specific optimization; the TPU analogue is the VMEM-resident frontier of
the dense block — see kernels/dense_spmv).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.bsp import (MIN, BSPEngine, EdgeMessage, IncrementalForm,
                            VertexProgram, gather_src)
from repro.core.graph import CSRGraph
from repro.core.partition import PartitionedGraph

INF = jnp.float32(jnp.inf)


def multi_source_state(pg: PartitionedGraph, sources: Sequence[int],
                       fill=np.inf, value=0.0) -> np.ndarray:
    """[Q, P, v_max] per-query state with ``value`` at each query's source.

    The shared multi-source constructor: one row per query, ``fill``
    elsewhere — BFS levels, SSSP distances, and BC's dist/sigma all start
    from this shape.
    """
    sources = np.asarray(sources, dtype=np.int64).reshape(-1)
    out = np.full((len(sources), pg.num_parts, pg.v_max), fill,
                  dtype=np.float32)
    out[np.arange(len(sources)), pg.assignment.part_of[sources],
        pg.assignment.local_id[sources]] = value
    return out


def gather_batch(pg: PartitionedGraph, per_part: np.ndarray) -> np.ndarray:
    """Collect a [Q, P, v_max] batched state into global [Q, n] order."""
    return np.stack([pg.gather_global(row) for row in np.asarray(per_part)])


def _edge_fn(state, src, weight, step):
    del weight
    level = gather_src(state["level"], src)
    # Only frontier vertices (level == step) send; others send identity.
    return jnp.where(level == step.astype(jnp.float32), level + 1.0, INF)


def _edge_msg_fn(vals, weight, step, consts):
    del weight, consts
    level = vals["level"]
    # np.inf (not the jnp INF const): Pallas kernels may not capture arrays.
    return jnp.where(level == step, level + 1.0, np.inf)


def _apply_fn(state, acc, step):
    del step
    level = state["level"]
    newly = jnp.isinf(level) & jnp.isfinite(acc)
    new_level = jnp.where(newly, acc, level)
    finished = ~jnp.any(newly)
    return {"level": new_level}, finished


# --- incremental (warm-start) form -----------------------------------------
# The level-synchronous program cannot lower a *finite* level (its frontier
# test is ``level == step`` and its apply only fills unvisited vertices), so
# warm starts run BFS's relaxation restatement instead: unit-weight
# Bellman-Ford over levels with an active set.  Its fixpoint is reachable by
# descent from any over-approximation — exactly the previous solution after
# insert-only mutations — and since levels are small exact-f32 integers the
# warm fixpoint is *bitwise* equal to a cold rerun (docs/dynamic.md).

def _inc_edge_fn(state, src, weight, step):
    del weight, step
    level = gather_src(state["level"], src)
    active = gather_src(state["active"].astype(jnp.float32), src) > 0
    return jnp.where(active, level + 1.0, INF)


def _inc_edge_msg_fn(vals, weight, step, consts):
    del weight, step, consts
    # np.inf (not the jnp INF const): Pallas kernels may not capture arrays.
    return jnp.where(vals["active"] > 0, vals["level"] + 1.0, np.inf)


def _inc_apply_fn(state, acc, step):
    del step
    level = state["level"]
    improved = acc < level
    new_level = jnp.where(improved, acc, level)
    return {"level": new_level, "active": improved}, ~jnp.any(improved)


BFS_RELAX_PROGRAM = VertexProgram(
    combine=MIN, edge_fn=_inc_edge_fn, apply_fn=_inc_apply_fn,
    edge_msg=EdgeMessage(gather=("level", "active"), fn=_inc_edge_msg_fn))


def _inc_seed(prev_state, dirty):
    """Warm state: previous levels + dirty-frontier active set.  ``dirty``
    is a [Pl, v_max] mask of vertices whose out-edges changed; only dirty
    vertices that are themselves reached can improve a neighbour."""
    level = prev_state["level"]
    active = jnp.logical_and(jnp.broadcast_to(dirty, level.shape),
                             jnp.isfinite(level))
    return {"level": level, "active": active}


# Weightless min combine → the hybrid backend runs BFS under the pure-min
# semiring (the message already carries level+1), with the frontier-density
# push/pull direction switch as the traversal showcase: sparse frontiers take
# the push segment-min, dense frontiers the frontier-oblivious SpMV pull.
# Under the distributed hybrid, boundary levels min-reduce into outbox slots
# at the source, so frontier-sparse supersteps ship aggregated slots (not
# per-edge messages) over the mesh axis.
BFS_PROGRAM = VertexProgram(combine=MIN, edge_fn=_edge_fn,
                            apply_fn=_apply_fn,
                            # Every frontier vertex sends the SAME value
                            # (step+1) — the bottom-up kernel's early exit
                            # is exact (kernels/bottomup.py).
                            edge_msg=EdgeMessage(gather=("level",),
                                                 fn=_edge_msg_fn,
                                                 frontier_uniform=True),
                            incremental=IncrementalForm(BFS_RELAX_PROGRAM,
                                                        _inc_seed))


def bfs_batched(engine: BSPEngine,
                sources: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
    """Run a batch of Q BFS queries through one engine invocation.

    All queries share the resident partitioned graph and advance through a
    single compiled ``lax.while_loop``; each converges independently.
    Returns (levels [Q, n], per-query supersteps [Q]).
    """
    pg = engine.pg
    level0 = multi_source_state(pg, sources)
    state, steps = engine.execute(BFS_PROGRAM,
                                  {"level": jnp.asarray(level0)})
    return gather_batch(pg, state["level"]), np.asarray(steps)


def bfs(engine: BSPEngine, source: int) -> Tuple[np.ndarray, int]:
    """Run BFS from global vertex ``source``; returns (levels [n], steps)."""
    levels, steps = bfs_batched(engine, [source])
    return levels[0], int(steps[0])


def bfs_incremental(engine: BSPEngine, prev_levels: np.ndarray,
                    dirty_global: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Warm-start a batch of BFS solutions after insert-only mutations.

    ``prev_levels`` is the [Q, n] (or [n]) result of an earlier run whose
    sources are being kept fresh; ``dirty_global`` the [n] mask of vertices
    with inserted out-edges since (``DynamicGraph.dirty_since`` — the caller
    must fall back to cold :func:`bfs_batched` when that window was not
    monotone).  Returns (levels [Q, n], supersteps [Q]) — bitwise equal to
    a cold rerun, typically in a fraction of the supersteps.
    """
    pg = engine.pg
    prev = np.atleast_2d(np.asarray(prev_levels, dtype=np.float32))
    state = {"level": jnp.asarray(np.stack(
        [pg.scatter_global(row, np.inf) for row in prev]))}
    st, steps = engine.execute(BFS_PROGRAM, state,
                               incremental=pg.scatter_dirty(dirty_global))
    return gather_batch(pg, st["level"]), np.asarray(steps)


def bfs_reference(g: CSRGraph, source: int) -> np.ndarray:
    """Pure-numpy frontier BFS oracle."""
    n = g.num_vertices
    level = np.full(n, np.inf, dtype=np.float32)
    level[source] = 0.0
    frontier = np.array([source], dtype=np.int64)
    d = 0
    while len(frontier):
        nbrs = np.concatenate([
            g.col[g.row_ptr[v]: g.row_ptr[v + 1]] for v in frontier
        ]) if len(frontier) else np.empty(0, dtype=np.int64)
        nbrs = np.unique(nbrs)
        newly = nbrs[np.isinf(level[nbrs])]
        level[newly] = d + 1
        frontier = newly
        d += 1
    return level


def teps(g: CSRGraph, levels: np.ndarray, seconds: float) -> float:
    """Graph500-style TEPS: sum of degrees of visited vertices / time."""
    visited = np.isfinite(levels)
    traversed = int(g.out_degrees()[visited].sum())
    return traversed / max(seconds, 1e-12)
