"""Connected components via label propagation (paper §9.4, Table 4).

Min-label propagation over *undirected* edges: the paper notes CC operates on
undirected graphs (Table 5 doubles the edge count).  Callers should partition
the symmetrized graph; ``symmetrize`` below is provided for that.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

import dataclasses

from repro.core.bsp import (MIN, BSPEngine, EdgeMessage, IncrementalForm,
                            VertexProgram, batch_state, gather_src)
from repro.core.graph import CSRGraph, from_edge_list

INF = jnp.float32(jnp.inf)


def symmetrize(g: CSRGraph) -> CSRGraph:
    src = g.edge_sources()
    return from_edge_list(np.concatenate([src, g.col]),
                          np.concatenate([g.col, src]),
                          g.num_vertices, dedup=True)


def _edge_fn(state, src, weight, step):
    del weight, step
    label = gather_src(state["label"], src)
    active = gather_src(state["active"].astype(jnp.float32), src) > 0
    return jnp.where(active, label, INF)


def _apply_fn(state, acc, step):
    del step
    label = state["label"]
    improved = acc < label
    new_label = jnp.where(improved, acc, label)
    return ({"label": new_label, "active": improved},
            ~jnp.any(improved))


def _edge_msg_fn(vals, weight, step, consts):
    del weight, step, consts
    # np.inf (not the jnp INF const): Pallas kernels may not capture arrays.
    return jnp.where(vals["active"] > 0, vals["label"], np.inf)


# Weightless min combine → the hybrid backend runs label propagation under
# the pure-min semiring (no per-edge add at all on the ELL path); the
# distributed hybrid min-reduces boundary labels into outbox slots at the
# source before the exchange (§3.4 aggregation is exact for min).
CC_PROGRAM = VertexProgram(combine=MIN, edge_fn=_edge_fn,
                           apply_fn=_apply_fn,
                           edge_msg=EdgeMessage(gather=("label", "active"),
                                                fn=_edge_msg_fn))


def _inc_seed(prev_state, dirty):
    """Warm state after insert-only mutations: label propagation is already
    an active-set min-relaxation, so re-seed it from the previous fixpoint
    with the dirty frontier active.  Mutations must keep the graph
    symmetric (insert both (u, v) and (v, u)) — CC's contract."""
    label = prev_state["label"]
    active = jnp.logical_and(jnp.broadcast_to(dirty, label.shape),
                             jnp.isfinite(label))
    return {"label": label, "active": active}


CC_PROGRAM = dataclasses.replace(
    CC_PROGRAM, incremental=IncrementalForm(CC_PROGRAM, _inc_seed))


def connected_components(engine: BSPEngine) -> Tuple[np.ndarray, int]:
    """Returns (labels [n] — min global vertex id per component, steps)."""
    pg = engine.pg
    # Initial label = global vertex id, so components get their min-id label.
    gids = np.arange(pg.num_vertices, dtype=np.float32)
    label0 = pg.scatter_global(gids, np.inf)
    active0 = pg.vertex_mask.copy()
    state, steps = engine.execute(CC_PROGRAM, batch_state({
        "label": jnp.asarray(label0, dtype=jnp.float32),
        "active": jnp.asarray(active0)}))
    return pg.gather_global(np.asarray(state["label"][0])), int(steps[0])


def cc_incremental(engine: BSPEngine, prev_labels: np.ndarray,
                   dirty_global: np.ndarray) -> Tuple[np.ndarray, int]:
    """Warm-start component labels after insert-only *symmetric* mutations
    (see :func:`repro.algorithms.bfs.bfs_incremental` for the contract)."""
    pg = engine.pg
    prev = np.asarray(prev_labels, dtype=np.float32)
    state = {"label": jnp.asarray(pg.scatter_global(prev, np.inf))[None]}
    st, steps = engine.execute(CC_PROGRAM, state,
                               incremental=pg.scatter_dirty(dirty_global))
    return pg.gather_global(np.asarray(st["label"][0])), int(steps[0])


def cc_reference(g: CSRGraph) -> np.ndarray:
    """Pure-numpy min-label propagation oracle (assumes symmetric graph)."""
    n = g.num_vertices
    src = g.edge_sources()
    label = np.arange(n, dtype=np.float64)
    while True:
        new = label.copy()
        np.minimum.at(new, g.col, label[src])
        if np.array_equal(new, label):
            return label.astype(np.float32)
        label = new
