"""SSSP via Bellman-Ford (paper Fig. 20) as a TOTEM vertex program.

The paper picks Bellman-Ford over Δ-stepping for the GPU because every active
vertex relaxes its edges in parallel with no dynamic buckets — the same
reasoning holds for the TPU (fixed shapes, no dynamic memory).  Our
improvement from the paper (allowing a vertex to become active and relax in
the same round) is inherent to the min-reduction formulation: a vertex's new
distance is visible to the *next* superstep, which is exactly the BSP
semantics.  The paper's ``atomicMin`` becomes the engine's segment_min.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

import dataclasses

from repro.core.bsp import (MIN, BSPEngine, EdgeMessage, IncrementalForm,
                            VertexProgram, gather_src)
from repro.core.graph import CSRGraph

INF = jnp.float32(jnp.inf)


def _edge_fn(state, src, weight, step):
    del step
    dist = gather_src(state["dist"], src)
    active = gather_src(state["active"].astype(jnp.float32), src) > 0
    return jnp.where(active, dist + weight, INF)


def _apply_fn(state, acc, step):
    del step
    dist = state["dist"]
    improved = acc < dist
    new_dist = jnp.where(improved, acc, dist)
    finished = ~jnp.any(improved)
    return {"dist": new_dist, "active": improved}, finished


def _edge_msg_fn(vals, weight, step, consts):
    del step, consts
    # np.inf (not the jnp INF const): Pallas kernels may not capture arrays.
    return jnp.where(vals["active"] > 0, vals["dist"] + weight, np.inf)


# weight_op="add" declares msg = f(src) + w — the min_plus semiring — which
# makes SSSP eligible for the hybrid degree-split backend (relaxation as a
# tropical SpMV over the dense block + ELL remainder) and for the
# distributed hybrid's source-side outbox aggregation: boundary relaxations
# apply the same ⊗ inside kernels/outbox_reduce before crossing the wire.
SSSP_PROGRAM = VertexProgram(combine=MIN, edge_fn=_edge_fn,
                             apply_fn=_apply_fn,
                             edge_msg=EdgeMessage(
                                 gather=("dist", "active"),
                                 fn=_edge_msg_fn, use_weight=True,
                                 weight_op="add"))


def _inc_seed(prev_state, dirty):
    """Warm state after insert-only mutations: Bellman-Ford *is* already a
    relaxation with an active set, so the incremental form is the program
    itself re-seeded — previous distances + the dirty frontier (sources of
    inserted edges that are themselves reached)."""
    dist = prev_state["dist"]
    active = jnp.logical_and(jnp.broadcast_to(dirty, dist.shape),
                             jnp.isfinite(dist))
    return {"dist": dist, "active": active}


# The incremental form reuses the relaxation program; min-plus fixpoints of
# an insert-only mutation window are descent-reachable from the previous
# solution and every old path survives, so the warm result is bitwise equal
# to a cold rerun (docs/dynamic.md has the argument).
SSSP_PROGRAM = dataclasses.replace(
    SSSP_PROGRAM, incremental=IncrementalForm(SSSP_PROGRAM, _inc_seed))


def sssp_batched(engine: BSPEngine,
                 sources: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
    """Run a batch of Q SSSP queries through one engine invocation.

    Returns (dists [Q, n], per-query supersteps [Q]); each query relaxes
    independently and freezes once converged.
    """
    from repro.algorithms.bfs import gather_batch, multi_source_state

    pg = engine.pg
    if pg.fwd.weight is None:
        raise ValueError("SSSP needs edge weights "
                         "(graph.with_uniform_weights)")
    dist0 = multi_source_state(pg, sources)
    active0 = np.isfinite(dist0)
    state, steps = engine.execute(SSSP_PROGRAM, {
        "dist": jnp.asarray(dist0), "active": jnp.asarray(active0)})
    return gather_batch(pg, state["dist"]), np.asarray(steps)


def sssp(engine: BSPEngine, source: int) -> Tuple[np.ndarray, int]:
    dists, steps = sssp_batched(engine, [source])
    return dists[0], int(steps[0])


def sssp_incremental(engine: BSPEngine, prev_dists: np.ndarray,
                     dirty_global: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Warm-start SSSP solutions after insert-only mutations (see
    :func:`repro.algorithms.bfs.bfs_incremental` for the contract)."""
    from repro.algorithms.bfs import gather_batch

    pg = engine.pg
    prev = np.atleast_2d(np.asarray(prev_dists, dtype=np.float32))
    state = {"dist": jnp.asarray(np.stack(
        [pg.scatter_global(row, np.inf) for row in prev]))}
    st, steps = engine.execute(SSSP_PROGRAM, state,
                               incremental=pg.scatter_dirty(dirty_global))
    return gather_batch(pg, st["dist"]), np.asarray(steps)


def sssp_reference(g: CSRGraph, source: int) -> np.ndarray:
    """Pure-numpy Bellman-Ford oracle (edge-parallel rounds)."""
    n = g.num_vertices
    src = g.edge_sources()
    dist = np.full(n, np.inf, dtype=np.float64)
    dist[source] = 0.0
    for _ in range(n):
        cand = dist[src] + g.weights
        new = dist.copy()
        np.minimum.at(new, g.col, cand)
        if np.array_equal(new, dist, equal_nan=True):
            break
        dist = new
    return dist.astype(np.float32)
