"""Betweenness Centrality — Brandes (paper §7.2, Fig. 18).

Two BSP cycles, exactly the paper's structure:

- **Forward** (over out-edges): level-synchronous BFS that also accumulates
  shortest-path counts: frontier vertices push ``sigma`` (sum-reduced);
  undiscovered receivers adopt ``dist = level + 1`` and ``sigma = acc``.
  Because the reduction is a sum over *all* same-level contributions in one
  superstep, the paper's ``atomicAdd(numSPs)`` becomes a segment_sum.
- **Backward** (over *reverse* edges, the paper's two-way pull): vertices at
  ``dist == level+1`` send ``(1 + delta) / sigma`` to their predecessors;
  vertices at ``dist == level`` set ``delta = sigma * acc`` and fold it into
  the bc score.  This runs levels ``max_level-1 .. 1``.

Single-source BC, as in the paper's evaluation (Table 4: "for a single
source"), plus the batched form: ``betweenness_centrality_batched`` runs Q
sources through one forward and one backward engine invocation — each
query's ``max_level`` rides the state as a per-query scalar, so queries at
different depths process their own levels inside the shared loop.
``bc_exact`` chunks all |V| sources through that path instead of
re-entering the engine once per source (the old O(|V|)-dispatch loop is
kept as ``bc_exact_sequential``, the parity oracle).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.bsp import (MIN, SUM, BSPEngine, EdgeMessage, VertexProgram,
                            batch_state, gather_src, unbatch_state)
from repro.core.graph import CSRGraph


# --------------------------- forward cycle ---------------------------------

def _fwd_edge(state, src, weight, step):
    del weight
    dist = gather_src(state["dist"], src)
    sigma = gather_src(state["sigma"], src)
    on_frontier = dist == step.astype(jnp.float32)
    return jnp.where(on_frontier, sigma, 0.0)


def _fwd_apply(state, acc, step):
    dist, sigma = state["dist"], state["sigma"]
    newly = jnp.isinf(dist) & (acc > 0)
    new_dist = jnp.where(newly, step.astype(jnp.float32) + 1.0, dist)
    new_sigma = jnp.where(newly, acc, sigma)
    state = dict(state, dist=new_dist, sigma=new_sigma)
    return state, ~jnp.any(newly)


def _fwd_edge_msg(vals, weight, step, consts):
    del weight, consts
    return jnp.where(vals["dist"] == step, vals["sigma"], 0.0)


FORWARD_PROGRAM = VertexProgram(combine=SUM, edge_fn=_fwd_edge,
                                apply_fn=_fwd_apply,
                                edge_msg=EdgeMessage(
                                    gather=("dist", "sigma"),
                                    fn=_fwd_edge_msg))


# --------------------------- backward cycle --------------------------------

def _bwd_edge(state, src, weight, step):
    del weight
    # level being processed: max_level - 1 - step (per-partition scalar).
    level = (state["max_level"] - 1.0 - step.astype(jnp.float32))[:, None]
    dist = gather_src(state["dist"], src)
    sigma = gather_src(state["sigma"], src)
    delta = gather_src(state["delta"], src)
    sending = (dist == level + 1.0) & (sigma > 0)
    return jnp.where(sending, (1.0 + delta) / jnp.maximum(sigma, 1.0), 0.0)


def _bwd_apply(state, acc, step):
    level = (state["max_level"] - 1.0 - step.astype(jnp.float32))[:, None]
    at_level = state["dist"] == level
    new_delta = jnp.where(at_level, state["sigma"] * acc, state["delta"])
    # Exclude the source (level 0) from its own score, per Brandes.
    add = jnp.where(at_level & (level > 0), new_delta, 0.0)
    state = dict(state, delta=new_delta, bc=state["bc"] + add)
    next_level = state["max_level"][0] - 2.0 - step.astype(jnp.float32)
    return state, next_level < 1.0


def _bwd_edge_msg(vals, weight, step, consts):
    del weight
    level = consts["max_level"] - 1.0 - step
    sending = (vals["dist"] == level + 1.0) & (vals["sigma"] > 0)
    return jnp.where(sending,
                     (1.0 + vals["delta"]) / jnp.maximum(vals["sigma"], 1.0),
                     0.0)


# Both cycles are weightless sum combines → the hybrid backend runs them
# under plus_times; the backward cycle degree-splits the *reverse* graph
# (built by the single-device engine itself, so hybrid BC doesn't need
# include_reverse there; the *distributed* hybrid routes reverse boundary
# edges through the reverse outbox maps, so it does — see
# BSPEngine.provides_reverse).
BACKWARD_PROGRAM = VertexProgram(combine=SUM, edge_fn=_bwd_edge,
                                 apply_fn=_bwd_apply, use_reverse=True,
                                 edge_msg=EdgeMessage(
                                     gather=("dist", "sigma", "delta"),
                                     fn=_bwd_edge_msg,
                                     consts=("max_level",)))


def betweenness_centrality_batched(engine: BSPEngine,
                                   sources: Sequence[int]
                                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-source BC contributions for a batch of Q sources.

    One forward and one backward engine invocation cover the whole batch:
    the forward BFS+sigma cycle converges per query, then each query's
    measured ``max_level`` rides the backward state as a per-query scalar,
    so the shared backward loop walks every query down its *own* levels.
    Queries whose forward tree is shallower than 2 levels contribute
    nothing, exactly as in the single-source path (they spend one no-op
    vote round in the batched backward loop, so their reported step count
    can exceed the sequential count by one).

    Returns (bc [Q, n], per-query total supersteps [Q]).
    """
    from repro.algorithms.bfs import gather_batch, multi_source_state

    pg = engine.pg
    if pg.rev is None and not engine.provides_reverse(BACKWARD_PROGRAM):
        raise ValueError("BC needs reverse edges "
                         "(partition with include_reverse=True)")
    P, V = pg.num_parts, pg.v_max
    q = len(np.asarray(sources).reshape(-1))
    dist0 = multi_source_state(pg, sources)
    sigma0 = multi_source_state(pg, sources, fill=0.0, value=1.0)

    fwd_state, fwd_steps = engine.execute(FORWARD_PROGRAM, {
        "dist": jnp.asarray(dist0), "sigma": jnp.asarray(sigma0)})

    dist = np.asarray(fwd_state["dist"])                   # [Q, P, V]
    finite = np.where(np.isfinite(dist), dist, -np.inf)
    max_level = np.maximum(finite.max(axis=(1, 2)), 0.0)   # [Q]

    bwd_state = {
        "dist": fwd_state["dist"], "sigma": fwd_state["sigma"],
        "delta": jnp.zeros((q, P, V), dtype=jnp.float32),
        "bc": jnp.zeros((q, P, V), dtype=jnp.float32),
        "max_level": jnp.asarray(
            np.broadcast_to(max_level[:, None].astype(np.float32), (q, P))),
    }
    if float(max_level.max(initial=0.0)) >= 2.0:
        bwd_state, bwd_steps = engine.execute(BACKWARD_PROGRAM, bwd_state)
        bwd_steps = np.asarray(bwd_steps)
    else:
        bwd_steps = np.zeros(q, dtype=np.int32)
    bc = gather_batch(pg, bwd_state["bc"])
    return bc, np.asarray(fwd_steps) + bwd_steps


def betweenness_centrality(engine: BSPEngine,
                           source: int) -> Tuple[np.ndarray, int]:
    """Single-source BC contribution; returns (bc [n], total supersteps)."""
    pg = engine.pg
    if pg.rev is None and not engine.provides_reverse(BACKWARD_PROGRAM):
        raise ValueError("BC needs reverse edges "
                         "(partition with include_reverse=True)")
    P, V = pg.num_parts, pg.v_max
    dist0 = np.full((P, V), np.inf, dtype=np.float32)
    sigma0 = np.zeros((P, V), dtype=np.float32)
    sp = int(pg.assignment.part_of[source])
    sl = int(pg.assignment.local_id[source])
    dist0[sp, sl], sigma0[sp, sl] = 0.0, 1.0

    fwd_b, fwd_sq = engine.execute(FORWARD_PROGRAM, batch_state({
        "dist": jnp.asarray(dist0), "sigma": jnp.asarray(sigma0)}))
    fwd_state, fwd_steps = unbatch_state(fwd_b), fwd_sq[0]

    dist = np.asarray(fwd_state["dist"])
    finite = dist[np.isfinite(dist)]
    max_level = float(finite.max()) if len(finite) else 0.0

    bwd_state = {
        "dist": fwd_state["dist"], "sigma": fwd_state["sigma"],
        "delta": jnp.zeros((P, V), dtype=jnp.float32),
        "bc": jnp.zeros((P, V), dtype=jnp.float32),
        "max_level": jnp.full((P,), max_level, dtype=jnp.float32),
    }
    if max_level >= 2.0:
        bwd_b, bwd_sq = engine.execute(BACKWARD_PROGRAM,
                                       batch_state(bwd_state))
        bwd_state, bwd_steps = unbatch_state(bwd_b), bwd_sq[0]
    else:
        bwd_steps = 0
    bc = pg.gather_global(np.asarray(bwd_state["bc"]))
    return bc, int(fwd_steps) + int(bwd_steps)


def bc_reference(g: CSRGraph, source: int) -> np.ndarray:
    """Pure-numpy Brandes oracle (single source, unweighted)."""
    n = g.num_vertices
    dist = np.full(n, np.inf)
    sigma = np.zeros(n)
    dist[source], sigma[source] = 0.0, 1.0
    frontier = [source]
    levels = [frontier]
    d = 0
    while frontier:
        nxt = {}
        for v in frontier:
            for w in g.col[g.row_ptr[v]: g.row_ptr[v + 1]]:
                w = int(w)
                if np.isinf(dist[w]):
                    nxt[w] = True
                    dist[w] = d + 1
        for v in frontier:
            for w in g.col[g.row_ptr[v]: g.row_ptr[v + 1]]:
                w = int(w)
                if dist[w] == d + 1:
                    sigma[w] += sigma[v]
        frontier = list(nxt)
        if frontier:
            levels.append(frontier)
        d += 1
    delta = np.zeros(n)
    bc = np.zeros(n)
    for lvl in reversed(range(1, len(levels))):
        for v in levels[lvl - 1]:
            acc = 0.0
            for w in g.col[g.row_ptr[v]: g.row_ptr[v + 1]]:
                w = int(w)
                if dist[w] == lvl and sigma[w] > 0:
                    acc += (1.0 + delta[w]) / sigma[w]
            delta[v] = sigma[v] * acc
            if lvl - 1 > 0:
                bc[v] += delta[v]
    return bc.astype(np.float32)


def bc_exact(engine: BSPEngine, chunk: Optional[int] = 32) -> np.ndarray:
    """All-sources exact BC via the batched path, in source chunks.

    Replaces the O(|V|)-dispatch loop (one engine re-entry per source) with
    ``⌈|V|/chunk⌉`` batched invocations; the tail chunk is padded with
    repeats of source 0 (their rows are dropped) so every chunk compiles to
    the same Q and the engine's compile cache holds exactly one entry per
    cycle.  Contributions accumulate in source order in float64 —
    bit-identical to ``bc_exact_sequential`` whenever the batched engine
    matches the sequential engine bitwise (asserted in the tier-1 suite).
    ``chunk=None`` runs the whole vertex set as one batch.
    """
    n = engine.pg.num_vertices
    chunk = n if chunk is None else min(chunk, n)
    total = np.zeros(n, dtype=np.float64)
    for lo in range(0, n, chunk):
        srcs = np.arange(lo, min(lo + chunk, n), dtype=np.int64)
        pad = chunk - len(srcs)
        contrib, _ = betweenness_centrality_batched(
            engine, np.concatenate([srcs, np.zeros(pad, np.int64)]))
        for row in contrib[: len(srcs)]:
            total += row          # source-order accumulation (bitwise)
    return total.astype(np.float32)


def bc_exact_sequential(engine: BSPEngine) -> np.ndarray:
    """The pre-batching all-sources loop: one engine re-entry per source.

    Kept as the parity oracle for ``bc_exact`` (and as a measure of the
    dispatch overhead the batched path amortizes away).
    """
    total = np.zeros(engine.pg.num_vertices, dtype=np.float64)
    for s in range(engine.pg.num_vertices):
        contrib, _ = betweenness_centrality(engine, s)
        total += contrib
    return total.astype(np.float32)
