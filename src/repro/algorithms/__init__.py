from repro.algorithms.bfs import (bfs, bfs_batched, bfs_incremental,
                                  bfs_reference)
from repro.algorithms.pagerank import (pagerank, pagerank_reference,
                                       personalized_pagerank,
                                       personalized_pagerank_reference)
from repro.algorithms.sssp import (sssp, sssp_batched, sssp_incremental,
                                   sssp_reference)
from repro.algorithms.cc import (cc_incremental, cc_reference,
                                 connected_components)
from repro.algorithms.bc import (bc_exact, bc_exact_sequential, bc_reference,
                                 betweenness_centrality,
                                 betweenness_centrality_batched)
from repro.algorithms.continuous import (CONTINUOUS_FORMS, ContinuousForm,
                                         continuous_form)

__all__ = [
    "CONTINUOUS_FORMS", "ContinuousForm", "continuous_form",
    "bfs", "bfs_batched", "bfs_incremental", "bfs_reference", "pagerank",
    "pagerank_reference", "personalized_pagerank",
    "personalized_pagerank_reference", "sssp", "sssp_batched",
    "sssp_incremental", "sssp_reference", "connected_components",
    "cc_incremental", "cc_reference", "betweenness_centrality",
    "betweenness_centrality_batched", "bc_exact", "bc_exact_sequential",
    "bc_reference",
]
