from repro.algorithms.bfs import bfs, bfs_reference
from repro.algorithms.pagerank import pagerank, pagerank_reference
from repro.algorithms.sssp import sssp, sssp_reference
from repro.algorithms.cc import connected_components, cc_reference
from repro.algorithms.bc import betweenness_centrality, bc_reference

__all__ = [
    "bfs", "bfs_reference", "pagerank", "pagerank_reference", "sssp",
    "sssp_reference", "connected_components", "cc_reference",
    "betweenness_centrality", "bc_reference",
]
