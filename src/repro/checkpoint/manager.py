"""Atomic, reshardable checkpointing with async save.

Design for the 1000-node story:
- **Atomicity**: write to ``step_N.tmp`` then ``os.rename`` — a crash mid-
  save never corrupts the latest-complete pointer (``rename`` is atomic on
  POSIX).  ``latest()`` only ever sees fully-written checkpoints.
- **Async save**: device→host copies happen synchronously (cheap), the disk
  write runs on a background thread so the train loop loses only the copy
  time (the paper's overlap-communication-with-computation principle applied
  to I/O).
- **Elastic restore**: arrays are stored unsharded (per-leaf .npy inside an
  .npz); ``restore_resharded`` re-places them under ANY mesh/sharding — the
  checkpoint written on a 512-chip run restores onto 256 chips or 1 CPU.
  (On a real multi-host pod each host writes its shard slice; the manifest
  format already carries the leaf paths needed for that extension.)
- **Retention**: keeps the most recent ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


class CheckpointCorruption(RuntimeError):
    """A restored leaf's bytes disagree with its manifest checksum.

    Deliberately NOT retryable (re-reading the same torn file cannot
    succeed): the caller must fall back to an older snapshot or recompute.
    """


def _flatten(tree, prefix=""):
    """Flatten with jax's canonical leaf order (dicts sorted by key)."""
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)) or hasattr(tree, "_fields"):
        items = tree._asdict().items() if hasattr(tree, "_asdict") else \
            enumerate(tree)
        for k, v in items:
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save_tree(self, step: int, tree: Any,
                  extra: Optional[dict] = None,
                  blocking: bool = True) -> Path:
        """Persist an **arbitrary** pytree of arrays.

        The generic entry point: serving snapshots (vertex state, per-query
        step counters, finished votes, dynamic-graph delta/tombstone
        payloads) and train states alike.  ``save`` wraps it in the
        train-shaped ``{"params", "opt_state"}`` tree for back-compat.
        ``extra`` lands in the manifest JSON (small host metadata: replay
        cursors, round indices) and reads back via :meth:`manifest_extra`.
        """
        self.wait()
        flat = _flatten(tree)
        if "" in flat:                       # bare-leaf tree
            flat = {"_": flat.pop("")}
        host = {k: np.asarray(v) for k, v in flat.items()}
        # Per-leaf CRCs into the manifest: a torn/bit-flipped tensor on disk
        # is caught at restore time instead of silently warm-starting a
        # corrupted state.  Computed on the main thread, before the chaos
        # seam below, so an injected tear always mismatches its checksum.
        checksums = {k: int(zlib.crc32(np.ascontiguousarray(v).tobytes()))
                     for k, v in host.items()}
        from repro.runtime import chaos  # local: manager imports stay light
        if chaos.visit("checkpoint.torn", step=int(step)) and host:
            torn_key = sorted(host)[0]
            torn = np.ascontiguousarray(host[torn_key]).copy()
            torn.view(np.uint8)[0] ^= 0x7F
            host[torn_key] = torn

        def write():
            tmp = self.dir / f"step_{step:08d}.npz.tmp"
            final = self.dir / f"step_{step:08d}.npz"
            with open(tmp, "wb") as f:
                np.savez(f, **{k.replace("/", "|"): v
                               for k, v in host.items()})
            os.replace(tmp, final)       # atomic publish
            manifest = self.dir / f"step_{step:08d}.json"
            manifest.write_text(json.dumps(
                {"step": step, "leaves": sorted(host),
                 "checksums": checksums, "extra": extra or {}}))
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return self.dir / f"step_{step:08d}.npz"

    def save(self, step: int, params: Any, opt_state: Any = None,
             extra: Optional[dict] = None, blocking: bool = True) -> Path:
        """Train-shaped adapter over :meth:`save_tree`."""
        tree = {"params": params}
        if opt_state is not None:
            tree["opt_state"] = opt_state
        return self.save_tree(step, tree, extra=extra, blocking=blocking)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*.npz"))
        for old in ckpts[: -self.keep]:
            old.unlink(missing_ok=True)
            old.with_suffix(".json").unlink(missing_ok=True)

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        self.wait()
        ckpts = sorted(self.dir.glob("step_*.npz"))
        valid = [c for c in ckpts if c.with_suffix(".json").exists()]
        if not valid:
            return None
        return int(valid[-1].stem.split("_")[1])

    def restore_tree(self, like: Any, step: Optional[int] = None,
                     verify: bool = True) -> Tuple[int, Any]:
        """Restore an arbitrary pytree into the structure of ``like``.

        ``verify=True`` (default) re-checksums every loaded leaf against the
        manifest CRCs and raises :class:`CheckpointCorruption` on mismatch —
        a torn write never silently warm-starts a corrupted state.  Pre-CRC
        manifests (no ``checksums`` entry) load unverified for back-compat.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        data = np.load(self.dir / f"step_{step:08d}.npz")
        flat = {k.replace("|", "/"): data[k] for k in data.files}
        if verify:
            manifest = self.dir / f"step_{step:08d}.json"
            want = {}
            if manifest.exists():
                want = json.loads(manifest.read_text()).get("checksums", {})
            bad = [k for k, crc in want.items()
                   if k in flat and
                   int(zlib.crc32(np.ascontiguousarray(flat[k]).tobytes()))
                   != int(crc)]
            if bad:
                raise CheckpointCorruption(
                    f"checkpoint step {step} in {self.dir}: leaves {bad[:4]} "
                    f"fail their manifest CRC — torn or bit-flipped on disk; "
                    f"fall back to an older snapshot or recompute")
        leaves, treedef = jax.tree.flatten(like)
        names = [n or "_" for n in _flatten(like)]
        missing = [n for n in names if n not in flat]
        if missing:
            raise KeyError(
                f"checkpoint step {step} in {self.dir} lacks leaves "
                f"{missing[:4]} (have {sorted(flat)[:4]}...) — was the "
                f"snapshot written with a different tree structure?")
        restored = [flat[n] for n in names]
        return step, jax.tree.unflatten(treedef, restored)

    def restore(self, like: Any, step: Optional[int] = None
                ) -> Tuple[int, Any]:
        """Restore into the structure of ``like`` ({"params":..,
        "opt_state":..}).  Adapter over :meth:`restore_tree`."""
        return self.restore_tree(like, step)

    def manifest_extra(self, step: Optional[int] = None) -> dict:
        """Host metadata saved alongside a snapshot (replay cursor, round)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        manifest = self.dir / f"step_{step:08d}.json"
        return json.loads(manifest.read_text()).get("extra", {})


def restore_resharded(manager: CheckpointManager, like: Any, mesh,
                      spec_tree, step: Optional[int] = None):
    """Elastic restore: place checkpoint leaves under a (different) mesh.

    ``spec_tree`` mirrors ``like`` with PartitionSpecs; works across device
    counts because leaves are stored unsharded.
    """
    step, tree = manager.restore(like, step)
    shardings = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    placed = jax.tree.map(
        lambda arr, shd: jax.device_put(arr, shd), tree, shardings)
    return step, placed
