"""Gradient compression for cross-pod all-reduce (distributed-opt trick).

int8 block-quantized compression with error feedback: the pod-local
reduce-scatter runs at full precision (cheap, in-pod ICI), only the cross-pod
all-reduce sees int8 payloads (4× less data on the slowest links).  The
quantization residual is carried to the next step (error feedback) so the
scheme stays convergent (Karimireddy et al. 2019).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _blocked(x: jax.Array) -> Tuple[jax.Array, tuple]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), (x.shape, x.size)


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array, tuple]:
    """→ (int8 values, f32 per-block scales, meta)."""
    blocks, meta = _blocked(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale, meta


def decompress_int8(q: jax.Array, scale: jax.Array, meta: tuple,
                    dtype=jnp.float32) -> jax.Array:
    shape, size = meta
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return out.reshape(shape).astype(dtype)


class CompressedAllReduce(NamedTuple):
    """Error-feedback int8 psum over a mesh axis (used inside shard_map)."""

    axis: str

    def init_error(self, grads) -> Any:
        return jax.tree.map(jnp.zeros_like, grads)

    def __call__(self, grads, error) -> Tuple[Any, Any]:
        def one(g, e):
            g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
            q, s, meta = compress_int8(g32)
            deq = decompress_int8(q, s, meta)
            new_e = (g32 - deq).astype(e.dtype)
            # all-reduce the *quantized* payload (int8 on the wire);
            # psum in int32 to avoid overflow across shards.
            summed = jax.lax.psum(q.astype(jnp.int32), self.axis)
            s_sum = jax.lax.psum(s, self.axis)  # conservative shared scale
            n = jax.lax.psum(jnp.ones((), jnp.float32), self.axis)
            deq_sum = (summed.astype(jnp.float32)
                       * (s_sum / n)).reshape(-1)[:meta[1]]
            return deq_sum.reshape(meta[0]).astype(g.dtype), new_e

        flat_g, tree = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(error)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        new_g = jax.tree.unflatten(tree, [o[0] for o in outs])
        new_e = jax.tree.unflatten(tree, [o[1] for o in outs])
        return new_g, new_e
