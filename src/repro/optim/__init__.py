from repro.optim.adamw import AdamW, apply_updates, global_norm
from repro.optim.compression import (compress_int8, decompress_int8,
                                     CompressedAllReduce)

__all__ = ["AdamW", "apply_updates", "global_norm", "compress_int8",
           "decompress_int8", "CompressedAllReduce"]
