"""AdamW with global-norm clipping, built for sharded pytrees.

Optimizer moments inherit the parameter PartitionSpecs (FSDP-sharded params
→ ZeRO-style sharded optimizer state for free).  All ops are elementwise, so
no resharding is introduced by the update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(jnp.zeros_like, params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.zeros_like, params))

    def _lr(self, step):
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        return self.learning_rate * warm

    def update(self, grads, state: AdamWState,
               params) -> Tuple[Any, AdamWState]:
        if self.clip_norm is not None:
            g_norm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm
                                / jnp.maximum(g_norm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(g),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(m, n, p):
            mhat = m / bc1
            nhat = n / bc2
            return -lr * (mhat / (jnp.sqrt(nhat) + self.eps)
                          + self.weight_decay * p)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params,
                        updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
