"""Checkpoint/restart fault tolerance.

``run_with_restarts`` wraps a step function with: periodic async
checkpointing, exception capture (a node failure surfaces as an exception
in the driver), restore-from-latest, and bounded retry with exponential
backoff.  Because the data pipeline is seekable (data/tokens.py) and the
graph supersteps are deterministic, a restart reproduces the exact
pre-failure trajectory.  State is an **arbitrary pytree** persisted through
``CheckpointManager.save_tree`` — the train driver's ``{"params",
"opt_state"}`` dict is just one shape of it.

Only *retryable* errors burn the restart budget: ``WorkerFailure`` (what
injected faults and worker-death shims raise) and XLA runtime errors.
Programming bugs (``ValueError``, ``KeyError``...) and control flow
(``KeyboardInterrupt``) surface immediately.

``FaultInjector`` deterministically raises at chosen steps or chaos sites
(see runtime/chaos.py) — the node-failure drill used in tests, the chaos CI
job, and the fault-tolerance example.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.checkpoint.manager import CheckpointManager


class WorkerFailure(RuntimeError):
    """A worker/shard died mid-step (or a drill pretended it did)."""


class ExchangeCorruption(WorkerFailure):
    """An exchange checksum mismatched: a payload block was corrupted in
    flight.  Subclasses :class:`WorkerFailure` so the existing
    ``RestartPolicy`` whitelist treats it as retryable — the recovery is a
    bounded window-replay from the last checkpoint, identical to a worker
    death at the same superstep."""


def _xla_error_types() -> tuple:
    types = []
    try:  # jaxlib's runtime error (device OOM, donated-buffer reuse, ...)
        from jax.errors import JaxRuntimeError
        types.append(JaxRuntimeError)
    except ImportError:
        pass
    try:
        from jaxlib.xla_extension import XlaRuntimeError
        types.append(XlaRuntimeError)
    except ImportError:
        pass
    return tuple(types)


#: Errors worth a restart: injected/real worker faults + XLA runtime errors.
RETRYABLE_EXCEPTIONS: tuple = (WorkerFailure,) + _xla_error_types()


@dataclasses.dataclass
class RestartPolicy:
    """Bounded retry with exponential backoff over a retryable whitelist."""
    max_failures: int = 3
    backoff_s: float = 0.0
    backoff_factor: float = 2.0
    retryable: tuple = RETRYABLE_EXCEPTIONS
    failures: int = 0
    restarts: List[dict] = dataclasses.field(default_factory=list)

    def handle(self, exc: BaseException, context: Optional[dict] = None
               ) -> float:
        """Record a failure; return the backoff sleep in seconds.

        Re-raises when ``exc`` is not retryable or the budget is spent.
        """
        if not isinstance(exc, self.retryable):
            raise exc
        self.failures += 1
        self.restarts.append({"error": repr(exc), **(context or {})})
        if self.failures > self.max_failures:
            raise exc
        return self.backoff_s * (self.backoff_factor ** (self.failures - 1))


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault drill: step-indexed (``maybe_fail``) and
    chaos-site-scoped (``on_visit``) injection.

    ``sites`` maps a site name to trigger specs.  A spec is a dict of
    matchers — ``{"at": n}`` fires at the n-th visit of the site,
    ``{"round": r}`` / ``{"index": i}`` / any other key matches the visit's
    context by equality, ``{"shard": s}`` matches a shard id (membership in
    a ctx ``shards`` tuple when the site is dispatched for a shard group).
    ``{"flag": True}`` makes the spec non-raising (the site's caller sees a
    True flag — used for data-level poison).  Each spec fires at most once.
    """
    fail_at_steps: Set[int] = dataclasses.field(default_factory=set)
    exc: type = WorkerFailure
    fired: Set[int] = dataclasses.field(default_factory=set)
    sites: Dict[str, Sequence[dict]] = dataclasses.field(default_factory=dict)
    site_fired: List[Tuple[str, int, dict]] = \
        dataclasses.field(default_factory=list)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected node failure at step {step}")

    def _matches(self, spec: dict, count: int, ctx: dict) -> bool:
        at = spec.get("at")
        if at is not None and count != at:
            return False
        want_shard = spec.get("shard")
        if want_shard is not None:
            if "shard" in ctx:
                if ctx["shard"] != want_shard:
                    return False
            elif "shards" in ctx:
                if want_shard not in ctx["shards"]:
                    return False
            else:
                return False
        for k, v in spec.items():
            if k in ("at", "shard", "flag", "exc", "_done"):
                continue
            if ctx.get(k) != v:
                return False
        return True

    def on_visit(self, site: str, count: int, ctx: dict) -> bool:
        flagged = False
        for spec in self.sites.get(site, ()):
            if spec.get("_done") or not self._matches(spec, count, ctx):
                continue
            spec["_done"] = True
            self.site_fired.append((site, count, dict(ctx)))
            if spec.get("flag"):
                flagged = True
            else:
                exc = spec.get("exc", self.exc)
                raise exc(f"injected fault at site {site!r} "
                          f"(visit {count}, ctx={ctx})")
        return flagged


def run_with_restarts(
    step_fn: Callable[[int, Any], Tuple[Any, dict]],
    state: Any,
    num_steps: int,
    manager: CheckpointManager,
    checkpoint_every: int = 10,
    max_failures: int = 3,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
    retryable: Optional[tuple] = None,
    backoff_s: float = 0.0,
) -> Tuple[Any, dict]:
    """Run ``state = step_fn(step, state)`` for ``num_steps`` with
    checkpoint/restart.  ``state`` may be any pytree.  Returns
    (final_state, summary)."""
    policy = RestartPolicy(
        max_failures=max_failures, backoff_s=backoff_s,
        retryable=retryable if retryable is not None
        else RETRYABLE_EXCEPTIONS)
    start = manager.latest_step()
    if start is not None:
        _, state = manager.restore_tree(state, start)
    else:
        manager.save_tree(0, state, blocking=True)
        start = 0

    step = start
    while step < num_steps:
        try:
            state, metrics = step_fn(step, state)
            if on_metrics:
                on_metrics(step, metrics)
            if (step + 1) % checkpoint_every == 0:
                manager.save_tree(step + 1, state, blocking=False)
            step += 1
        except Exception as e:                      # node failure drill
            sleep_s = policy.handle(e, context={"step": step})
            if sleep_s:
                time.sleep(sleep_s)
            latest = manager.latest_step()
            if latest is None:
                raise
            _, state = manager.restore_tree(state, latest)
            step = latest
    manager.wait()
    return state, {"failures": policy.failures, "restarts": policy.restarts,
                   "final_step": step}


def serve_with_restarts(
    make_session: Callable[[], Any],
    manager: CheckpointManager,
    checkpoint_every: int = 1,
    max_failures: int = 3,
    backoff_s: float = 0.0,
    retryable: Optional[tuple] = None,
) -> Tuple[Any, dict]:
    """Drive a continuous serving session to drained with checkpoint/restart.

    The session-shaped sibling of :func:`run_with_restarts`: where that
    wraps a bare ``step_fn(step, state)``, this wraps the *session
    protocol* — any object with ``step() -> bool`` (False when drained),
    ``snapshot(manager, step)``, ``restore(manager)`` and a ``windows``
    counter.  On a retryable failure the session is **rebuilt from the
    factory** (the engine may have died with it) and restored from the
    latest snapshot, which carries the refilled slot occupancy — occupancy
    mask, per-slot query ids and per-slot step frames ride the checkpoint
    carry, so the restarted loop resumes mid-refill, not from the initial
    admission.  Duck-typed on purpose: this module must not import the
    session layer (session → sla → failures).

    Returns ``(session, summary)`` with the drained session.
    """
    policy = RestartPolicy(
        max_failures=max_failures, backoff_s=backoff_s,
        retryable=retryable if retryable is not None
        else RETRYABLE_EXCEPTIONS)
    session = make_session()
    if manager.latest_step() is not None:
        session.restore(manager)
    else:
        session.snapshot(manager, 0, blocking=True)
    while True:
        try:
            alive = session.step()
            if session.windows % checkpoint_every == 0:
                session.snapshot(manager, session.windows)
            if not alive:
                break
        except Exception as e:                      # noqa: BLE001 — policy
            sleep_s = policy.handle(e, context={"windows": session.windows})
            if sleep_s:
                time.sleep(sleep_s)
            if manager.latest_step() is None:
                raise
            session = make_session()
            session.restore(manager)
    manager.wait()
    return session, {"failures": policy.failures,
                     "restarts": policy.restarts,
                     "windows": session.windows}
