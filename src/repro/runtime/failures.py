"""Checkpoint/restart fault tolerance.

``run_with_restarts`` wraps a step function with: periodic async
checkpointing, exception capture (a node failure surfaces as an exception
in the driver), restore-from-latest, and bounded retry.  Because the data
pipeline is seekable (data/tokens.py) and the graph supersteps are
deterministic, a restart reproduces the exact pre-failure trajectory.

``FaultInjector`` deterministically raises at chosen steps — the node-failure
drill used in tests and the fault-tolerance example.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional, Set, Tuple

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class FaultInjector:
    fail_at_steps: Set[int]
    exc: type = RuntimeError
    fired: Set[int] = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected node failure at step {step}")


def run_with_restarts(
    step_fn: Callable[[int, Any], Tuple[Any, dict]],
    state: Any,
    num_steps: int,
    manager: CheckpointManager,
    checkpoint_every: int = 10,
    max_failures: int = 3,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
) -> Tuple[Any, dict]:
    """Run ``state = step_fn(step, state)`` for ``num_steps`` with
    checkpoint/restart.  Returns (final_state, summary)."""
    failures = 0
    restarts = []
    start = manager.latest_step()
    if start is not None:
        _, state = manager.restore(state, start)
        start += 1
    else:
        manager.save(0, state["params"], state.get("opt_state"),
                     blocking=True)
        start = 0

    step = start
    while step < num_steps:
        try:
            state, metrics = step_fn(step, state)
            if on_metrics:
                on_metrics(step, metrics)
            if (step + 1) % checkpoint_every == 0:
                manager.save(step + 1, state["params"],
                             state.get("opt_state"), blocking=False)
            step += 1
        except Exception as e:                      # node failure drill
            failures += 1
            restarts.append({"step": step, "error": repr(e)})
            if failures > max_failures:
                raise
            latest = manager.latest_step()
            if latest is None:
                raise
            _, state = manager.restore(state, latest)
            step = latest
    manager.wait()
    return state, {"failures": failures, "restarts": restarts,
                   "final_step": step}
