"""Serving SLA policies: admission control, query quarantine, degradation.

The serving driver treats the host as the reliability tier (the paper's
hybrid split applied to operations): accelerator work is optimistic, the
host enforces the contract.

- :class:`AdmissionController` — bounded queue in front of the query
  stream; when full, offers are rejected **with a reason** instead of
  growing latency unboundedly.
- :class:`QuarantinePolicy` — the divergence watchdog for the batched
  while_loop.  Runs at chunk boundaries of the checkpointable run mode
  (``run_batched_chunked``'s ``on_chunk`` hook): NaN-producing queries and
  queries exceeding a superstep budget are force-finished (frozen bitwise,
  exactly like converged queries) and reported — a poisoned query never
  pins the batch.
- :class:`DegradationLadder` — primary backend, bounded retry, then the
  reference backend for the affected batch; every downgrade is reported.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.runtime.failures import RETRYABLE_EXCEPTIONS


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdmissionController:
    """Bounded admission queue: reject-with-reason when full."""
    capacity: int
    admitted: int = 0
    rejected: List[dict] = dataclasses.field(default_factory=list)
    _queue: deque = dataclasses.field(default_factory=deque)

    def offer(self, query: Any, deadline_ms: Optional[float] = None) -> bool:
        if len(self._queue) >= self.capacity:
            self.rejected.append({
                "query": query, "reason": "queue_full",
                "detail": f"admission queue at capacity "
                          f"{self.capacity}; resubmit or raise capacity"})
            return False
        self._queue.append((query, deadline_ms))
        self.admitted += 1
        return True

    def take(self, k: int) -> List[Any]:
        out = []
        while self._queue and len(out) < k:
            out.append(self._queue.popleft()[0])
        return out

    def take_entries(self, k: int) -> List[tuple]:
        """Pop up to ``k`` pending ``(query, deadline_ms)`` entries — how a
        continuous-batching session feeds freed slots without losing the
        per-query deadline it admitted with."""
        out = []
        while self._queue and len(out) < k:
            out.append(self._queue.popleft())
        return out

    def reorder(self, key: Callable[[Any], Any]) -> None:
        """Stable-reorder the pending queue by ``key(query)`` (ascending).

        The depth-aware admission schedule for continuous batching: order
        pending queries shallow-first (out-degree proxy, see
        ``graph_serve.estimate_depth_order``) so a freed slot never waits
        on a deep query while shallow ones queue behind it.  Admission
        accounting (capacity, rejects) is unaffected.
        """
        self._queue = deque(sorted(self._queue, key=lambda e: key(e[0])))

    def __len__(self) -> int:
        return len(self._queue)


# ---------------------------------------------------------------------------
# quarantine (divergence watchdog)
# ---------------------------------------------------------------------------

def nonfinite_queries(state, combine: str = "min") -> np.ndarray:
    """[Q] bool: queries whose vertex state is semiring-illegally non-finite.

    The legality of ``inf`` depends on the combine: under a min semiring
    (bfs/sssp/cc) ``+inf`` is the canonical "unreached" value, so only NaN
    and ``-inf`` are poison; under a sum combine (pagerank/bc forward
    sigma) *any* non-finite value means an overflow or poisoned
    accumulation escaped — ``~isfinite`` is the right net.
    """
    masks = []
    for leaf in jax.tree.leaves(state):
        arr = np.asarray(leaf)
        if not np.issubdtype(arr.dtype, np.floating):
            continue
        flat = arr.reshape(arr.shape[0], -1)
        if combine == "sum":
            bad = ~np.isfinite(flat)
        else:
            bad = np.isnan(flat) | np.isneginf(flat)
        masks.append(bad.any(axis=1))
    if not masks:
        return np.zeros(0, bool)
    out = masks[0].copy()
    for m in masks[1:]:
        out |= m
    return out


def nan_queries(state) -> np.ndarray:
    """Back-compat alias: min-semiring rules (NaN / -inf are poison)."""
    return nonfinite_queries(state, combine="min")


@dataclasses.dataclass
class QuarantinePolicy:
    """Chunk-boundary scan: quarantine non-finite / over-budget queries.

    ``combine`` selects the finiteness rule (see ``nonfinite_queries``):
    min-semiring states keep ``+inf`` legal for unreached slots; sum
    combines treat any non-finite value as poison.

    Use as the ``on_chunk`` hook: ``engine.execute(.., chunk=k,
    on_chunk=policy.scan)`` after ``policy.begin(q)``.  ``quarantined``
    accumulates (query, reason, step) reports across runs; ``begin`` resets
    only the per-run kill mask, so a standing query re-poisoned on every
    refresh is re-quarantined each run but reported once per
    (query, reason).
    """
    superstep_budget: Optional[int] = None
    check_nan: bool = True
    combine: str = "min"
    quarantined: List[dict] = dataclasses.field(default_factory=list)
    _killed: Optional[np.ndarray] = None
    _reported: set = dataclasses.field(default_factory=set)

    def begin(self, num_queries: int):
        self._killed = np.zeros(num_queries, bool)

    def release(self, slots: np.ndarray) -> None:
        """Clear the kill record for refilled ``slots`` (a [Q] bool mask).

        Continuous batching reuses slot indices for new tenants; without a
        release, a slot once quarantined would stay marked killed and its
        next tenant would silently escape the NaN / budget scan.
        """
        if self._killed is None:
            return
        slots = np.asarray(slots, bool)
        if len(slots) == len(self._killed):
            self._killed &= ~slots

    def scan(self, snap: dict,
             ids: Optional[np.ndarray] = None) -> Optional[np.ndarray]:
        """Return a [Q] kill mask (or None).  ``ids`` maps slot index to a
        stable query id for reporting — continuous sessions pass their
        per-slot query ids so a report names the query, not the slot, and
        re-use of a slot by a new poisoned tenant is reported anew."""
        fin = np.asarray(snap["fin"])
        steps_q = np.asarray(snap["steps_q"])
        q = len(fin)
        if self._killed is None or len(self._killed) != q:
            self._killed = np.zeros(q, bool)
        kill = np.zeros(q, bool)
        reasons: Dict[int, str] = {}
        if self.check_nan:
            bad = nonfinite_queries(snap["state"], combine=self.combine)
            if len(bad) == q:
                for i in np.flatnonzero(bad & ~self._killed):
                    kill[i] = True
                    reasons[int(i)] = "nonfinite"
        if self.superstep_budget is not None:
            over = (steps_q >= self.superstep_budget) & ~fin & ~self._killed
            for i in np.flatnonzero(over):
                kill[i] = True
                reasons.setdefault(int(i), "superstep_budget")
        for i, reason in sorted(reasons.items()):
            qid = int(ids[i]) if ids is not None else i
            if (qid, reason) not in self._reported:
                self._reported.add((qid, reason))
                self.quarantined.append(
                    {"query": qid, "reason": reason,
                     "step": int(snap["step"]),
                     "steps_q": int(steps_q[i])})
        self._killed |= kill
        return kill if kill.any() else None


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DegradationLadder:
    """Primary → bounded retry → reference fallback for one query batch."""
    retries: int = 1
    backoff_s: float = 0.0
    retryable: tuple = RETRYABLE_EXCEPTIONS
    downgrades: List[dict] = dataclasses.field(default_factory=list)

    def run(self, primary: Callable[[], Any], fallback: Callable[[], Any],
            label: str = "") -> Any:
        """Call ``primary`` with bounded retries, else ``fallback``.

        Takes thunks, not engines: a continuous session threads itself
        through by closing over ``session.step()`` for the primary and a
        fallback-engine session *restored from the primary's snapshot*
        (occupancy mask and per-slot query ids ride the snapshot carry) —
        see ``ServeSession.step_with_fallback``.
        """
        errors = []
        for attempt in range(1 + self.retries):
            try:
                return primary()
            except self.retryable as e:
                errors.append(repr(e))
                if self.backoff_s:
                    time.sleep(self.backoff_s * (2 ** attempt))
        self.downgrades.append(
            {"label": label, "errors": errors,
             "detail": "primary backend failed on retry; batch served by "
                       "the reference backend"})
        return fallback()
