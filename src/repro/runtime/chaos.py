"""Failure-injection site registry (the chaos drill's control plane).

The engine stack is instrumented with named *sites* — host-visible seams
where a worker fault would surface in a real deployment:

- ``superstep.chunk``   chunk boundary of the checkpointable run mode
                        (``run_batched_chunked``); ctx: step, chunk, plus
                        caller context (e.g. serving round)
- ``worker.chunk``      distributed chunk dispatch; ctx: shards, step
- ``exchange``          inside the distributed outbox exchange (trace-time)
- ``kernel.fused``      inside the fused-kernel compute (trace-time)
- ``kernel.hybrid``     inside the hybrid two-engine superstep (trace-time)
- ``kernel.dispatch``   host-side dispatch of a query batch to the primary
                        backend (the degradation ladder's retry point)
- ``mutation.apply``    entry of ``DynamicGraph.apply_mutations``
- ``mutation.scatter``  mid-mutation-batch, after host planning but before
                        the device scatter — a crash here leaves the batch
                        unacknowledged (recovery must rebuild + replay)
- ``serve.round``       top of a serving round
- ``query.poison``      non-raising flag: the serving driver poisons a
                        query's initial state (NaN) when this fires

Data-corruption sites (the *fail-silent* half of the fault model — each
flips bits instead of raising, and the integrity layer in
``runtime/verify.py`` / the checksummed exchange must catch it):

- ``state.corrupt``     non-raising flag: ``run_batched_chunked`` bit-flips
                        one element of the first float state leaf at the
                        window boundary (host-side, between compiled
                        windows); ctx: step, plus caller context
- ``exchange.payload``  non-raising flag: the checked exchange corrupts one
                        outbox element *after* send-side tags are computed
                        (rides a traced poison operand — no retrace); the
                        inbox-side tag check must convert it into an
                        ``ExchangeCorruption``
- ``checkpoint.torn``   non-raising flag: ``CheckpointManager.save_tree``
                        tears one tensor after manifest checksums are
                        computed — ``restore_tree(verify=True)`` must refuse
                        the snapshot; ctx: step
- ``tombstone.flip``    non-raising flag: the dynamic-graph chunk dispatch
                        flips one tombstone mask bit (a deleted edge
                        resurrects) via a traced operand; the result
                        certifier must reject the harvested fixpoint

``visit(site, **ctx)`` is a cheap no-op until an injector is installed
(``install``); injectors decide per-visit whether to raise (worker fault)
or to return a flag (data-level poison).  Visit counts per site are global
and monotone, so a drill's arming (``{"at": n}`` / ``{"round": r}``) is
deterministic for a fixed seed and schedule.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, List, Tuple


class ChaosRegistry:
    def __init__(self):
        self._injectors: List = []
        self.counts: Dict[str, int] = {}
        self.fired_log: List[Tuple[str, int]] = []

    @property
    def armed(self) -> bool:
        return bool(self._injectors)

    def install(self, injector):
        """Install an injector exposing ``on_visit(site, count, ctx)``."""
        self._injectors.append(injector)
        return injector

    def remove(self, injector):
        if injector in self._injectors:
            self._injectors.remove(injector)

    def clear(self):
        self._injectors.clear()

    def reset(self):
        """Forget visit counts and the fired log (injectors stay)."""
        self.counts.clear()
        self.fired_log.clear()

    def visit(self, site: str, **ctx) -> bool:
        """Record a visit; let injectors raise or flag.  Returns True when a
        non-raising (flag) injection fired at this visit."""
        if not self._injectors:
            return False
        n = self.counts.get(site, 0)
        self.counts[site] = n + 1
        flagged = False
        for inj in list(self._injectors):
            if inj.on_visit(site, n, ctx):      # may raise a worker fault
                flagged = True
        if flagged:
            self.fired_log.append((site, n))
        return flagged


registry = ChaosRegistry()


def visit(site: str, **ctx) -> bool:
    return registry.visit(site, **ctx)


def install(injector):
    return registry.install(injector)


def clear():
    registry.clear()


@contextmanager
def active(*injectors):
    """Scope injectors to a block; resets counts on exit."""
    for inj in injectors:
        registry.install(inj)
    try:
        yield registry
    finally:
        for inj in injectors:
            registry.remove(inj)
        registry.reset()
